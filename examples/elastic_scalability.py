"""The paper's core demo: the SAME service handles a growing federation by
switching engines — the memory wall never appears.

Sweeps client counts against a memory-capped "single node"; shows the
classification flipping from VMEM_RESIDENT -> HBM_LOCAL -> DISTRIBUTED,
the seamless-transition signal, and that fused results stay identical
across engines (paper §IV-C).

    PYTHONPATH=src python examples/elastic_scalability.py
"""
import numpy as np

from repro.core import (
    AggregationService,
    LocalEngine,
    Planner,
    Workload,
    classify,
    get_fusion,
)
from repro.utils.mem import bytes_to_human

P = 200_000          # scaled model update (0.8 MB fp32)
rng = np.random.default_rng(0)
fedavg = get_fusion("fedavg")

print(f"{'clients':>8} {'S':>10} {'class':>14} {'engine':>12} "
      f"{'est(s)':>9} {'route->store':>12}")
planner = Planner(n_devices=256)
for n in (4, 64, 1024, 16_384, 262_144):
    load = Workload(update_bytes=P * 4, n_clients=n)
    plan = planner.plan(load, fedavg)
    print(f"{n:8d} {bytes_to_human(load.total_bytes):>10} "
          f"{classify(load).value:>14} {plan.engine:>12} "
          f"{plan.est_seconds:9.4f} "
          f"{str(plan.engine != 'local'):>12}")

# engine equivalence at a size we can actually run here
n = 24
u = rng.normal(size=(n, P)).astype(np.float32)
w = rng.uniform(1, 50, size=(n,)).astype(np.float32)
a = np.asarray(LocalEngine(strategy="jnp").fuse(fedavg, u, w))
b = np.asarray(
    LocalEngine(strategy="jnp", memory_cap_bytes=P * 4 * 4).fuse(fedavg, u, w)
)
print(f"\nfull-memory vs memory-capped-streaming engines allclose: "
      f"{np.allclose(a, b, rtol=1e-5, atol=1e-6)} (paper §IV-C invariant)")
