"""Serve a federated-trained model: a few FL rounds, then batched
autoregressive decoding with per-layer KV/state caches — exercising the
same decode path the decode_32k/long_500k dry-runs lower at pod scale.

    PYTHONPATH=src python examples/serve_federated_model.py --arch zamba2-1.2b
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import AggregationService
from repro.data import FederatedLoader, SyntheticLM
from repro.fl import Client, FederatedServer
from repro.launch.serve import generate
from repro.models import build_model
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(), vocab=512
    )
    model = build_model(cfg)
    loader = FederatedLoader(
        gen=SyntheticLM(vocab=cfg.vocab, seed=0, temperature=0.5),
        n_clients=4, batch=8, seq_len=32,
    )
    clients = [
        Client(client_id=i, model=model, optimizer=sgd(0.5), local_steps=2)
        for i in range(4)
    ]
    server = FederatedServer(
        model=model, clients=clients, loader=loader,
        service=AggregationService(fusion="fedavg", local_strategy="jnp"),
    )
    for r in range(args.rounds):
        res = server.run_round(r)
        print(f"[train] round {r}: loss={res.mean_client_loss:.4f}")

    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, size=(2, 8)),
        jnp.int32,
    )
    out = generate(model, server.params, prompt, args.new_tokens,
                   cache_len=64)
    print(f"[serve] {cfg.arch_id}: generated {args.new_tokens} tokens/seq")
    print("[serve] tokens:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
