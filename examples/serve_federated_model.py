"""Serve a federated-trained model: a few FL rounds, then batched
autoregressive decoding with per-layer KV/state caches — exercising the
same decode path the decode_32k/long_500k dry-runs lower at pod scale.

    PYTHONPATH=src python examples/serve_federated_model.py --arch zamba2-1.2b
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import AggregationService
from repro.data import FederatedLoader, SyntheticLM
from repro.fl import Client, FederatedServer
from repro.models import build_model
from repro.optim import sgd


def generate(model, params, prompt: jnp.ndarray, n_new: int,
             cache_len: int, temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature decode. prompt: (B, T0) int32.

    Lives with the example: ``repro.launch.serve`` is the aggregation
    ingest service now, and this demo's batched decode loop is the only
    consumer of a toy text-generation path."""
    B, T0 = prompt.shape
    cache = model.init_cache(B, cache_len)
    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos)
    )
    rng = jax.random.PRNGKey(seed)
    toks = [prompt]
    logits = None
    # teacher-forced prefill through the decode path (cache warmup)
    for t in range(T0):
        cache, logits = step(params, cache, prompt[:, t: t + 1],
                             jnp.int32(t))
    cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [cur]
    for i in range(n_new - 1):
        cache, logits = step(params, cache, cur, jnp.int32(T0 + i))
        if temperature > 0:
            rng, k = jax.random.split(rng)
            cur = jax.random.categorical(
                k, logits / temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(cur)
    return jnp.concatenate(toks + out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(), vocab=512
    )
    model = build_model(cfg)
    loader = FederatedLoader(
        gen=SyntheticLM(vocab=cfg.vocab, seed=0, temperature=0.5),
        n_clients=4, batch=8, seq_len=32,
    )
    clients = [
        Client(client_id=i, model=model, optimizer=sgd(0.5), local_steps=2)
        for i in range(4)
    ]
    server = FederatedServer(
        model=model, clients=clients, loader=loader,
        service=AggregationService(fusion="fedavg", local_strategy="jnp"),
    )
    for r in range(args.rounds):
        res = server.run_round(r)
        print(f"[train] round {r}: loss={res.mean_client_loss:.4f}")

    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, size=(2, 8)),
        jnp.int32,
    )
    out = generate(model, server.params, prompt, args.new_tokens,
                   cache_len=64)
    print(f"[serve] {cfg.arch_id}: generated {args.new_tokens} tokens/seq")
    print("[serve] tokens:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
