"""End-to-end federated training of a (reduced) assigned architecture.

Non-IID clients train locally; the adaptive aggregation service fuses
every round; global loss drops. Also demonstrates byzantine robustness:
with --poison, client 0 sends garbage and --fusion coordmedian shrugs.

    PYTHONPATH=src python examples/federated_training.py \
        --arch gemma3-1b --rounds 10 [--poison --fusion coordmedian]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import AggregationService
from repro.data import FederatedLoader, SyntheticLM
from repro.fl import Client, FederatedServer
from repro.models import build_model
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--fusion", default="fedavg")
    ap.add_argument("--poison", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, vocab=min(cfg.vocab, 512))
    model = build_model(cfg)
    loader = FederatedLoader(
        gen=SyntheticLM(vocab=cfg.vocab, seed=0, temperature=0.5),
        n_clients=args.clients, batch=8, seq_len=32,
    )
    clients = [
        Client(client_id=i, model=model, optimizer=sgd(0.5), local_steps=2)
        for i in range(args.clients)
    ]
    if args.poison:
        bad = clients[0]
        orig = bad.train_round

        def poisoned(params, batch_fn, r):
            upd, loss = orig(params, batch_fn, r)
            upd = jax.tree_util.tree_map(
                lambda u: u + 100.0 * jnp.sign(u), upd
            )
            return upd, loss

        bad.train_round = poisoned
        print("[example] client 0 is byzantine (+-100 on every weight)")

    service = AggregationService(fusion=args.fusion, local_strategy="jnp")
    server = FederatedServer(model=model, clients=clients, loader=loader,
                             service=service)
    print(f"[example] {cfg.arch_id}: {cfg.num_params():,} params, "
          f"{args.clients} clients, fusion={args.fusion}")
    for r in range(args.rounds):
        res = server.run_round(r)
        print(f"  round {r:2d}: loss={res.mean_client_loss:.4f} "
              f"engine={res.report.plan.engine}")
    first, last = server.results[0], server.results[-1]
    print(f"[example] loss {first.mean_client_loss:.4f} -> "
          f"{last.mean_client_loss:.4f}")


if __name__ == "__main__":
    main()
