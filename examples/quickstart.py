"""Quickstart: the adaptive aggregation service in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import AggregationService, UpdateStore, Workload, classify
from repro.utils.mem import bytes_to_human

# 1. A federated round: 16 clients, each holding a small "model update"
rng = np.random.default_rng(0)
template = {"conv/w": jnp.zeros((3, 3, 8, 16)), "dense/w": jnp.zeros((128, 10))}
updates = [
    {k: jnp.asarray(rng.normal(size=v.shape), jnp.float32)
     for k, v in template.items()}
    for _ in range(16)
]
weights = list(rng.integers(10, 100, size=16).astype(float))  # sample counts

# 2. The service classifies the workload (paper Algorithm 1) and picks an
#    engine: single-chip fusion for small loads, distributed map-reduce
#    for loads that exceed one chip.
service = AggregationService(fusion="fedavg", local_strategy="jnp")
fused, report = service.aggregate(
    updates=updates, weights=weights, template=template
)

load = Workload(update_bytes=report.update_bytes, n_clients=report.n_clients)
print(f"workload      : {report.n_clients} clients x "
      f"{bytes_to_human(report.update_bytes)} = "
      f"{bytes_to_human(load.total_bytes)}")
print(f"classification: {classify(load).value}")
print(f"engine        : {report.plan.engine} "
      f"({report.plan.reason}), fused in {report.fuse_seconds*1e3:.1f} ms")
print(f"fused example : dense/w[0,:4] = {np.asarray(fused['dense/w'][0,:4])}")

# 3. Large loads route through the UpdateStore (the HDFS analogue): clients
#    write, the monitor gates on a threshold, the distributed engine fuses.
store = UpdateStore()
svc2 = AggregationService(fusion="coordmedian", store=store,
                          local_strategy="jnp", monitor_timeout=2.0)
for i, u in enumerate(updates):
    store.write(f"client{i}", u)
fused2, rep2 = svc2.aggregate(from_store=True, template=template,
                              expected_clients=16)
print(f"store path    : monitor_ready={rep2.monitor.ready} "
      f"count={rep2.monitor.count} engine={rep2.plan.engine} "
      f"(robust fusion: coordinate-wise median)")
