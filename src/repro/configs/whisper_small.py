"""Whisper-small — encoder-decoder ASR backbone [arXiv:2212.04356].

12 encoder + 12 decoder layers, d_model=768, 12H MHA (kv=12), d_ff=3072,
vocab=51865. The mel-spectrogram + 2x conv1d frontend is STUBBED per the
task carve-out: input_specs() supplies precomputed frame embeddings
(B, 1500, 768) — 30 s of audio at 50 Hz after the conv stride-2.
"""
from repro.configs.base import AttnPattern, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,             # decoder layers
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    qkv_bias=False,
    tie_embeddings=True,
    rope_theta=10_000.0,     # decoder uses learned pos in the paper; rope here
    attn=AttnPattern(),
    n_audio_frames=1536,  # 30 s @ 50 Hz = 1500, padded to the 512-tile grid
    max_seq_len=32_768,
    citation="arXiv:2212.04356 (Whisper: robust speech recognition)",
    supports_long_context=False,
)
