"""Architecture registry: ``--arch <id>`` resolution.

Ten assigned architectures (public-literature pool; citations inline in
each config module) + the paper's own CNN update suite (Table I).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    INPUT_SHAPES,
    AttnPattern,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    XLSTMConfig,
    input_specs,
)
from repro.configs.cnn_suite import CNN_SUITE, UpdateSpec

from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.qwen2_0_5b import CONFIG as QWEN2_0_5B
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.gemma3_1b import CONFIG as GEMMA3_1B
from repro.configs.deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2_1_2B

ARCHITECTURES: Dict[str, ModelConfig] = {
    c.arch_id: c
    for c in (
        MINITRON_8B,
        LLAVA_NEXT_34B,
        DBRX_132B,
        XLSTM_350M,
        QWEN2_0_5B,
        WHISPER_SMALL,
        QWEN2_5_3B,
        GEMMA3_1B,
        DEEPSEEK_MOE_16B,
        ZAMBA2_1_2B,
    )
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id.endswith("-smoke"):
        return ARCHITECTURES[arch_id[: -len("-smoke")]].reduced()
    return ARCHITECTURES[arch_id]


def applicable_shapes(cfg: ModelConfig):
    """The (arch x shape) grid with documented skips (DESIGN.md §4)."""
    out = []
    for shape in INPUT_SHAPES.values():
        if shape.name == "long_500k" and not cfg.supports_long_context:
            continue
        out.append(shape)
    return out


__all__ = [
    "ARCHITECTURES",
    "CNN_SUITE",
    "INPUT_SHAPES",
    "AttnPattern",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "UpdateSpec",
    "XLSTMConfig",
    "applicable_shapes",
    "get_config",
    "input_specs",
]
