"""Zamba2-1.2B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

38 Mamba2 layers, d_model=2048; a single SHARED transformer block
(32H MHA kv=32 + MLP d_ff=8192) whose weights are reused at every
interleave point (every 6th Mamba layer), ssm_state=64, vocab=32000.
"""
from repro.configs.base import AttnPattern, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    qkv_bias=False,
    tie_embeddings=True,
    rope_theta=10_000.0,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    hybrid_shared_every=6,
    attn=AttnPattern(sliding_window=2048),  # shared block attends windowed
    max_seq_len=1_048_576,
    citation="arXiv:2411.15242 (Zamba2 suite: SSM-hybrid)",
    supports_long_context=True,  # Mamba2 state + windowed shared attention
)
