"""Config system: architecture + input-shape descriptors.

Every assigned architecture gets a ``ModelConfig`` with the *exact* numbers
from the assignment (citations in each file). ``reduced()`` yields the
smoke-test variant (≤2 layers, d_model ≤ 512, ≤4 experts) exercised on CPU;
full configs are only touched through ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0           # always-on shared experts (DeepSeekMoE)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64         # N in Mamba2 / SSD
    head_dim: int = 64          # P (channels per SSM head)
    n_ssm_heads: int = 0        # derived if 0: d_inner // head_dim
    expand: int = 2             # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256            # SSD chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # ratio of mLSTM to sLSTM blocks, xLSTM[a:b] notation of the paper
    slstm_every: int = 7        # an sLSTM block every k-th block (0 = none)
    mlstm_qk_dim_factor: float = 0.5
    mlstm_v_dim_factor: float = 1.0
    proj_factor: float = 1.3334  # sLSTM ffn up-projection factor
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class AttnPattern:
    """Per-layer attention pattern.

    sliding_window > 0 with local_to_global k>0 means: layers whose index
    % (k+1) != k use windowed attention, every (k+1)-th layer is global
    (gemma3's 5:1). sliding_window>0 and local_to_global==0: ALL layers
    windowed.
    """

    sliding_window: int = 0
    local_to_global: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # derived if 0
    qkv_bias: bool = False
    tie_embeddings: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn: AttnPattern = AttnPattern()
    # hybrid (zamba2): a shared attention+MLP block every k SSM layers
    hybrid_shared_every: int = 0
    # enc-dec (whisper): encoder layers; n_layers = decoder layers
    n_encoder_layers: int = 0
    # modality stubs
    n_patch_tokens: int = 0     # vlm: precomputed vision-patch embeddings
    n_audio_frames: int = 0     # audio: precomputed encoder frame embeddings
    max_seq_len: int = 8_192
    dtype: str = "bfloat16"
    citation: str = ""
    # families that have no decode step / no sub-quadratic long path
    supports_long_context: bool = False

    # -- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def num_params(self) -> int:
        """Analytic parameter count (matches models.build exactly —
        asserted by tests/test_param_count.py)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        total = emb + head + d  # final norm

        def attn_params(dm, nq, nkv, h, bias):
            p = dm * nq * h + 2 * dm * nkv * h + nq * h * dm
            if bias:
                p += (nq + 2 * nkv) * h
            return p

        def mlp_params(dm, ff):
            return 3 * dm * ff  # SwiGLU: gate, up, down

        if self.family == "ssm" and self.xlstm is not None:
            # xLSTM blocks (see models/xlstm.py for the exact shapes)
            x = self.xlstm
            per_m = self._mlstm_params()
            per_s = self._slstm_params()
            n_s = (
                self.n_layers // x.slstm_every if x.slstm_every else 0
            )
            n_m = self.n_layers - n_s
            total += n_m * per_m + n_s * per_s
            return total

        if self.family in ("ssm", "hybrid") and self.ssm is not None:
            per_ssm = self._mamba2_params()
            if self.family == "hybrid" and self.hybrid_shared_every:
                n_shared_applications = self.n_layers // self.hybrid_shared_every
                shared = (
                    2 * self.d_model  # norms
                    + attn_params(d, n_q, n_kv, hd, False)
                    + mlp_params(d, self.d_ff)
                )
                total += self.n_layers * (per_ssm + self.d_model) + shared
                del n_shared_applications
            else:
                total += self.n_layers * (per_ssm + self.d_model)
            return total

        # transformer-family layers
        per_layer = 2 * d  # two RMSNorms
        per_layer += attn_params(d, n_q, n_kv, hd, self.qkv_bias)
        if self.moe is not None:
            m = self.moe
            expert = mlp_params(d, self.d_ff)
            per_layer += d * m.n_experts            # router
            per_layer += (m.n_experts + m.n_shared) * expert
        else:
            per_layer += mlp_params(d, self.d_ff)
        total += self.n_layers * per_layer

        if self.n_encoder_layers:
            # whisper encoder: self-attn + MLP; decoder adds cross-attn
            enc_layer = 2 * d + attn_params(d, n_q, n_q, hd, False) + mlp_params(d, self.d_ff)
            total += self.n_encoder_layers * enc_layer + d
            total += self.n_layers * (d + attn_params(d, n_q, n_kv, hd, False))  # cross-attn + norm
        return total

    def _mamba2_params(self) -> int:
        # mirrors models.layers.mamba2.Mamba2Params exactly
        s = self.ssm
        d_inner = s.expand * self.d_model
        n_heads = s.n_ssm_heads or (d_inner // s.head_dim)
        p = self.d_model * (2 * d_inner + 2 * s.state_dim + n_heads)  # w_in
        p += s.conv_width * (d_inner + 2 * s.state_dim)               # conv_w
        p += n_heads * 3                                              # dt_bias, a_log, d_skip
        p += d_inner                                                  # gated norm
        p += d_inner * self.d_model                                   # w_out
        return p

    def _mlstm_params(self) -> int:
        # mirrors models.layers.xlstm_layers.MLSTMParams (+ block norm)
        x = self.xlstm
        d = self.d_model
        d_inner = 2 * d
        d_qk = int(d_inner * x.mlstm_qk_dim_factor)
        d_v = int(d_inner * x.mlstm_v_dim_factor)
        nh = self.n_heads
        p = d                        # block-level RMSNorm
        p += 2 * d * d_inner         # w_up, w_z
        p += 4 * d_inner             # conv_w
        p += 2 * d_inner * d_qk      # w_q, w_k
        p += d_inner * d_v           # w_v
        p += d_inner * 2 * nh + 2 * nh  # w_if, b_if
        p += d_v                     # group norm
        p += d_v * d                 # w_out
        return p

    def _slstm_params(self) -> int:
        # mirrors models.layers.xlstm_layers.SLSTMParams (+ block norm)
        x = self.xlstm
        d = self.d_model
        nh = self.n_heads
        hd = d // nh
        p = d                   # block-level RMSNorm
        p += 4 * d * d          # w_in (i,f,z,o)
        p += 4 * nh * hd * hd   # block-diag recurrent kernels
        p += 4 * d              # biases
        p += d                  # group norm
        up = int(d * x.proj_factor)
        p += d * up * 2 + up * d  # gated ffn
        return p

    def model_flops_per_token(self) -> float:
        """6 * N_active for training; used in §Roofline MODEL_FLOPS."""
        n = self.num_active_params()
        return 6.0 * n

    def num_active_params(self) -> int:
        if self.moe is None:
            return self.num_params()
        # replace per-layer expert count by (top_k + shared)
        m = self.moe
        expert = 3 * self.d_model * self.d_ff
        dense_equiv = self.num_params() - self.n_layers * (m.n_experts + m.n_shared) * expert
        return dense_equiv + self.n_layers * (m.top_k + m.n_shared) * expert

    def update_bytes(self) -> int:
        """Size of one client model update — the paper's w_s."""
        return self.num_params() * jnp.dtype(self.dtype).itemsize

    # -- smoke-test reduction -------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family, tiny dims: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        hd = 32
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(n_heads, self.n_kv_heads if self.n_kv_heads else n_heads))
        if self.n_kv_heads == self.n_heads:
            n_kv = n_heads
        kw: Dict = dict(
            arch_id=self.arch_id + "-smoke",
            family=self.family,
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 1024),
            head_dim=hd,
            qkv_bias=self.qkv_bias,
            tie_embeddings=self.tie_embeddings,
            rope_theta=self.rope_theta,
            moe=None,
            ssm=None,
            xlstm=None,
            attn=self.attn if self.attn.sliding_window == 0 else AttnPattern(
                sliding_window=16, local_to_global=self.attn.local_to_global
            ),
            hybrid_shared_every=0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            n_patch_tokens=8 if self.n_patch_tokens else 0,
            n_audio_frames=16 if self.n_audio_frames else 0,
            max_seq_len=128,
            dtype="float32",
            citation=self.citation,
            supports_long_context=self.supports_long_context,
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                n_experts=4,
                top_k=min(2, self.moe.top_k),
                n_shared=min(1, self.moe.n_shared),
                capacity_factor=self.moe.capacity_factor,
            )
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(
                state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16
            )
        if self.xlstm is not None:
            kw["xlstm"] = XLSTMConfig(
                slstm_every=2,
                mlstm_qk_dim_factor=0.5,
                mlstm_v_dim_factor=1.0,
                chunk=16,
            )
        if self.family == "hybrid":
            kw["hybrid_shared_every"] = 1
        return ModelConfig(**kw)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct inputs for (cfg, shape) — no device allocation.

    train  -> {tokens, labels[, patch_embeds | audio_frames]}
    prefill-> {tokens[, ...modality]}
    decode -> {token, cache_*} handled by models.cache.cache_specs
    """
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, T), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
    else:  # decode: one new token against a cache of seq_len
        specs["token"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.family == "vlm":
        n = cfg.n_patch_tokens
        if shape.kind != "decode":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, n, cfg.d_model), cfg.param_dtype
            )
    if cfg.family == "audio":
        n = cfg.n_audio_frames
        specs["audio_frames"] = jax.ShapeDtypeStruct(
            (B, n, cfg.d_model), cfg.param_dtype
        )
    return specs
