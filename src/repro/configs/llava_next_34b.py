"""LLaVA-NeXT-34B backbone — anyres tiling VLM
[hf:llava-hf/llava-v1.6-mistral-7b-hf family, 34B variant].

VLM: the language backbone only (60L, d_model=7168, 56H GQA kv=8,
d_ff=20480, vocab=64000). The SigLIP/ViT tower + projector are STUBBED per
the task carve-out: input_specs() supplies precomputed patch embeddings of
shape (B, n_patch_tokens, d_model); anyres tiling yields up to 2880 patch
tokens (5 tiles x 576).
"""
from repro.configs.base import AttnPattern, ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    qkv_bias=False,
    tie_embeddings=False,
    rope_theta=5_000_000.0,
    attn=AttnPattern(),
    # anyres: 5 tiles x 512 post-pool patch tokens; 2560 keeps the combined
    # (patches + text) sequence divisible by the 512-token attention tiles
    n_patch_tokens=2560,
    max_seq_len=32_768,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf (LLaVA-NeXT anyres)",
    supports_long_context=False,
)
