"""DBRX-132B — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base].

40L, d_model=6144, 48H GQA kv=8, per-expert d_ff=10752, vocab=100352.
"""
from repro.configs.base import AttnPattern, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    head_dim=128,
    qkv_bias=False,
    tie_embeddings=False,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, n_shared=0, capacity_factor=1.25),
    attn=AttnPattern(),
    max_seq_len=32_768,
    citation="hf:databricks/dbrx-base (16-expert top-4 fine-grained MoE)",
    supports_long_context=False,
)
