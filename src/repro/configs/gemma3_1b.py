"""Gemma3-1B — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt].

26L, d_model=1152, 4H GQA kv=1, d_ff=6912, vocab=262144. Five consecutive
sliding-window (1024) layers per one global layer. For the long_500k decode
shape the global layers also run windowed (documented deviation in
DESIGN.md) which makes the architecture fully sub-quadratic.
"""
from repro.configs.base import AttnPattern, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    qkv_bias=False,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    attn=AttnPattern(sliding_window=1024, local_to_global=5),
    max_seq_len=131_072,
    citation="hf:google/gemma-3-1b-pt (Gemma 3 model card)",
    supports_long_context=True,  # sliding-window KV cache bounds memory
)
