"""Paper Table I: the CNN model-update suite used in every aggregation
benchmark (CNN4.6 ... CNN956, ResNet50, VGG16).

The aggregation service never runs these models — it fuses their *parameter
pytrees* (exactly as IBMFL fuses lists of ndarrays). So each entry here is a
pytree SPEC whose fp32 byte size matches the paper's Table I, with
conv/dense-shaped leaves so the pytree structure is realistic (many small
tensors + a few big ones), which stresses the flatten/partition path the
same way the paper's pickled keras weights stress Spark's binaryFiles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class UpdateSpec:
    """A federated model-update workload (the paper's w_s)."""

    name: str
    target_mb: float
    leaves: Tuple[Tuple[str, Tuple[int, ...]], ...]

    def shape_dtype(self, dtype=np.float32) -> Dict[str, jax.ShapeDtypeStruct]:
        return {n: jax.ShapeDtypeStruct(s, dtype) for n, s in self.leaves}

    @property
    def num_params(self) -> int:
        return int(sum(np.prod(s) for _, s in self.leaves))

    @property
    def bytes_fp32(self) -> int:
        return self.num_params * 4


def _cnn_spec(name: str, target_mb: float, convs: List[int], dense: List[int],
              in_ch: int = 3, img: int = 32, classes: int = 10) -> UpdateSpec:
    """Build conv+dense leaf shapes, then pad with a trailing blob so the
    fp32 total matches the paper's reported MB (decimal MB, as sizes of
    pickled weight files are reported)."""
    leaves: List[Tuple[str, Tuple[int, ...]]] = []
    ch = in_ch
    spatial = img
    for i, c in enumerate(convs):
        leaves.append((f"conv{i}/w", (3, 3, ch, c)))
        leaves.append((f"conv{i}/b", (c,)))
        ch = c
        if i % 2 == 1 and spatial > 4:
            spatial //= 2
    flat = ch * max(spatial // 2, 1) ** 2
    prev = flat
    for i, d in enumerate(dense):
        leaves.append((f"dense{i}/w", (prev, d)))
        leaves.append((f"dense{i}/b", (d,)))
        prev = d
    leaves.append(("head/w", (prev, classes)))
    leaves.append(("head/b", (classes,)))
    target_params = int(target_mb * 1e6 / 4)

    def total() -> int:
        return int(sum(np.prod(s) for _, s in leaves))

    # Shrink the largest leaves row-by-row until we are at or under target,
    # then pad with a trailing blob to hit the byte count exactly.
    while total() > target_params:
        over = total() - target_params
        idx = max(range(len(leaves)), key=lambda i: np.prod(leaves[i][1]))
        nm, shape = leaves[idx]
        row = int(np.prod(shape[1:])) or 1
        drop_rows = min(shape[0] - 1, max(1, over // row))
        if shape[0] <= 1 or drop_rows < 1:
            leaves.pop(idx)
            continue
        leaves[idx] = (nm, (shape[0] - drop_rows,) + shape[1:])
        if shape[0] - drop_rows == shape[0]:  # no progress
            leaves.pop(idx)
    pad = target_params - total()
    if pad > 0:
        leaves.append(("pad/blob", (pad,)))
    return UpdateSpec(name=name, target_mb=target_mb, leaves=tuple(leaves))


# Table I of the paper. Conv widths are the paper's; dense layer is 128-wide.
CNN_SUITE: Dict[str, UpdateSpec] = {
    "CNN4.6": _cnn_spec("CNN4.6", 4.6, [32, 64], [128]),
    "CNN73": _cnn_spec("CNN73", 73.0, [32, 256, 512, 1024], [128]),
    "CNN179": _cnn_spec("CNN179", 179.0, [32, 512, 1024, 1900], [128]),
    "CNN239": _cnn_spec("CNN239", 239.0, [32, 1024, 1900, 2400], [128]),
    "CNN478": _cnn_spec("CNN478", 478.0, [32, 32, 1024, 1024, 1900, 1900, 2400, 2400], [128, 128]),
    "CNN717": _cnn_spec("CNN717", 717.0, [32] * 3 + [1024] * 3 + [1900] * 3 + [2400] * 3, [128] * 3),
    "CNN956": _cnn_spec("CNN956", 956.0, [32, 32, 1024, 1024, 1900, 1900, 2400, 2400], [128] * 4),
    "Resnet50": _cnn_spec("Resnet50", 91.0, [64, 256, 512, 1024, 2048], [1000]),
    "VGG16": _cnn_spec("VGG16", 528.0, [64, 64, 128, 128, 256, 256, 256, 512, 512, 512, 512, 512, 512], [4096, 4096], classes=1000),
}
