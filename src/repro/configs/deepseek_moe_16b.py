"""DeepSeekMoE-16B — fine-grained experts, 2 shared + 64 routed top-6
[arXiv:2401.06066].

28L, d_model=2048, 16H MHA (kv=16), per-expert d_ff=1408, vocab=102400.
"""
from repro.configs.base import AttnPattern, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    qkv_bias=False,
    tie_embeddings=False,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, capacity_factor=1.25),
    attn=AttnPattern(),
    max_seq_len=16_384,
    citation="arXiv:2401.06066 (DeepSeekMoE: fine-grained expert specialization)",
    supports_long_context=False,
)
