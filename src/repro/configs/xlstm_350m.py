"""xLSTM-350M — sLSTM + mLSTM blocks [arXiv:2405.04517].

24 blocks, d_model=1024, 4 heads, vocab=50304. d_ff=0: xLSTM blocks carry
their own up-projections (mLSTM pre-up-projection, sLSTM gated FFN), so
there is no separate transformer MLP. We use the paper's xLSTM[7:1]
block ratio: every 8th block is an sLSTM block, the rest are mLSTM.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=256,
    tie_embeddings=True,
    xlstm=XLSTMConfig(
        slstm_every=8,
        mlstm_qk_dim_factor=0.5,
        mlstm_v_dim_factor=1.0,
        proj_factor=1.3334,
        chunk=256,
    ),
    max_seq_len=1_048_576,
    citation="arXiv:2405.04517 (xLSTM: Extended LSTM)",
    supports_long_context=True,  # recurrent state: O(1) in context length
)
