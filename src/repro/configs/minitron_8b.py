"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679].

Dense decoder, 32L, d_model=4096, 32 query heads with GQA (8 KV heads),
d_ff=16384, vocab=256000.
"""
from repro.configs.base import AttnPattern, ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    head_dim=128,
    qkv_bias=False,
    tie_embeddings=False,
    rope_theta=10_000.0,
    attn=AttnPattern(),
    max_seq_len=32_768,
    citation="arXiv:2407.14679 (Minitron: compact LMs via pruning+distillation)",
    supports_long_context=False,  # full attention; long_500k skipped (DESIGN.md)
)
