"""Qwen2-0.5B — GQA with QKV bias [arXiv:2407.10671].

24L, d_model=896, 14H GQA kv=2, d_ff=4864, vocab=151936.
"""
from repro.configs.base import AttnPattern, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    attn=AttnPattern(),
    max_seq_len=32_768,
    citation="arXiv:2407.10671 (Qwen2 technical report)",
    supports_long_context=False,
)
