"""Qwen2.5-3B — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family].

36L, d_model=2048, 16H GQA kv=2, d_ff=11008, vocab=151936.
"""
from repro.configs.base import AttnPattern, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    attn=AttnPattern(),
    max_seq_len=32_768,
    citation="hf:Qwen/Qwen2.5-0.5B (Qwen2.5 series model card)",
    supports_long_context=False,
)
