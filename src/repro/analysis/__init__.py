"""repro.analysis — static concurrency/trace lint + runtime lock witness.

Two halves:

* **Static** (``repro.analysis.lint``, also ``python -m
  repro.analysis.lint``): an AST pass over the source tree enforcing
  the locking and tracing invariants PRs 5-9 established by hand —
  guarded attributes touched only under their lock, no blocking I/O
  while a lock is held, no host-varying values in compile-cache keys
  or traced closures, no device syncs inside ``device_sem`` regions,
  every worker thread joined.  Rules are pluggable (`Rule`), findings
  carry file/line, and deliberate exceptions are annotated in-source
  with ``# lint: disable=<rule> -- <reason>``.

* **Runtime** (``repro.analysis.witness``): an opt-in instrumented
  lock wrapper that records the cross-thread lock acquisition graph
  while the concurrency suites run, failing on cycles or on orderings
  that contradict the declared partial order
  (``state lock ≺ store lock ≺ per-tenant round lock``).

See docs/ANALYSIS.md for the rule catalog and annotation conventions.
"""
from repro.analysis.core import (  # noqa: F401
    Finding,
    LintResult,
    Rule,
    Suppression,
    lint_paths,
)
from repro.analysis.witness import (  # noqa: F401
    LockOrderWitness,
    LockOrderViolation,
    instrument_service,
)

__all__ = [
    "Finding",
    "LintResult",
    "Rule",
    "Suppression",
    "lint_paths",
    "LockOrderWitness",
    "LockOrderViolation",
    "instrument_service",
]
