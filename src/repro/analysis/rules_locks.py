"""Lock-discipline rules.

Convention (see docs/ANALYSIS.md):

* An attribute is declared *guarded* by putting ``# guarded-by: <lock>``
  on the line that assigns it in the class body (normally ``__init__``)::

      self._mem: dict = {}          # guarded-by: _lock

* A lock is any ``self.X = threading.Lock()/RLock()/Condition(...)``
  assignment.  ``threading.Condition(self.Y)`` aliases ``Y`` — entering
  the condition *is* holding ``Y`` (the store's ``_arrival_cv`` idiom).

* A method that is only ever called with a lock already held declares
  so either with a ``# lint: holds=<lock>`` comment on its ``def`` line
  or a docstring containing ``Caller holds ``self.<lock>```` (the
  existing ``*_locked`` helper idiom).

`GuardedAccessRule` then checks every ``self.<attr>`` touch of a
guarded attribute happens inside ``with self.<lock>`` (or an alias, or
a holds-declaring method).  `BlockingUnderLockRule` forbids blocking
calls (``open``/``np.load``/``np.save``/``os.replace``/``socket.*``/
``time.sleep``) while *any* declared lock is held —
``Condition.wait`` is exempt because it releases the lock.

``__init__`` bodies are exempt from the guarded check: the object is
not yet shared.  Nested functions reset the held set — their bodies
run later, on some other thread's schedule.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis.core import (
    FileContext,
    Finding,
    GUARDED_RE,
    HOLDS_COMMENT_RE,
    Rule,
)

DOCSTRING_HOLDS_RE = re.compile(
    r"[Cc]allers?\s+(?:must\s+)?holds?\s+"
    r"`{0,2}self\.([A-Za-z_][A-Za-z0-9_]*)`{0,2}"
)

LOCK_FACTORIES = {"Lock", "RLock", "Condition"}

#: Dotted-name prefixes considered blocking while a lock is held.
BLOCKING_CALLS = (
    "open",
    "time.sleep",
    "os.replace",
    "np.load",
    "np.save",
    "numpy.load",
    "numpy.save",
    "socket.",
    "shutil.",
    "subprocess.",
)


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains (``self`` kept), else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def self_attr(node: ast.AST) -> Optional[str]:
    """``X`` when node is exactly ``self.X``."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


class ClassLockInfo:
    """Lock inventory + guarded-attribute map for one class."""

    def __init__(self, node: ast.ClassDef, ctx: FileContext):
        self.node = node
        #: lock attr -> canonical lock attr (Condition aliases resolve)
        self.locks: Dict[str, str] = {}
        #: guarded attr -> canonical lock attr
        self.guarded: Dict[str, str] = {}
        self.annotation_errors: List[Tuple[int, str]] = []
        raw_cond_alias: Dict[str, str] = {}
        for meth in node.body:
            if not isinstance(meth, ast.FunctionDef):
                continue
            for sub in ast.walk(meth):
                if not isinstance(sub, (ast.Assign, ast.AnnAssign)):
                    continue
                targets = (
                    sub.targets if isinstance(sub, ast.Assign) else [sub.target]
                )
                value = sub.value
                if value is None or len(targets) != 1:
                    continue
                attr = self_attr(targets[0])
                if attr is None or not isinstance(value, ast.Call):
                    continue
                fn = dotted_name(value.func) or ""
                base = fn.rsplit(".", 1)[-1]
                if fn.startswith("threading.") and base in LOCK_FACTORIES:
                    if base == "Condition" and value.args:
                        inner = self_attr(value.args[0])
                        if inner is not None:
                            raw_cond_alias[attr] = inner
                            continue
                    self.locks[attr] = attr
        for cv, inner in raw_cond_alias.items():
            self.locks[cv] = self.locks.get(inner, inner)
        # guarded-by comments anywhere in the class span
        end = getattr(node, "end_lineno", node.lineno)
        for line in range(node.lineno, end + 1):
            m = GUARDED_RE.search(ctx.comment_on(line))
            if m is None:
                continue
            lock = m.group(1)
            src = ctx.lines[line - 1] if line - 1 < len(ctx.lines) else ""
            am = re.search(r"self\.([A-Za-z_][A-Za-z0-9_]*)\s*[:=]", src)
            if am is None:
                self.annotation_errors.append(
                    (line, f"guarded-by comment with no 'self.<attr> =' "
                           f"assignment on the line")
                )
                continue
            if lock not in self.locks:
                self.annotation_errors.append(
                    (line, f"guarded-by names {lock!r} which is not a "
                           f"threading.Lock/RLock/Condition attribute of "
                           f"this class")
                )
                continue
            self.guarded[am.group(1)] = self.locks[lock]

    def assumed_held(self, meth: ast.FunctionDef, ctx: FileContext) -> Set[str]:
        """Locks a method declares its caller already holds."""
        held: Set[str] = set()
        m = HOLDS_COMMENT_RE.search(ctx.comment_on(meth.lineno))
        if m:
            for name in m.group(1).split(","):
                held.add(self.locks.get(name, name))
        doc = ast.get_docstring(meth) or ""
        for dm in DOCSTRING_HOLDS_RE.finditer(doc):
            held.add(self.locks.get(dm.group(1), dm.group(1)))
        return held


def collect_classes(ctx: FileContext) -> List[Tuple[ast.ClassDef, ClassLockInfo]]:
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ClassDef):
            info = ClassLockInfo(node, ctx)
            if info.locks or info.guarded:
                out.append((node, info))
    return out


class _HeldWalker:
    """Walks a method body tracking which declared locks are held."""

    def __init__(self, info: ClassLockInfo, on_node):
        self.info = info
        self.on_node = on_node  # callback(node, held_frozenset)

    def walk(self, node: ast.AST, held: FrozenSet[str]) -> None:
        if isinstance(node, ast.With):
            acquired = set()
            for item in node.items:
                attr = self_attr(item.context_expr)
                if attr is not None and attr in self.info.locks:
                    acquired.add(self.info.locks[attr])
                self.walk(item.context_expr, held)
            inner = held | frozenset(acquired)
            for stmt in node.body:
                self.walk(stmt, inner)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            # A nested function's body executes later (worker threads,
            # callbacks): it does not inherit the lexical held set.
            body = node.body if not isinstance(node, ast.Lambda) else [node.body]
            for stmt in body if isinstance(body, list) else [body]:
                self.walk(stmt, frozenset())
            return
        self.on_node(node, held)
        for child in ast.iter_child_nodes(node):
            self.walk(child, held)


class GuardedAccessRule(Rule):
    name = "guarded-access"
    description = (
        "attributes declared '# guarded-by: <lock>' may only be touched "
        "inside 'with self.<lock>' (or a method declaring the caller "
        "holds it)"
    )

    def check(self, ctx: FileContext) -> List[Finding]:
        findings: List[Finding] = []
        for node, info in collect_classes(ctx):
            for line, msg in info.annotation_errors:
                findings.append(self.finding(ctx, line, msg))
            if not info.guarded:
                continue
            for meth in node.body:
                if not isinstance(meth, ast.FunctionDef):
                    continue
                if meth.name in ("__init__", "__del__"):
                    continue
                held0 = frozenset(info.assumed_held(meth, ctx))

                def visit(sub: ast.AST, held: FrozenSet[str]) -> None:
                    attr = self_attr(sub)
                    if attr is None:
                        return
                    lock = info.guarded.get(attr)
                    if lock is not None and lock not in held:
                        findings.append(self.finding(
                            ctx, sub.lineno,
                            f"self.{attr} is guarded by self.{lock} but "
                            f"accessed without holding it "
                            f"(in {node.name}.{meth.name})",
                        ))

                walker = _HeldWalker(info, visit)
                for stmt in meth.body:
                    walker.walk(stmt, held0)
        return findings


class BlockingUnderLockRule(Rule):
    name = "blocking-under-lock"
    description = (
        "blocking calls (open/np.load/np.save/os.replace/socket.*/"
        "time.sleep/...) are forbidden while a declared lock is held"
    )

    def check(self, ctx: FileContext) -> List[Finding]:
        findings: List[Finding] = []
        for node, info in collect_classes(ctx):
            for meth in node.body:
                if not isinstance(meth, ast.FunctionDef):
                    continue
                held0 = frozenset(info.assumed_held(meth, ctx))

                def visit(sub: ast.AST, held: FrozenSet[str]) -> None:
                    if not held or not isinstance(sub, ast.Call):
                        return
                    fn = dotted_name(sub.func)
                    if fn is None:
                        return
                    # Condition.wait releases the lock while blocking.
                    if fn.endswith(".wait") or fn.endswith(".wait_for"):
                        return
                    for pat in BLOCKING_CALLS:
                        if fn == pat or (pat.endswith(".") and
                                         fn.startswith(pat)):
                            findings.append(self.finding(
                                ctx, sub.lineno,
                                f"blocking call {fn}() while holding "
                                f"{{{', '.join(sorted(held))}}} "
                                f"(in {node.name}.{meth.name})",
                            ))
                            return

                walker = _HeldWalker(info, visit)
                for stmt in meth.body:
                    walker.walk(stmt, held0)
        return findings
