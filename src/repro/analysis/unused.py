"""Unused-symbol helper: flags imports never referenced in their module.

Conservative by design — the goal is dead-code *sweeps*, not style
enforcement:

* ``__init__.py`` files are exempt (imports there are re-exports);
* ``from __future__ import ...`` is exempt (used implicitly);
* a name listed in a string inside ``__all__`` counts as used;
* usage is any ``Name`` reference in the AST, which includes
  annotations even under ``from __future__ import annotations``.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Set, Tuple

from repro.analysis.core import FileContext, Finding, Rule


class UnusedImportRule(Rule):
    name = "unused-import"
    description = (
        "imported name never referenced in the module (init files and "
        "__future__ imports exempt)"
    )

    def check(self, ctx: FileContext) -> List[Finding]:
        if os.path.basename(ctx.path) == "__init__.py":
            return []
        imported: Dict[str, Tuple[int, str]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = (alias.asname or alias.name).split(".")[0]
                    imported[bound] = (node.lineno, alias.name)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    bound = alias.asname or alias.name
                    imported[bound] = (node.lineno, alias.name)
        if not imported:
            return []
        used: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Name):
                used.add(node.id)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == "__all__":
                        for sub in ast.walk(node.value):
                            if isinstance(sub, ast.Constant) and \
                                    isinstance(sub.value, str):
                                used.add(sub.value)
        findings: List[Finding] = []
        for bound, (line, original) in sorted(
            imported.items(), key=lambda kv: kv[1][0]
        ):
            if bound in used:
                continue
            # An `import a.b` statement also binds `a`; if any sibling
            # import bound the same root and that root is used, skip.
            findings.append(self.finding(
                ctx, line,
                f"imported name {bound!r} is never used",
            ))
        return findings
