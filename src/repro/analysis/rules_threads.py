"""Thread-hygiene rules.

`ThreadJoinRule`: every ``threading.Thread(...)`` creation must have a
matching ``.join(`` on its binding somewhere in the same file —
non-daemon threads because they block interpreter exit, daemon workers
because an unjoined worker leaks into the next round/test (the repo
convention is daemon **and** joined on the shutdown path).  Handles
the three binding shapes the codebase uses: ``x = Thread(...)``,
``self._t = Thread(...)``, and ``pool.append(Thread(...))`` (the last
is satisfied by any ``.join(`` in the enclosing function).  A thread
deliberately handed to the caller (e.g. ``start_writer`` returning the
handle) carries a suppression.

`BareAcquireRule`: direct ``<lock>.acquire()`` calls on anything that
looks like a lock (name contains ``lock`` or ``mutex``).  ``with``
blocks guarantee release on every exit path; a bare acquire must be
annotated with why the try/finally shape is impossible.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from repro.analysis.core import FileContext, Finding, Rule
from repro.analysis.rules_locks import dotted_name

LOCKISH_RE = re.compile(r"lock|mutex|cv|cond", re.IGNORECASE)


def _is_thread_ctor(call: ast.Call) -> bool:
    fn = dotted_name(call.func)
    return fn in ("threading.Thread", "Thread")


def _daemon_kwarg(call: ast.Call) -> Optional[bool]:
    for kw in call.keywords:
        if kw.arg == "daemon" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return None


class ThreadJoinRule(Rule):
    name = "thread-join"
    description = (
        "every threading.Thread created must be join()ed in the same "
        "file (or carry a suppression explaining who joins it)"
    )

    def check(self, ctx: FileContext) -> List[Finding]:
        findings: List[Finding] = []
        src = ctx.source

        def joined(binding: str) -> bool:
            # `self._t` matches `._t.join(`; `t` matches `t.join(`
            if binding.startswith("self."):
                return f".{binding[5:]}.join(" in src
            return bool(re.search(
                rf"\b{re.escape(binding)}\.join\(", src
            ))

        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Module)):
                continue
            body_src = None
            for stmt in ast.walk(node):
                if not (isinstance(stmt, ast.Call) and _is_thread_ctor(stmt)):
                    continue
                # find the statement binding this ctor call
                binding = self._binding_for(node, stmt)
                daemon = _daemon_kwarg(stmt)
                if binding == "__append__":
                    if body_src is None:
                        seg = ast.get_source_segment(src, node)
                        body_src = seg if seg is not None else src
                    if ".join(" in body_src:
                        continue
                elif binding is not None and joined(binding):
                    continue
                elif binding is None and isinstance(node, ast.Module):
                    # ctor nested in some non-function context; be lenient
                    continue
                kind = "daemon" if daemon else "non-daemon"
                findings.append(self.finding(
                    ctx, stmt.lineno,
                    f"{kind} thread created here is never join()ed in "
                    f"this file",
                ))
            break  # only walk from Module once; inner defs seen via walk
        return findings

    def _binding_for(self, root: ast.AST, ctor: ast.Call) -> Optional[str]:
        for stmt in ast.walk(root):
            if isinstance(stmt, ast.Assign) and stmt.value is ctor and \
                    len(stmt.targets) == 1:
                return dotted_name(stmt.targets[0])
            if isinstance(stmt, ast.Call) and ctor in stmt.args and \
                    isinstance(stmt.func, ast.Attribute) and \
                    stmt.func.attr == "append":
                return "__append__"
        return None


class BareAcquireRule(Rule):
    name = "bare-acquire"
    description = (
        "lock.acquire() outside a 'with' block risks a missed release "
        "on an exception path; use 'with lock' or annotate why not"
    )

    def check(self, ctx: FileContext) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not (isinstance(func, ast.Attribute) and
                    func.attr == "acquire"):
                continue
            recv = dotted_name(func.value) or ""
            if LOCKISH_RE.search(recv):
                findings.append(self.finding(
                    ctx, node.lineno,
                    f"bare {recv}.acquire() — prefer 'with {recv}:'",
                ))
        return findings
