"""CLI for the static pass: ``python -m repro.analysis.lint [paths]``.

Exit status is 0 when no findings (or, with ``--baseline``, no *new*
findings vs the recorded baseline), 1 otherwise.

    python -m repro.analysis.lint src/repro
    python -m repro.analysis.lint src/repro --format=json
    python -m repro.analysis.lint src/repro --write-baseline lint.json
    python -m repro.analysis.lint src/repro --baseline lint.json
    python -m repro.analysis.lint --list-rules
    python -m repro.analysis.lint src/repro --show-suppressed

Baselines match findings by (rule, path, message) — line-insensitive,
so unrelated edits moving code around do not resurrect old findings.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

from repro.analysis.core import Finding, default_rules, lint_paths


def _load_baseline(path: str) -> set:
    with open(path, "r", encoding="utf-8") as fh:
        payload = json.load(fh)
    return {
        (f["rule"], f["path"], f["message"])
        for f in payload.get("findings", [])
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro static concurrency/trace lint",
    )
    parser.add_argument("paths", nargs="*", default=["src/repro"],
                        help="files or directories (default: src/repro)")
    parser.add_argument("--format", choices=("text", "json"), default="text")
    parser.add_argument("--rules", default=None,
                        help="comma-separated subset of rule names to run")
    parser.add_argument("--baseline", default=None, metavar="FILE",
                        help="fail only on findings not in this baseline")
    parser.add_argument("--write-baseline", default=None, metavar="FILE",
                        help="write current findings as a baseline and exit 0")
    parser.add_argument("--list-rules", action="store_true")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="also print the suppression register")
    args = parser.parse_args(argv)

    all_rules = default_rules()
    if args.list_rules:
        for r in all_rules:
            print(f"{r.name:20s} {r.description}")
        return 0
    rules = all_rules
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",")}
        unknown = wanted - {r.name for r in all_rules}
        if unknown:
            parser.error(f"unknown rule(s): {', '.join(sorted(unknown))}")
        rules = [r for r in all_rules if r.name in wanted]

    result = lint_paths(list(args.paths), rules)
    findings: List[Finding] = result.findings
    new = findings
    if args.baseline:
        base = _load_baseline(args.baseline)
        new = [f for f in findings if f.fingerprint() not in base]

    if args.write_baseline:
        with open(args.write_baseline, "w", encoding="utf-8") as fh:
            json.dump(
                {"findings": [f.as_dict() for f in findings]}, fh, indent=2
            )
        print(f"baseline: {len(findings)} finding(s) -> "
              f"{args.write_baseline}")
        return 0

    if args.format == "json":
        payload = {
            "files": result.files,
            "findings": [f.as_dict() for f in new],
            "suppressed": [
                {**f.as_dict(), "reason": s.reason}
                for f, s in result.suppressed
            ],
        }
        if args.baseline:
            payload["baselined"] = len(findings) - len(new)
        print(json.dumps(payload, indent=2))
    else:
        for f in new:
            print(f)
        if args.show_suppressed:
            print(f"-- suppressed ({len(result.suppressed)}):")
            for f, s in result.suppressed:
                print(f"  {f}  [reason: {s.reason}]")
        tail = f"{result.files} file(s), {len(new)} finding(s)"
        if args.baseline:
            tail += f" ({len(findings) - len(new)} baselined)"
        print(tail)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
