"""Core lint infrastructure: findings, suppressions, rule registry.

A `Rule` inspects one parsed file (`FileContext`) and yields
`Finding`s.  The engine (`lint_paths`) then filters findings through
inline suppression comments::

    # lint: disable=<rule>[,<rule>...] -- <reason>

A suppression applies to the physical line it sits on; placed on a
``def`` line it applies to the whole function body.  A suppression
without a reason (or naming an unknown rule) is itself a finding
(rule ``suppression-format``) so every waived site stays enumerable
and explained — ``python -m repro.analysis.lint --show-suppressed``
prints the register.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)"
    r"(?:\s*--\s*(\S.*?))?\s*$"
)
# Any comment that *mentions* the lint-disable marker, used to catch
# malformed variants the strict regex above would silently skip.
SUPPRESS_LOOSE_RE = re.compile(r"#\s*lint:\s*disable")
GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
HOLDS_COMMENT_RE = re.compile(r"#\s*lint:\s*holds=([A-Za-z_][A-Za-z0-9_,]*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a file/line."""

    rule: str
    path: str
    line: int
    message: str

    def fingerprint(self) -> Tuple[str, str, str]:
        """Line-insensitive identity used by ``--baseline`` matching."""
        return (self.rule, self.path, self.message)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:  # text reporter row
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    """A parsed ``# lint: disable=`` comment."""

    path: str
    line: int
    rules: Tuple[str, ...]
    reason: Optional[str]


class FileContext:
    """One parsed source file plus its comment annotations."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        #: line -> raw comment text (from the tokenizer, so ``#`` inside
        #: string literals never false-matches)
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(source).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:  # pragma: no cover - ast.parse passed
            pass
        self.suppressions: List[Suppression] = []
        self.malformed_suppressions: List[int] = []
        for line, text in self.comments.items():
            if not SUPPRESS_LOOSE_RE.search(text):
                continue
            m = SUPPRESS_RE.search(text)
            if m is None:
                self.malformed_suppressions.append(line)
                continue
            rules = tuple(r.strip() for r in m.group(1).split(","))
            self.suppressions.append(
                Suppression(path, line, rules, m.group(2))
            )
        #: (start, end, def_line) spans of every function, innermost last
        self.func_spans: List[Tuple[int, int, int]] = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                end = getattr(node, "end_lineno", node.lineno)
                self.func_spans.append((node.lineno, end, node.lineno))
        self.func_spans.sort()

    def comment_on(self, line: int) -> str:
        return self.comments.get(line, "")

    def suppressors_for(self, line: int) -> Iterable[Suppression]:
        """Suppressions covering ``line``: same-line ones plus any on the
        ``def`` line of an enclosing function."""
        def_lines = {line}
        for start, end, def_line in self.func_spans:
            if start <= line <= end:
                def_lines.add(def_line)
        for sup in self.suppressions:
            if sup.line in def_lines:
                yield sup


class Rule:
    """Base class: subclasses set ``name``/``description`` and implement
    ``check(ctx) -> list[Finding]``."""

    name: str = ""
    description: str = ""

    def check(self, ctx: FileContext) -> List[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, line: int, message: str) -> Finding:
        return Finding(self.name, ctx.path, line, message)


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    suppressed: List[Tuple[Finding, Suppression]]
    files: int

    @property
    def ok(self) -> bool:
        return not self.findings


def default_rules() -> List[Rule]:
    """All shipped rules (import here to avoid a cycle at module load)."""
    from repro.analysis.rules_locks import GuardedAccessRule, BlockingUnderLockRule
    from repro.analysis.rules_trace import TraceHazardRule, SyncUnderSemRule
    from repro.analysis.rules_threads import ThreadJoinRule, BareAcquireRule
    from repro.analysis.unused import UnusedImportRule

    return [
        GuardedAccessRule(),
        BlockingUnderLockRule(),
        TraceHazardRule(),
        SyncUnderSemRule(),
        ThreadJoinRule(),
        BareAcquireRule(),
        UnusedImportRule(),
    ]


def iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in ("__pycache__", ".git", ".pytest_cache")
                )
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.append(os.path.join(dirpath, fn))
        elif p.endswith(".py"):
            out.append(p)
    return out


def lint_file(
    path: str, rules: Sequence[Rule], source: Optional[str] = None
) -> Tuple[List[Finding], List[Tuple[Finding, Suppression]]]:
    """Lint one file; returns (kept findings, suppressed findings)."""
    if source is None:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
    try:
        ctx = FileContext(path, source)
    except SyntaxError as exc:
        return (
            [Finding("parse-error", path, exc.lineno or 1, str(exc.msg))],
            [],
        )
    raw: List[Finding] = []
    for rule in rules:
        raw.extend(rule.check(ctx))
    known = {r.name for r in rules}
    kept: List[Finding] = []
    suppressed: List[Tuple[Finding, Suppression]] = []
    for f in raw:
        sup = next(
            (s for s in ctx.suppressors_for(f.line) if f.rule in s.rules),
            None,
        )
        if sup is not None:
            suppressed.append((f, sup))
        else:
            kept.append(f)
    # Suppression hygiene: malformed comments, missing reasons, unknown
    # rule names.  These are never themselves suppressible — the point
    # is that every waiver stays legible.
    for line in ctx.malformed_suppressions:
        kept.append(Finding(
            "suppression-format", path, line,
            "malformed suppression; expected "
            "'# lint: disable=<rule>[,<rule>] -- <reason>'",
        ))
    for sup in ctx.suppressions:
        if not sup.reason:
            kept.append(Finding(
                "suppression-format", path, sup.line,
                "suppression missing a reason ('-- <why>')",
            ))
        for r in sup.rules:
            if r not in known:
                kept.append(Finding(
                    "suppression-format", path, sup.line,
                    f"suppression names unknown rule {r!r}",
                ))
    return kept, suppressed


def lint_paths(
    paths: Sequence[str], rules: Optional[Sequence[Rule]] = None
) -> LintResult:
    """Run ``rules`` (default: all) over every ``.py`` under ``paths``."""
    rules = list(rules) if rules is not None else default_rules()
    findings: List[Finding] = []
    suppressed: List[Tuple[Finding, Suppression]] = []
    files = iter_py_files(paths)
    for path in files:
        kept, sups = lint_file(path, rules)
        findings.extend(kept)
        suppressed.extend(sups)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintResult(findings, suppressed, len(files))
