"""Trace/recompile-hazard rules.

`TraceHazardRule` flags host-varying values where they would either
defeat the compile cache or get baked into a trace as constants:

* in the **key expression** of a ``CompiledCache``-style call —
  ``<cache>.get(key, ...)`` / ``<cache>.get_jitted(key, ...)`` where
  the receiver's name ends in ``cache`` — host-varying calls
  (``time.time``/``random.*``/``uuid.*``/``id``) make every round a
  cold compile, and unhashable literals (list/dict/set) raise at
  runtime;
* in the **body of a traced function** — one decorated with
  ``jax.jit``/``partial(jax.jit, ...)`` or passed to ``jax.jit(f)`` /
  ``pl.pallas_call(kernel, ...)`` — where a host-varying call is
  evaluated once at trace time and frozen into the executable.

`SyncUnderSemRule` flags ``block_until_ready``/``.item()`` host syncs
lexically inside a ``with <device_sem>`` region: the semaphore is
meant to bound *device* work, and a deliberate sync there must be
annotated (the engines do this on purpose so the permit covers the
execution, not just the dispatch — those sites carry suppressions).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.core import FileContext, Finding, Rule
from repro.analysis.rules_locks import dotted_name

HOST_VARYING_PREFIXES = (
    "time.time",
    "time.monotonic",
    "time.perf_counter",
    "time.time_ns",
    "random.",
    "np.random.",
    "numpy.random.",
    "jax.random.PRNGKey",  # key folded into a cache key defeats caching
    "uuid.",
    "secrets.",
)
HOST_VARYING_BARE = {"id"}

JIT_NAMES = {"jax.jit", "jit", "api.jit"}
PALLAS_NAMES = {"pl.pallas_call", "pallas_call", "jax.experimental.pallas.pallas_call"}
SEM_NAMES = {"sem", "device_sem", "self.device_sem", "self._device_sem"}


def _host_varying(call: ast.Call) -> Optional[str]:
    fn = dotted_name(call.func)
    if fn is None:
        return None
    if fn in HOST_VARYING_BARE:
        return fn
    for pat in HOST_VARYING_PREFIXES:
        if fn == pat or (pat.endswith(".") and fn.startswith(pat)):
            return fn
    return None


def _is_cache_recv(node: ast.AST) -> bool:
    name = dotted_name(node)
    if name is None:
        return False
    last = name.split(".")[-1].lower()
    return last.endswith("cache")


class TraceHazardRule(Rule):
    name = "trace-hazard"
    description = (
        "host-varying values (time/random/uuid/id) must not flow into "
        "compile-cache keys or be evaluated inside jit/pallas-traced "
        "functions; cache keys must be hashable"
    )

    def check(self, ctx: FileContext) -> List[Finding]:
        findings: List[Finding] = []
        traced_names: Set[str] = set()
        # -- pass 1: find traced functions ---------------------------------
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    dn = dotted_name(dec)
                    if dn in JIT_NAMES:
                        traced_names.add(node.name)
                    elif isinstance(dec, ast.Call):
                        dfn = dotted_name(dec.func)
                        if dfn in JIT_NAMES:
                            traced_names.add(node.name)
                        elif dfn in ("functools.partial", "partial") and \
                                dec.args and \
                                dotted_name(dec.args[0]) in JIT_NAMES:
                            traced_names.add(node.name)
            elif isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn in JIT_NAMES | PALLAS_NAMES and node.args:
                    target = node.args[0]
                    if isinstance(target, ast.Name):
                        traced_names.add(target.id)
        # -- pass 2: scan traced function bodies ---------------------------
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef) and node.name in traced_names:
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call):
                        hv = _host_varying(sub)
                        if hv is not None:
                            findings.append(self.finding(
                                ctx, sub.lineno,
                                f"host-varying call {hv}() inside traced "
                                f"function {node.name!r} — evaluated once "
                                f"at trace time and baked into the "
                                f"executable",
                            ))
        # -- pass 3: cache-key expressions ----------------------------------
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not (isinstance(func, ast.Attribute)
                    and func.attr in ("get", "get_jitted")
                    and _is_cache_recv(func.value)
                    and node.args):
                continue
            key = node.args[0]
            for sub in ast.walk(key):
                if isinstance(sub, ast.Call):
                    hv = _host_varying(sub)
                    if hv is not None:
                        findings.append(self.finding(
                            ctx, sub.lineno,
                            f"host-varying call {hv}() in a compile-cache "
                            f"key — every lookup misses and re-traces",
                        ))
                elif isinstance(sub, (ast.List, ast.Dict, ast.Set,
                                      ast.ListComp, ast.DictComp,
                                      ast.SetComp, ast.GeneratorExp)):
                    kind = type(sub).__name__.lower()
                    findings.append(self.finding(
                        ctx, sub.lineno,
                        f"unhashable {kind} literal in a compile-cache "
                        f"key — raises TypeError at lookup",
                    ))
        return findings


class SyncUnderSemRule(Rule):
    name = "sync-under-sem"
    description = (
        "block_until_ready/.item() host syncs inside a 'with device_sem' "
        "region hold a device permit across a host round-trip; deliberate "
        "sites must be annotated"
    )

    def check(self, ctx: FileContext) -> List[Finding]:
        findings: List[Finding] = []

        def walk(node: ast.AST, in_sem: bool) -> None:
            if isinstance(node, ast.With):
                entered = in_sem
                for item in node.items:
                    name = dotted_name(item.context_expr)
                    if name in SEM_NAMES:
                        entered = True
                for stmt in node.body:
                    walk(stmt, entered)
                return
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for stmt in node.body:
                    walk(stmt, False)
                return
            if in_sem and isinstance(node, ast.Call):
                # attribute lookup directly, so chains rooted in a call
                # result — step(block).item() — are still seen
                if isinstance(node.func, ast.Attribute):
                    last = node.func.attr
                else:
                    last = (dotted_name(node.func) or "").split(".")[-1]
                if last in ("block_until_ready", "item"):
                    findings.append(self.finding(
                        ctx, node.lineno,
                        f"host sync {last}() inside a device_sem region",
                    ))
            for child in ast.iter_child_nodes(node):
                walk(child, in_sem)

        walk(ctx.tree, False)
        return findings
