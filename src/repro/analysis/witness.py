"""Runtime lock-order witness.

The static rules prove lexical discipline; this module watches the
*dynamic* order.  `LockOrderWitness.wrap` returns a drop-in lock
wrapper that records, per thread, the stack of witnessed locks held
and, globally, every acquisition edge ``A -> B`` ("B was acquired
while A was held", with the owning thread names).  `check()` then
fails on either:

* a **cycle** in the union graph across threads (two threads acquiring
  the same pair of locks in opposite orders — the classic deadlock
  shape), or
* a **rank violation** against the declared partial order.  The repo's
  order is ``state ≺ store ≺ per-tenant round lock`` with ``≺``
  meaning *inner-before-outer*: a lock may only be acquired while
  every held ranked lock has a strictly greater rank.  The round lock
  (rank 2) is the outermost; store (1) and state (0) may be taken
  under it; nothing may be taken while holding state (0), and two
  round locks never nest.

Unranked locks participate in cycle detection only.

`instrument_service` swaps an `AggregationService`'s three lock layers
for witnessed wrappers — it must run before any concurrent use (in
tests: right after construction, via the ``lock_witness`` fixture).

The wrapper implements ``acquire``/``release``/``__enter__``/
``__exit__``/``locked`` plus ``_is_owned`` so ``threading.Condition``
composes with it without falling back to its acquire-probe ownership
test.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple

DECLARED_ORDER: Tuple[str, ...] = ("state", "store", "round")


class LockOrderViolation(AssertionError):
    """Raised by `LockOrderWitness.check` on cycles or rank breaks."""


class _Held(threading.local):
    def __init__(self):
        self.stack: List["WitnessedLock"] = []


class WitnessedLock:
    """Drop-in wrapper recording acquisitions into a witness."""

    def __init__(self, witness: "LockOrderWitness", inner, name: str,
                 rank: Optional[int]):
        self._witness = witness
        self._inner = inner
        self.name = name
        self.rank = rank

    # -- lock protocol -------------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._witness._on_acquire(self)
        return got

    def release(self) -> None:
        self._witness._on_release(self)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def _is_owned(self) -> bool:
        # For Condition: ownership == this thread has it on its stack.
        return any(l is self for l in self._witness._held.stack)

    def __repr__(self) -> str:
        return f"<WitnessedLock {self.name!r} rank={self.rank}>"


class LockOrderWitness:
    """Collects the cross-thread acquisition graph; see module docs."""

    def __init__(self, order: Tuple[str, ...] = DECLARED_ORDER):
        self.order = tuple(order)
        self._ranks = {name: i for i, name in enumerate(self.order)}
        self._held = _Held()
        self._mu = threading.Lock()  # guards the two dicts below
        #: (outer name, inner name) -> example (thread, outer, inner)
        self.edges: Dict[Tuple[str, str], Tuple[str, str, str]] = {}
        self.violations: List[str] = []

    # -- wrapping ------------------------------------------------------------
    def wrap(self, lock, name: str, rank_class: Optional[str] = None
             ) -> WitnessedLock:
        """Wrap ``lock``; ``rank_class`` is a name from the declared
        order (or None for cycle-detection-only participation)."""
        rank = self._ranks.get(rank_class) if rank_class else None
        if rank_class is not None and rank is None:
            raise ValueError(
                f"unknown rank class {rank_class!r}; declared order is "
                f"{self.order}"
            )
        return WitnessedLock(self, lock, name, rank)

    # -- recording -----------------------------------------------------------
    def _on_acquire(self, lock: WitnessedLock) -> None:
        stack = self._held.stack
        tname = threading.current_thread().name
        if stack:
            with self._mu:
                for held in stack:
                    self.edges.setdefault(
                        (held.name, lock.name), (tname, held.name, lock.name)
                    )
                for held in stack:
                    if held.rank is None or lock.rank is None:
                        continue
                    if held.rank <= lock.rank:
                        self.violations.append(
                            f"thread {tname!r} acquired {lock.name!r} "
                            f"(rank {self.order[lock.rank]!r}) while "
                            f"holding {held.name!r} (rank "
                            f"{self.order[held.rank]!r}); declared order "
                            f"is inner-first: "
                            f"{' ≺ '.join(self.order)}"
                        )
        stack.append(lock)

    def _on_release(self, lock: WitnessedLock) -> None:
        stack = self._held.stack
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                return

    # -- verdicts ------------------------------------------------------------
    def find_cycle(self) -> Optional[List[str]]:
        """A lock-name cycle in the acquisition graph, if any."""
        with self._mu:
            adj: Dict[str, Set[str]] = {}
            for (a, b) in self.edges:
                adj.setdefault(a, set()).add(b)
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in adj}
        path: List[str] = []

        def dfs(n: str) -> Optional[List[str]]:
            color[n] = GRAY
            path.append(n)
            for m in adj.get(n, ()):
                c = color.get(m, WHITE)
                if c == GRAY:
                    return path[path.index(m):] + [m]
                if c == WHITE:
                    color.setdefault(m, WHITE)
                    found = dfs(m)
                    if found:
                        return found
            color[n] = BLACK
            path.pop()
            return None

        for n in list(adj):
            if color.get(n, WHITE) == WHITE:
                found = dfs(n)
                if found:
                    return found
        return None

    def check(self) -> None:
        """Raise `LockOrderViolation` on any recorded rank violation or
        any cycle in the acquisition graph."""
        with self._mu:
            violations = list(self.violations)
        cycle = self.find_cycle()
        if cycle is not None:
            violations.append(
                "acquisition-order cycle (potential deadlock): "
                + " -> ".join(cycle)
            )
        if violations:
            raise LockOrderViolation(
                "lock-order witness failed:\n  " + "\n  ".join(violations)
            )


class _WitnessedLockDict(dict):
    """Dict subclass that wraps every lock stored into it — covers the
    service's lazy per-tenant round-lock creation
    (``self._tenant_locks[tenant] = threading.Lock()``)."""

    def __init__(self, witness: LockOrderWitness, rank_class: str,
                 name_fmt: str, initial: dict):
        super().__init__()
        self._witness = witness
        self._rank_class = rank_class
        self._name_fmt = name_fmt
        for k, v in initial.items():
            self[k] = v

    def __setitem__(self, key, value):
        if not isinstance(value, WitnessedLock):
            value = self._witness.wrap(
                value, self._name_fmt.format(key), self._rank_class
            )
        super().__setitem__(key, value)


def instrument_service(service, witness: LockOrderWitness):
    """Swap ``service``'s lock layers for witnessed wrappers.

    Covers the three declared layers: the service state lock (rank
    ``state``), the store lock + its ``_arrival_cv`` condition alias
    (rank ``store``), and every per-tenant round lock, including ones
    created lazily after instrumentation (rank ``round``).  Must run
    before the service sees concurrent traffic.
    """
    service._state_lock = witness.wrap(
        service._state_lock, "state", "state"
    )
    store = service.store
    if not isinstance(store._lock, WitnessedLock):
        # two services sharing one store: wrap the store layer once
        wrapped = witness.wrap(store._lock, "store", "store")
        store._lock = wrapped
        # The condition must share the witnessed lock, or waiters would
        # release the raw inner lock while the witness still thinks the
        # wrapper is held.
        store._arrival_cv = threading.Condition(wrapped)
    service._tenant_locks = _WitnessedLockDict(
        witness, "round", "round:{}", service._tenant_locks
    )
    return service
