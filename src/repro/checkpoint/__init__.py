from repro.checkpoint.ckpt import (
    load_controller_state,
    load_pytree,
    save_controller_state,
    save_pytree,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "save_controller_state",
    "load_controller_state",
]
