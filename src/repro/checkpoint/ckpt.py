"""Checkpointing: pytrees <-> a single .npz + structure manifest, and
adaptive-controller state <-> a JSON sidecar.

Sharded arrays are gathered to host before saving (fine at the scales this
container runs; on a real pod you'd swap in per-shard files keyed by the
same path strings — the format is already path-addressed to allow that).

``save_controller_state`` / ``load_controller_state`` persist an
:class:`repro.core.adaptive.AdaptiveController`'s learned arrival
curves (per-tenant models + the cross-tenant prior) NEXT TO the model
checkpoint, so an aggregator restart resumes with its learned gates
instead of re-learning from static-timeout rounds. The controller's
``state_dict`` is already JSON-able, so the format is plain JSON —
inspectable, diffable, and independent of the .npz model format.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np

PyTree = Any


def _paths_and_leaves(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # bf16 etc: not a native numpy dtype
            arr = np.asarray(leaf, dtype=np.float32)  # lossless widening
        out[key] = arr
    return out, treedef


def save_pytree(path: str, tree: PyTree) -> None:
    arrays, _ = _paths_and_leaves(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    manifest = {
        k: {"shape": list(v.shape), "dtype": str(v.dtype)}
        for k, v in arrays.items()
    }
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def _controller_path(path: str) -> str:
    """Canonical on-disk name: ``<path>.controller.json`` (``path`` may
    be the model checkpoint path — the controller state lands beside
    it)."""
    if path.endswith(".controller.json"):
        return path
    return path.removesuffix(".npz") + ".controller.json"


def save_controller_state(path: str, controller: Any) -> str:
    """Persist an ``AdaptiveController`` (or a raw ``state_dict``)
    as JSON at ``<path>.controller.json``. Returns the written path.

    ``path`` is typically the model checkpoint path passed to
    :func:`save_pytree`, so the learned gates travel with the model
    state they were learned under."""
    state = (
        controller.state_dict()
        if hasattr(controller, "state_dict") else controller
    )
    out = _controller_path(path)
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(state, f, indent=1)
    return out


def load_controller_state(path: str, controller: Any = None) -> Dict:
    """Load controller state saved by :func:`save_controller_state`.

    Returns the raw state dict; with ``controller`` given (anything
    exposing ``load_state_dict``, e.g. an ``AdaptiveController`` or an
    adaptive ``AggregationService``'s ``.controller``), the state is
    also restored into it."""
    with open(_controller_path(path)) as f:
        state = json.load(f)
    if controller is not None:
        controller.load_state_dict(state)
    return state


def load_pytree(path: str, template: PyTree) -> PyTree:
    """Restore into the structure of ``template`` (dtype-cast to match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    arrays, treedef = _paths_and_leaves(template)
    leaves = []
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    for pathk, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pathk
        )
        arr = npz[key]
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
