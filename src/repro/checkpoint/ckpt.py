"""Checkpointing: pytrees <-> a single .npz + structure manifest.

Sharded arrays are gathered to host before saving (fine at the scales this
container runs; on a real pod you'd swap in per-shard files keyed by the
same path strings — the format is already path-addressed to allow that).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np

PyTree = Any


def _paths_and_leaves(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # bf16 etc: not a native numpy dtype
            arr = np.asarray(leaf, dtype=np.float32)  # lossless widening
        out[key] = arr
    return out, treedef


def save_pytree(path: str, tree: PyTree) -> None:
    arrays, _ = _paths_and_leaves(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    manifest = {
        k: {"shape": list(v.shape), "dtype": str(v.dtype)}
        for k, v in arrays.items()
    }
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_pytree(path: str, template: PyTree) -> PyTree:
    """Restore into the structure of ``template`` (dtype-cast to match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    arrays, treedef = _paths_and_leaves(template)
    leaves = []
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    for pathk, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pathk
        )
        arr = npz[key]
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
