"""Hardware constants and memory math.

These numbers drive (a) the workload classifier — the TPU analogue of the
paper's `S = w_s * n  vs  M` rule — and (b) the roofline analysis.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip hardware model used by the planner and the roofline."""

    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bytes: int          # per chip
    hbm_bw: float           # bytes/s
    vmem_bytes: int         # per core
    ici_bw_per_link: float  # bytes/s per link
    ici_links: int          # links per chip (torus)

    @property
    def arithmetic_intensity_knee(self) -> float:
        """FLOPs/byte at which compute and HBM rooflines intersect."""
        return self.peak_flops_bf16 / self.hbm_bw


# Target hardware for this reproduction (per task constants):
#   197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    vmem_bytes=128 * 1024**2,
    ici_bw_per_link=50e9,
    ici_links=4,
)


def bytes_to_human(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"
