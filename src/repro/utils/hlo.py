"""HLO-text analysis: collective-byte accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and bytes but (a) NOT collective
traffic and (b) counts while-loop (lax.scan) bodies ONCE. This module
parses the optimized HLO text computation-by-computation, walks the call
graph (entry -> while bodies / conditional branches), extracts loop trip
counts from the loop-condition constants, and sums collective bytes with
the correct multiplicity.

Byte model per op (ring algorithms, per-device bytes crossing links):
  all-gather        result * (g-1)/g
  all-reduce        result * 2(g-1)/g
  reduce-scatter    result * (g-1)           (result is the scattered shard)
  all-to-all        result * (g-1)/g
  collective-permute result * 1
Unknown group size falls back to the full buffer (upper bound).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_REPLICA_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# computation header: `%name (params) -> type {` or `ENTRY %name (...) {`
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*\{\s*$")
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)=\{?%?([\w\.\-]+(?:, *%?[\w\.\-]+)*)\}?"
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1 if dims == "" else int(
            np.prod([int(d) for d in dims.split(",") if d])
        )
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _REPLICA_GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        if first:
            return len(first.split(","))
    return 0


def _header_name(line: str) -> Optional[str]:
    """Computation header: starts at column 0 with '%name (' or
    'ENTRY %name (' and ends with '{'. Param lists may contain nested
    parens (tuple types), so only the prefix is parsed."""
    if not line or line[0].isspace():
        return None
    if not line.rstrip().endswith("{"):
        return None
    s = line
    if s.startswith("ENTRY"):
        s = s[len("ENTRY"):].lstrip()
    if not (s.startswith("%") or s[:1].isalpha()):
        return None
    s = s.lstrip("%")
    name = re.split(r"[\s(]", s, 1)[0]
    return name or None


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        name = _header_name(line)
        if name is not None:
            cur = name
            comps[cur] = []
            continue
        if cur is not None:
            s = line.strip()
            if s == "}":
                cur = None
                continue
            comps[cur].append(s)
    return comps


def entry_name(hlo_text: str) -> Optional[str]:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            return _header_name(line)
    return None


def _loop_trip_count(cond_lines: List[str]) -> int:
    """Largest s32/u32 constant in the loop condition ~= trip count."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"[su]32\[\]\s+constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, float]
    bytes_moved: Dict[str, float]
    buffer_bytes: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_moved.values()))

    @property
    def total_count(self) -> float:
        return float(sum(self.counts.values()))


def analyze_collectives(hlo_text: str) -> CollectiveStats:
    comps = split_computations(hlo_text)
    entry = entry_name(hlo_text)
    counts = {k: 0.0 for k in _COLLECTIVES}
    moved = {k: 0.0 for k in _COLLECTIVES}
    raw = {k: 0.0 for k in _COLLECTIVES}

    def line_collective(s: str):
        for coll in _COLLECTIVES:
            if re.search(rf"\b{coll}(?:-start)?\(", s):
                if f"{coll}-done(" in s:
                    return None
                return coll
        return None

    visited_stack: List[str] = []

    def walk(comp: str, mult: float):
        if comp not in comps or comp in visited_stack:
            return
        visited_stack.append(comp)
        for s in comps[comp]:
            coll = line_collective(s)
            if coll is not None:
                lhs = s.split(f" {coll}", 1)[0]
                nbytes = _shape_bytes(lhs)
                if nbytes:
                    g = _group_size(s)
                    if coll == "all-gather":
                        f = (g - 1) / g if g > 1 else 1.0
                    elif coll == "all-reduce":
                        f = 2 * (g - 1) / g if g > 1 else 1.0
                    elif coll == "reduce-scatter":
                        f = (g - 1) if g > 1 else 1.0
                    elif coll == "all-to-all":
                        f = (g - 1) / g if g > 1 else 1.0
                    else:
                        f = 1.0
                    counts[coll] += mult
                    moved[coll] += nbytes * f * mult
                    raw[coll] += nbytes * mult
                continue
            if " while(" in s or s.startswith("while(") or re.search(r"=\s*\S*\s*while\(", s):
                mb = re.search(r"body=%?([\w\.\-]+)", s)
                mc = re.search(r"condition=%?([\w\.\-]+)", s)
                if mb:
                    trips = 1
                    if mc and mc.group(1) in comps:
                        trips = _loop_trip_count(comps[mc.group(1)])
                    walk(mb.group(1), mult * trips)
                continue
            if "conditional(" in s:
                mbr = re.search(r"branch_computations=\{([^}]*)\}", s)
                branches = []
                if mbr:
                    branches = [
                        b.strip().lstrip("%") for b in mbr.group(1).split(",")
                    ]
                else:
                    branches = re.findall(
                        r"(?:true_computation|false_computation)=%?([\w\.\-]+)", s
                    )
                # conservative: a data-dependent branch may always be taken
                for b in branches:
                    walk(b, mult)
                continue
            for attr in ("calls", "to_apply"):
                m = re.search(rf"{attr}=%?([\w\.\-]+)", s)
                if m:
                    walk(m.group(1), mult)
        visited_stack.pop()

    if entry:
        walk(entry, 1.0)
    else:  # fallback: flat scan, no multiplicity
        for comp in comps:
            walk(comp, 1.0)
    return CollectiveStats(counts=counts, bytes_moved=moved, buffer_bytes=raw)


def collective_bytes(hlo_text: str) -> float:
    return analyze_collectives(hlo_text).total_bytes


def count_collectives(hlo_text: str) -> Dict[str, float]:
    return analyze_collectives(hlo_text).counts
