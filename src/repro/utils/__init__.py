"""Shared utilities: pytree flattening, HLO analysis, memory math, logging."""
from repro.utils.pytree import (
    tree_size_bytes,
    tree_num_params,
    tree_to_flat_vector,
    flat_vector_to_tree,
    tree_shape_dtype,
    tree_zeros_like_spec,
    tree_allclose,
)
from repro.utils.hlo import collective_bytes, count_collectives
from repro.utils.mem import (
    HardwareSpec,
    TPU_V5E,
    bytes_to_human,
)

__all__ = [
    "tree_size_bytes",
    "tree_num_params",
    "tree_to_flat_vector",
    "flat_vector_to_tree",
    "tree_shape_dtype",
    "tree_zeros_like_spec",
    "tree_allclose",
    "collective_bytes",
    "count_collectives",
    "HardwareSpec",
    "TPU_V5E",
    "bytes_to_human",
]
