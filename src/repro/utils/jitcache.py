"""Shape-bucketed compile caches — persistent executables across elastic
rounds.

Elastic FL rounds change the client count ``n`` every round; jitting a
fresh closure per round (the seed behavior of both engines) re-traces and
re-compiles the whole fusion program each time, which is exactly the
per-round launch overhead the paper's adaptive aggregator is meant to
avoid. The fix has two halves:

  * **bucketing** — round ``n`` up to the next power of two and zero-pad
    the weights, so every round with ``n`` in ``(B/2, B]`` shares ONE
    executable (padded rows carry weight 0 and contribute nothing to any
    reducible fusion);
  * **caching** — key compiled executables by (fusion, bucket, P, dtype,
    path) and reuse them for as long as the process lives, instead of
    rebuilding ``shard_map``/``jax.jit`` closures per ``fuse()`` call.

``trace_count()`` is a global monotone counter bumped every time one of
our cached builders is (re-)traced; tests assert it stays flat across
same-bucket rounds. ``CompiledCache`` also accounts compile seconds,
which feeds ``RoundReport.phase_seconds["compile"]`` and the Planner's
reuse term (warm engines are costed below cold ones).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Hashable, Tuple

import jax

# -- trace accounting ---------------------------------------------------------

_TRACE_LOCK = threading.Lock()
_TRACE_COUNT = 0


def note_trace() -> None:
    """Called from INSIDE traced function bodies: executes once per trace
    (never on a compiled-cache hit), so the counter measures re-tracing."""
    global _TRACE_COUNT
    with _TRACE_LOCK:
        _TRACE_COUNT += 1


def trace_count() -> int:
    return _TRACE_COUNT


# -- bucketing ----------------------------------------------------------------


def round_up_pow2(n: int, floor: int = 1) -> int:
    n = max(int(n), int(floor))
    return 1 << (n - 1).bit_length()


def bucket_rows(n: int, floor: int = 8) -> int:
    """Client-count bucket: next power of two, with a small floor so tiny
    rounds (1..8 clients) all land in one bucket."""
    return round_up_pow2(n, floor)


def fusion_cache_key(fusion) -> Hashable:
    """Stable cache key for a fusion instance: name + hyperparameters.
    (Server-state fields like FedAvgM's velocity start with ``_`` and are
    not dataclass fields, so they never leak into the key.)"""
    if dataclasses.is_dataclass(fusion):
        fields = tuple(
            (f.name, getattr(fusion, f.name))
            for f in dataclasses.fields(fusion)
        )
        return (fusion.name, fields)
    return (fusion.name,)


# -- compiled-executable cache ------------------------------------------------


@dataclasses.dataclass
class CacheEntry:
    fn: Callable
    compile_seconds: float


class CompiledCache:
    """key -> compiled executable, with hit/miss and compile-time stats.

    Two styles:
      * ``get`` — AOT: the builder's function is jit'd, lowered against
        ShapeDtypeStructs and compiled immediately; the stored callable is
        the compiled executable (exact shapes/dtypes — which bucketing
        guarantees). Compile time is measured precisely.
      * ``get_jitted`` — lazy: stores a ``jax.jit`` object (used for
        ``shard_map`` closures whose sharded lowering wants real device
        inputs); jit's internal cache handles same-shape reuse, and the
        point is to stop rebuilding the closure per call.

    The compile path is SINGLE-FLIGHT per key: when two threads (two
    tenants' concurrent rounds) race the same shape bucket, exactly one
    compiles while the others block on that key's in-flight build and
    then share the finished executable as a hit — ``misses`` counts cold
    compiles actually paid, never duplicated work. Builds for DIFFERENT
    keys still proceed concurrently (the build itself runs outside the
    cache lock). If a build raises, its waiters retry and one of them
    takes over the build instead of caching the failure.
    """

    def __init__(self, name: str = "cache"):
        self.name = name
        self._entries: Dict[Hashable, CacheEntry] = {}
        self._lock = threading.Lock()
        # key -> Event for a build in flight; racers of the same key wait
        # here instead of compiling a duplicate executable
        self._building: Dict[Hashable, threading.Event] = {}
        self.hits = 0
        self.misses = 0
        self.compile_seconds = 0.0

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(
        self,
        key: Hashable,
        builder: Callable[[], Callable],
        *arg_specs,
    ) -> Tuple[Callable, float]:
        """Return ``(executable, compile_seconds_spent_now)`` — the second
        element is 0.0 on a hit, so callers can report a compile phase.
        ``arg_specs`` are ShapeDtypeStructs OR concrete (possibly sharded,
        committed) example arrays — the latter is what ``shard_map``
        closures need, since their sharded lowering binds to real input
        shardings."""
        done = self._claim(key)
        if done is not None:
            return done
        # Build outside the lock: compiling can take seconds and other
        # shapes' lookups must not serialize behind it. This thread owns
        # the key's in-flight slot; same-key racers wait in _claim.
        try:
            fn = builder()

            def traced(*args):
                note_trace()
                return fn(*args)

            t0 = time.perf_counter()
            compiled = jax.jit(traced).lower(*arg_specs).compile()
            dt = time.perf_counter() - t0
            with self._lock:
                self._entries[key] = CacheEntry(
                    fn=compiled, compile_seconds=dt
                )
                self.misses += 1
                self.compile_seconds += dt
        finally:
            self._release(key)
        return compiled, dt

    def _claim(self, key: Hashable):
        """Return the cached ``(fn, 0.0)`` on a hit, else claim the
        key's build slot and return None (the caller must build and then
        ``_release``). A thread racing an in-flight build for the SAME
        key blocks until that build lands and shares it as a hit."""
        while True:
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self.hits += 1
                    return entry.fn, 0.0
                ev = self._building.get(key)
                if ev is None:
                    self._building[key] = threading.Event()
                    return None
            # same-key build in flight: wait, then re-check — a failed
            # build wakes us with no entry and we take over the slot
            ev.wait()

    def _release(self, key: Hashable) -> None:
        with self._lock:
            ev = self._building.pop(key, None)
        if ev is not None:
            ev.set()

    def get_jitted(
        self, key: Hashable, builder: Callable[[], Callable]
    ) -> Callable:
        """Cache a ``jax.jit``-wrapped builder output (lazy compile).
        Single-flight per key, like ``get``."""
        done = self._claim(key)
        if done is not None:
            return done[0]
        try:
            fn = builder()

            def traced(*args):
                note_trace()
                return fn(*args)

            jitted = jax.jit(traced)
            with self._lock:
                self._entries[key] = CacheEntry(
                    fn=jitted, compile_seconds=0.0
                )
                self.misses += 1
        finally:
            self._release(key)
        return jitted

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
