"""Analytic FLOP/byte models per (architecture x input shape).

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies once, so
any scanned computation (layer stacks, flash-attention tiles, SSD chunks,
the chunked CE loss) is undercounted in the compiled artifact. The
roofline's compute term therefore uses these closed-form counts; the
measured HLO numbers are reported alongside for reference.

Definitions (per GLOBAL step, fp operations, multiply-add = 2 FLOPs):

  MODEL_FLOPS   — the useful math: 6·N_active·tokens (train) or
                  2·N_active·tokens (prefill/decode) + exact attention
                  term (causal/windowed).
  EXEC_FLOPS    — what actually executes: MODEL_FLOPS inflated by
                  (a) full-remat recompute (+1 forward in training),
                  (b) MoE capacity over-provisioning (capacity_factor),
                  (c) attention block-skip granularity (tile-rounded
                  causal mask).

The ratio MODEL/EXEC is §Roofline's "useful compute" metric.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class FlopsReport:
    model_flops: float
    exec_flops: float
    attn_flops: float          # included in both totals
    hbm_bytes_analytic: float  # per-device streaming traffic estimate

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.exec_flops, 1.0)


def _attn_tokens_sq(cfg: ModelConfig, T: int, tile: int = 512,
                    exact: bool = False) -> tuple[float, float]:
    """(useful, executed) sum over layers of per-query average key count.

    Causal: T(T+1)/2 useful; executed rounds the mask to (tile x tile)
    blocks (the lax.cond skip granularity). Windowed layers clip to the
    window. Returns per-batch-element totals summed over layers.
    """
    from repro.models.decoder import layer_windows

    if cfg.family in ("ssm",) and cfg.xlstm is not None:
        return 0.0, 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.hybrid_shared_every, 1)
        wins = [cfg.attn.sliding_window] * n_attn
    elif cfg.family == "audio":
        wins = [0] * cfg.n_layers  # decoder self-attn; encoder added below
    else:
        wins = layer_windows(cfg)
    useful = exec_ = 0.0
    n_tiles = max(T // tile, 1)
    for w in wins:
        if w and w < T:
            u = T * min(w, T)  # each query sees <= window keys
            blocks = n_tiles * (min(w, T) // tile + 2)
        else:
            u = T * (T + 1) / 2
            blocks = n_tiles * (n_tiles + 1) / 2
        useful += u
        exec_ += blocks * tile * tile
    return useful, exec_


def flops_for(cfg: ModelConfig, shape: InputShape,
              n_chips: int = 256) -> FlopsReport:
    B, T = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    nq = cfg.n_heads
    n_active = cfg.num_active_params()
    n_total = cfg.num_params()
    itemsize = 2  # bf16 params

    if shape.kind == "train":
        tokens = B * T
        dense_model = 6.0 * n_active * tokens
        # remat: +1 forward recompute => (2+4+2)/6 = 4/3 of the 6N·D
        dense_exec = 8.0 * n_active * tokens
        if cfg.moe is not None:
            # capacity-padded expert matmuls (dropped slots still compute)
            m = cfg.moe
            expert = 3 * cfg.d_model * cfg.d_ff
            routed_model = 6.0 * cfg.n_layers * m.top_k * expert * tokens
            routed_exec = routed_model * m.capacity_factor * (8 / 6)
            dense_exec += routed_exec - routed_model * (8 / 6)
        u_sq, e_sq = _attn_tokens_sq(cfg, T)
        attn_model = 6.0 * 2 * B * nq * hd * u_sq      # qk + av, fwd+bwd
        attn_exec = 8.0 * 2 * B * nq * hd * e_sq       # + remat recompute
        if cfg.family == "audio":
            S = cfg.n_audio_frames
            enc = 2.0 * B * cfg.n_heads * hd * cfg.n_encoder_layers * S * S
            attn_model += 6.0 * enc / 2
            attn_exec += 8.0 * enc / 2
        mf = dense_model + attn_model
        ef = dense_exec + attn_exec
        # HBM traffic/device: params+grads+moments churn + activations
        param_traffic = n_total * (itemsize * 3 + 4 * 4) / n_chips
        act_traffic = (
            tokens * cfg.d_model * itemsize * cfg.n_layers * 8 / n_chips
        )
        return FlopsReport(mf, ef, attn_model, param_traffic + act_traffic)

    if shape.kind == "prefill":
        tokens = B * T
        dense = 2.0 * n_active * tokens
        dense_exec = dense
        if cfg.moe is not None:
            m = cfg.moe
            expert = 3 * cfg.d_model * cfg.d_ff
            routed = 2.0 * cfg.n_layers * m.top_k * expert * tokens
            dense_exec += routed * (m.capacity_factor - 1.0)
        u_sq, e_sq = _attn_tokens_sq(cfg, T)
        attn_model = 2.0 * 2 * B * nq * hd * u_sq
        attn_exec = 2.0 * 2 * B * nq * hd * e_sq
        if cfg.family == "audio":
            S = cfg.n_audio_frames
            enc = 2.0 * 2 * B * cfg.n_heads * hd * cfg.n_encoder_layers * S * S
            attn_model += enc
            attn_exec += enc
        param_traffic = n_active * itemsize / n_chips
        act_traffic = tokens * cfg.d_model * itemsize * cfg.n_layers * 4 / n_chips
        return FlopsReport(
            dense + attn_model, dense_exec + attn_exec, attn_model,
            param_traffic + act_traffic,
        )

    # decode: ONE token per sequence against a length-T cache
    tokens = B
    dense = 2.0 * n_active * tokens
    dense_exec = dense
    if cfg.moe is not None:
        m = cfg.moe
        expert = 3 * cfg.d_model * cfg.d_ff
        routed = 2.0 * cfg.n_layers * m.top_k * expert * tokens
        dense_exec += routed * (m.capacity_factor - 1.0)
    # attention reads the whole (or windowed) cache once per layer
    from repro.models.decoder import layer_windows

    if cfg.family == "ssm" and cfg.xlstm is not None:
        attn = 0.0
        state_bytes = 0.0
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.hybrid_shared_every, 1)
        w = cfg.attn.sliding_window
        S_eff = min(w, T) if w else T
        attn = 2.0 * 2 * B * nq * hd * S_eff * n_attn
        state_bytes = 0.0
    else:
        force_local = shape.name == "long_500k"
        wins = layer_windows(cfg, force_local=force_local)
        S_layers = sum(min(w, T) if w else T for w in wins)
        attn = 2.0 * 2 * B * nq * hd * S_layers
        state_bytes = 0.0
    param_traffic = n_active * itemsize / n_chips
    # decode is cache-bandwidth-bound: the whole live cache streams once
    from repro.models import build_model
    from repro.utils.pytree import tree_size_bytes

    model = build_model(cfg)
    cache = model.init_cache(
        B, T, spec_only=True, force_local=shape.name == "long_500k"
    )
    cache_traffic = tree_size_bytes(cache) / n_chips
    return FlopsReport(
        dense + attn, dense_exec + attn, attn,
        param_traffic + cache_traffic + state_bytes,
    )
