"""JAX version compatibility shims.

The codebase targets the modern spellings (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); this container
ships jax 0.4.x where shard_map lives in ``jax.experimental`` (kwarg
``check_rep``) and meshes take no axis types. Route every use through
here so one file owns the version split.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, check_vma kwarg
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )


def make_mesh(axis_shapes, axis_names, devices=None):
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices,
            axis_types=(AxisType.Auto,) * len(axis_names),
        )
    except (ImportError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
