"""Pytree helpers used across the aggregation service and model stack.

The aggregation engines treat a model update as an arbitrary pytree of
arrays (exactly how IBMFL treats a model update as a list of ndarrays).
These helpers provide the flat-vector view used by fusion kernels and the
bookkeeping (sizes, parameter counts) used by the workload classifier.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_num_params(tree: PyTree) -> int:
    """Total number of scalar parameters in a pytree of arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(int(np.prod(l.shape)) if hasattr(l, "shape") else 1 for l in leaves))


def tree_size_bytes(tree: PyTree) -> int:
    """Total byte size of a pytree of arrays (or ShapeDtypeStructs)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = getattr(leaf, "shape", ())
        dtype = np.dtype(getattr(leaf, "dtype", np.float32))
        total += int(np.prod(shape)) * dtype.itemsize
    return total


def tree_shape_dtype(tree: PyTree) -> PyTree:
    """Map a pytree of arrays to ShapeDtypeStructs (no data)."""
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )


def tree_to_flat_vector(tree: PyTree, dtype=None) -> jnp.ndarray:
    """Concatenate every leaf into a single 1-D vector.

    This is the canonical layout the fusion kernels operate on: fusion
    algorithms are elementwise (or act per-coordinate across clients), so a
    flat view loses nothing and lets one kernel serve every architecture.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype=dtype or jnp.float32)
    flat = [jnp.ravel(l) for l in leaves]
    vec = jnp.concatenate(flat)
    if dtype is not None:
        vec = vec.astype(dtype)
    return vec


def flat_vector_to_tree(vec: jnp.ndarray, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_to_flat_vector` given a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    offset = 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        chunk = jax.lax.dynamic_slice_in_dim(vec, offset, n, 0)
        out.append(chunk.reshape(leaf.shape).astype(leaf.dtype))
        offset += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_zeros_like_spec(spec: PyTree) -> PyTree:
    """Materialize zeros for a pytree of ShapeDtypeStructs."""
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def tree_allclose(a: PyTree, b: PyTree, rtol=1e-5, atol=1e-6) -> bool:
    """Structural + numerical equality of two pytrees."""
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb:
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(la, lb)
    )


def tree_map_with_path_names(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """tree_map with a '/'-joined string path as the first argument."""

    def _go(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_go, tree)
