"""End-to-end federated training driver (CPU-runnable).

Trains a reduced variant of any assigned architecture with the FULL stack:
synthetic non-IID data -> per-client local steps -> AggregationService
(adaptive engine selection) -> global model update -> eval loss.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --rounds 20 --clients 8 --local-steps 2 --fusion fedavg
"""
from __future__ import annotations

import argparse
import time


from repro.configs import get_config
from repro.core import AggregationService
from repro.data import FederatedLoader, SyntheticLM
from repro.fl import Client, FederatedServer
from repro.models import build_model
from repro.optim import sgd
from repro.checkpoint import save_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clients-per-round", type=int, default=None)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.25)
    ap.add_argument("--fusion", default="fedavg")
    ap.add_argument("--local-strategy", default="jnp")
    ap.add_argument("--skew", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) architecture config")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced() if not args.arch.endswith("-smoke") else cfg
    model = build_model(cfg)
    gen = SyntheticLM(vocab=cfg.vocab, seed=args.seed, skew=args.skew)
    loader = FederatedLoader(
        gen=gen, n_clients=args.clients, batch=args.batch,
        seq_len=args.seq_len,
    )
    send_delta = args.fusion in ("gradavg", "fedavgm", "fedadam")
    clients = [
        Client(
            client_id=i, model=model, optimizer=sgd(args.lr),
            local_steps=args.local_steps, send_delta=send_delta,
        )
        for i in range(args.clients)
    ]
    service = AggregationService(
        fusion=args.fusion, local_strategy=args.local_strategy
    )
    server = FederatedServer(
        model=model, clients=clients, loader=loader, service=service,
        rng_seed=args.seed, clients_per_round=args.clients_per_round,
    )
    print(f"[train] arch={cfg.arch_id} params={cfg.num_params():,} "
          f"clients={args.clients} fusion={args.fusion}")
    t0 = time.time()
    for r in range(args.rounds):
        res = server.run_round(r)
        print(
            f"[round {r:3d}] loss={res.mean_client_loss:.4f} "
            f"engine={res.report.plan.engine} "
            f"class={res.report.plan.workload_class.value} "
            f"fuse={res.report.fuse_seconds*1e3:.1f}ms"
        )
    print(f"[train] done in {time.time()-t0:.1f}s; "
          f"loss {server.results[0].mean_client_loss:.4f} -> "
          f"{server.results[-1].mean_client_loss:.4f}")
    if args.save:
        save_pytree(args.save, server.params)
        print(f"[train] saved params to {args.save}")
    return server


if __name__ == "__main__":
    main()
