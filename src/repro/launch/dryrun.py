import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, compiles, and fits — and extract the roofline terms.

For each combo this script:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. constructs the right step (train_step / prefill / serve decode_step /
     aggregate_step) from ShapeDtypeStruct stand-ins (no allocation),
  3. ``jax.jit(fn, in_shardings=...).lower(...).compile()``,
  4. records ``memory_analysis()`` (fits per chip?), ``cost_analysis()``
     (per-device FLOPs / bytes), and collective traffic parsed from the
     optimized HLO,
  5. writes results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
  python -m repro.launch.dryrun --arch dbrx-132b --shape agg_64  # paper step
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (
    ARCHITECTURES,
    INPUT_SHAPES,
    applicable_shapes,
    get_config,
)
from repro.launch.mesh import make_production_mesh
from repro.models.runtime_flags import unrolled_layers
from repro.launch.steps import (
    decode_specs,
    make_aggregate_step,
    make_decode_step,
    make_optimizer,
    make_prefill_step,
    make_train_step,
    prefill_specs,
    train_specs,
)
from repro.utils.hlo import analyze_collectives
from repro.utils.mem import TPU_V5E, bytes_to_human

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"
)


# gradient-accumulation factors for the biggest training combos (§Perf):
# activation transients scale ~1/m at the cost of m x weight all-gathers
MICROBATCHES = {
    ("llava-next-34b", "train_4k"): 4,
    ("dbrx-132b", "train_4k"): 4,
}


def _jit_for(arch: str, shape_name: str, mesh, agg_clients: int = 64):
    """Returns (jitted fn, lower args, metadata)."""
    cfg = get_config(arch)
    meta = {
        "arch": arch,
        "shape": shape_name,
        "params": cfg.num_params(),
        "active_params": cfg.num_active_params(),
    }
    if shape_name.startswith("agg_"):
        n_clients = int(shape_name.split("_")[1])
        spec_fn = make_aggregate_step(mesh, n_clients)
        step, args, in_sh, out_sh = spec_fn(cfg)
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        meta["kind"] = "aggregate"
        return fn, args, meta

    shape = INPUT_SHAPES[shape_name]
    meta["kind"] = shape.kind
    if shape.kind == "train":
        opt = make_optimizer(cfg)
        model, args, shardings = train_specs(cfg, shape, mesh, opt)
        mb = MICROBATCHES.get((arch, shape_name), 1)
        meta["microbatches"] = mb
        step = make_train_step(model, opt, mesh, microbatches=mb)
        fn = jax.jit(step, in_shardings=shardings, donate_argnums=(0, 1))
        return fn, args, meta
    if shape.kind == "prefill":
        model, args, shardings = prefill_specs(cfg, shape, mesh)
        step = make_prefill_step(model, mesh)
        fn = jax.jit(step, in_shardings=shardings)
        return fn, args, meta
    # decode
    force_local = shape_name == "long_500k"
    model, args, shardings, out_sh = decode_specs(
        cfg, shape, mesh, force_local=force_local
    )
    step = make_decode_step(
        model, mesh, batch=shape.global_batch, force_local=force_local
    )
    fn = jax.jit(step, in_shardings=shardings, out_shardings=out_sh,
                 donate_argnums=(1,))
    return fn, args, meta


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = RESULTS_DIR, force: bool = False,
            verbose: bool = True, unroll: bool = False) -> dict:
    """``unroll=True`` unrolls layer stacks so cost_analysis counts every
    layer (XLA reports while-loop bodies once). Inner tile scans (flash
    attention, SSD chunks, the CE chunk loop) remain loops — their FLOPs
    are reconstructed analytically in the roofline (benchmarks/roofline)."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}.json"
    )
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips, "ok": False, "unrolled_layers": unroll,
    }
    import contextlib
    ctx = unrolled_layers() if unroll else contextlib.nullcontext()
    try:
        fn, args, meta = _jit_for(arch, shape_name, mesh)
        record.update(meta)
        with mesh, ctx:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = analyze_collectives(hlo)

        record.update({
            "ok": True,
            "lower_seconds": round(t_lower, 2),
            "compile_seconds": round(t_compile, 2),
            "per_device": {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
                "flops": ca.get("flops"),
                "bytes_accessed": ca.get("bytes accessed"),
            },
            "collectives": {
                "counts": coll.counts,
                "bytes_moved": coll.bytes_moved,
                "buffer_bytes": coll.buffer_bytes,
                "total_bytes": coll.total_bytes,
            },
        })
        arg_b = record["per_device"]["argument_bytes"] or 0
        tmp_b = record["per_device"]["temp_bytes"] or 0
        peak = arg_b + tmp_b
        record["per_device"]["peak_bytes_est"] = peak
        record["fits_hbm"] = bool(peak <= TPU_V5E.hbm_bytes)
        if verbose:
            print(
                f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:10s} OK  "
                f"args={bytes_to_human(arg_b)} temp={bytes_to_human(tmp_b)} "
                f"flops/dev={record['per_device']['flops'] or 0:.3e} "
                f"coll={bytes_to_human(coll.total_bytes)} "
                f"compile={t_compile:.1f}s fits_hbm={record['fits_hbm']}"
            )
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {mesh_name} FAIL: "
                  f"{record['error']}")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch, cfg in ARCHITECTURES.items():
            for shape in applicable_shapes(cfg):
                combos.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        combos = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in combos:
        rec = run_one(arch, shape, args.multi_pod, args.out_dir, args.force)
        n_fail += 0 if rec.get("ok") else 1
    print(f"[dryrun] done: {len(combos) - n_fail}/{len(combos)} OK")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
