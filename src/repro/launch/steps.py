"""Step functions lowered by the dry-run and the real launchers.

Each factory returns (fn, in_specs, out_specs?) ready for
``jax.jit(fn, in_shardings=...)`` — the same functions drive the CPU
examples (trivial mesh) and the 512-chip dry-run.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, input_specs
from repro.models import build_model
from repro.models.base import Model
from repro.models.sharding import decode_rules, train_rules, use_rules
from repro.optim import Optimizer, adamw, apply_updates
from repro.launch.shardings import (
    batch_shardings,
    cache_shardings,
    param_shardings,
)

PyTree = Any


def make_optimizer(cfg: ModelConfig) -> Optimizer:
    return adamw(lr=3e-4, b1=0.9, b2=0.95, weight_decay=0.0)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(model: Model, optimizer: Optimizer, mesh: Optional[Mesh],
                    microbatches: int = 1):
    """``microbatches > 1`` = gradient accumulation: the global batch is
    scanned in m slices, cutting activation/attention transient memory by
    ~m at the cost of re-running the per-slice weight all-gathers m times
    (the usual FSDP microbatching trade — measured in §Perf)."""
    rules = train_rules(mesh) if mesh is not None else None

    def train_step(params, opt_state, step, batch):
        with use_rules(rules):
            if microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True
                )(params, batch)
            else:
                mb = jax.tree_util.tree_map(
                    lambda a: a.reshape(
                        (microbatches, a.shape[0] // microbatches)
                        + a.shape[1:]
                    ),
                    batch,
                )

                def acc_step(carry, mbatch):
                    loss_acc, g_acc = carry
                    (l, _), g = jax.value_and_grad(
                        model.loss, has_aux=True
                    )(params, mbatch)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g
                    )
                    return (loss_acc + l, g_acc), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.zeros((), jnp.float32), g0), mb
                )
                loss = loss / microbatches
                grads = jax.tree_util.tree_map(
                    lambda g: g / microbatches, grads
                )
            ups, opt_state2 = optimizer.update(grads, opt_state, step, params)
            new_params = apply_updates(params, ups)
        return new_params, opt_state2, loss

    return train_step


def train_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                optimizer: Optimizer):
    """(arg ShapeDtypeStructs, arg NamedShardings) for train_step."""
    model = build_model(cfg)
    param_spec = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0))
    )
    opt_spec = jax.eval_shape(lambda: optimizer.init(param_spec))
    batch_spec = input_specs(cfg, shape)
    p_sh = param_shardings(param_spec, mesh)
    o_sh = _mirror_opt_shardings(opt_spec, param_spec, p_sh, mesh)
    b_sh = batch_shardings(batch_spec, mesh)
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)
    step_sh = NamedSharding(mesh, P())
    args = (param_spec, opt_spec, step_spec, batch_spec)
    shardings = (p_sh, o_sh, step_sh, b_sh)
    return model, args, shardings


def _mirror_opt_shardings(opt_spec, param_spec, param_sh, mesh):
    """Optimizer moments share their parameter's sharding."""
    flat_p, _ = jax.tree_util.tree_flatten(param_spec)
    flat_ps, _ = jax.tree_util.tree_flatten(param_sh)
    by_shape = {}
    for s, sh in zip(flat_p, flat_ps):
        by_shape.setdefault((s.shape), sh)

    def go(leaf):
        return by_shape.get(leaf.shape, NamedSharding(mesh, P()))

    return jax.tree_util.tree_map(go, opt_spec)


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model, mesh: Optional[Mesh]):
    rules = train_rules(mesh) if mesh is not None else None

    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch)

    return prefill_step


def prefill_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    model = build_model(cfg)
    param_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    batch_spec = input_specs(cfg, shape)
    return model, (param_spec, batch_spec), (
        param_shardings(param_spec, mesh),
        batch_shardings(batch_spec, mesh),
    )


def make_decode_step(model: Model, mesh: Optional[Mesh], batch: int,
                     force_local: bool = False):
    n_kv = model.config.n_kv_heads
    rules = (
        decode_rules(mesh, batch) if mesh is not None else None
    )

    def decode_step(params, cache, token, pos):
        with use_rules(rules):
            return model.decode_step(
                params, cache, token, pos, force_local=force_local
            )

    return decode_step


def decode_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 force_local: bool = False):
    model = build_model(cfg)
    param_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    B, S = shape.global_batch, shape.seq_len
    cache_spec = model.init_cache(B, S, spec_only=True,
                                  force_local=force_local)
    token_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    # decode weights: model-sharded only (no FSDP) when they fit —
    # otherwise every generated token re-all-gathers the weight shards
    # (§Perf). Models too big for model-only shards (dbrx: 263 GB bf16)
    # keep the FSDP layout.
    from repro.utils.pytree import tree_size_bytes

    # 4 GiB/chip resident-weight budget: conservative because XLA-CPU's
    # bf16->f32 dot conversions inflate measured temp; a TPU lowering
    # would admit llava-34b (4.3 GiB) resident too.
    model_n = mesh.shape.get("model", 1)
    resident_ok = tree_size_bytes(param_spec) / model_n < 4 * 2**30
    p_sh = param_shardings(param_spec, mesh, fsdp=not resident_ok)
    c_sh = cache_shardings(cache_spec, mesh, batch=B)
    t_sh = batch_shardings({"t": token_spec}, mesh)["t"]
    pos_sh = NamedSharding(mesh, P())
    # out_shardings for (new_cache, logits): the cache keeps its sharding so
    # donated input buffers alias in place (otherwise every decode step
    # copies the full KV cache — 32L x 1 GiB for minitron).
    from repro.launch.mesh import data_axis_names, n_data_shards

    dp = data_axis_names(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    dn = n_data_shards(mesh)
    model_n = mesh.shape.get("model", 1)
    logits_sh = NamedSharding(mesh, P(
        dp_spec if (B % dn == 0 and B >= dn) else None,
        "model" if cfg.vocab % model_n == 0 else None,
    ))
    out_sh = (c_sh, logits_sh)
    return model, (param_spec, cache_spec, token_spec, pos_spec), (
        p_sh, c_sh, t_sh, pos_sh
    ), out_sh


# ---------------------------------------------------------------------------
# aggregate — the paper's technique as a first-class lowered program
# ---------------------------------------------------------------------------


def make_aggregate_step(mesh: Mesh, n_clients: int):
    """FedAvg aggregation of n client updates of a model's parameters,
    sharded (clients x params) over (data-axes x model) — the paper's
    technique as a lowered program.

    shard_map + ``psum_scatter``: each device partial-sums its client
    shard, then the cross-client reduction SCATTERS the fused result over
    the data axes (half an all-reduce's ring traffic, and no chip ever
    materializes the full fused model). Leaves whose leading dim doesn't
    divide fall back to ``psum``."""
    from repro.utils.compat import shard_map
    from repro.launch.mesh import data_axis_names, n_data_shards

    dp = data_axis_names(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    dn = n_data_shards(mesh)
    data_axes = set(dp or ())
    # Few, giant clients (n < data shards — e.g. 8 x 245 GiB dbrx updates):
    # sharding the CLIENT dim is impossible/wasteful. Instead keep every
    # update FSDP-sharded over (data x model) on its PARAM dims and sum the
    # client dim locally — zero collectives, exact.
    param_sharded_mode = n_clients < dn
    if not param_sharded_mode:
        # pad the client axis to the shard multiple; padded rows carry
        # weight 0, so the weighted sum is exact
        n_clients = -(-n_clients // dn) * dn

    def _strip(sh):
        """Remove data axes from a param PartitionSpec (clients own them)."""
        stripped = []
        for entry in sh.spec:
            if entry is None:
                stripped.append(None)
            elif isinstance(entry, (tuple, list)):
                kept = tuple(a for a in entry if a not in data_axes)
                stripped.append(
                    kept if len(kept) > 1 else (kept[0] if kept else None)
                )
            else:
                stripped.append(None if entry in data_axes else entry)
        return stripped

    def specs(cfg: ModelConfig):
        model = build_model(cfg)
        p_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        stacked = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct((n_clients,) + l.shape, l.dtype),
            p_spec,
        )
        base_sh = param_shardings(p_spec, mesh)

        if param_sharded_mode:
            # clients local, params FSDP-sharded; plain jit (no shard_map)
            in_sh = (
                jax.tree_util.tree_map(
                    lambda sh: NamedSharding(mesh, P(None, *sh.spec)),
                    base_sh,
                    is_leaf=lambda x: isinstance(x, NamedSharding),
                ),
                NamedSharding(mesh, P()),
            )

            def step(u_tree, w):
                wf = w.astype(jnp.float32)
                tot = jnp.sum(wf) + 1e-6

                def leaf_fuse(u):
                    uf = u.astype(jnp.float32)
                    wb = wf.reshape((-1,) + (1,) * (uf.ndim - 1))
                    return (jnp.sum(uf * wb, axis=0) / tot).astype(u.dtype)

                return jax.tree_util.tree_map(leaf_fuse, u_tree)

            return step, (
                stacked, jax.ShapeDtypeStruct((n_clients,), jnp.float32)
            ), in_sh, base_sh
        stripped = jax.tree_util.tree_map(
            lambda sh, leaf: (
                _strip(sh) + [None] * (len(leaf.shape) - len(sh.spec))
            ),
            base_sh, p_spec,
            is_leaf=lambda x: isinstance(x, NamedSharding),
        )

        def in_spec(st):
            return P(dp_spec, *st)

        def scatter_dim(leaf_spec, st):
            """First unsharded, dn-divisible param dim (or -1: psum)."""
            for i, size in enumerate(leaf_spec.shape):
                if st[i] is None and size % dn == 0 and size >= dn:
                    return i
            return -1

        def out_spec(leaf_spec, st):
            d = scatter_dim(leaf_spec, st)
            if d < 0:
                return P(*st)
            entries = list(st)
            entries[d] = dp_spec
            return P(*entries)

        in_specs = (
            jax.tree_util.tree_map(
                in_spec, stripped, is_leaf=lambda x: isinstance(x, list)
            ),
            P(dp_spec),
        )
        out_specs = jax.tree_util.tree_map(
            out_spec, p_spec, stripped,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        scatter_tree = jax.tree_util.tree_map(
            scatter_dim, p_spec, stripped,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

        def local(u_tree, w):
            wf = w.astype(jnp.float32)
            tot = jax.lax.psum(jnp.sum(wf), dp) + 1e-6

            def leaf_fuse(u, sdim):
                uf = u.astype(jnp.float32)
                wb = wf.reshape((-1,) + (1,) * (uf.ndim - 1))
                partial = jnp.sum(uf * wb, axis=0)
                if sdim >= 0:
                    fused = jax.lax.psum_scatter(
                        partial, dp, scatter_dimension=sdim, tiled=True
                    )
                else:
                    fused = jax.lax.psum(partial, dp)
                return (fused / tot).astype(u.dtype)

            return jax.tree_util.tree_map(leaf_fuse, u_tree, scatter_tree)

        step = shard_map(
            local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        in_sh = (
            jax.tree_util.tree_map(
                lambda st: NamedSharding(mesh, P(dp_spec, *st)), stripped,
                is_leaf=lambda x: isinstance(x, list),
            ),
            NamedSharding(mesh, P(dp_spec)),
        )
        out_sh = jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), out_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        return step, (stacked, jax.ShapeDtypeStruct((n_clients,), jnp.float32)), in_sh, out_sh

    return specs
