"""Parameter/input/cache sharding assignment for the production mesh.

Weights get 2-D shardings ("model" = tensor/expert parallel, the data axes
= FSDP): a rule engine over (path, shape) with name-aware special cases
and a divisibility-checked automatic fallback. Stacked layer dims (the
scan axis) are never sharded — slicing a sharded stack inside ``scan``
would reshard every iteration.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axis_names, n_data_shards

PyTree = Any

# params under these roots are stacked along leading scan dims
_STACK_LEAD = {
    "layers": 1, "enc_layers": 1, "dec_layers": 1,
    "mamba": 1, "slstm": 1, "mlstm": 2,
}
REPLICATE_BELOW = 65536  # small leaves are replicated


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
        for p in path
    )


def spec_for_param(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    names = mesh.axis_names
    model_n = mesh.shape.get("model", 1)
    data_axes = data_axis_names(mesh)
    data_n = n_data_shards(mesh)
    size = int(np.prod(shape)) if shape else 1

    if size < REPLICATE_BELOW or not shape:
        return P()

    root = path.split("/")[0]
    lead = _STACK_LEAD.get(root, 0)
    last = path.split("/")[-1]

    # -- special cases -------------------------------------------------------
    def _fits(dim: int, axis: int) -> bool:
        return dim % axis == 0 and dim >= axis

    if last == "embed":              # (vocab, d): vocab-sharded table
        if "model" in names and _fits(shape[0], model_n):
            return P("model", None)
        if "model" in names and _fits(shape[1], model_n):
            return P(None, "model")  # odd vocab (whisper): shard d instead
        return P(None, None)
    if last == "head":               # (d, vocab): logits vocab-sharded
        if "model" in names and _fits(shape[1], model_n):
            return P(None, "model")
        if "model" in names and _fits(shape[0], model_n):
            return P("model", None)
        return P(None, None)

    spec: list = [None] * len(shape)
    free = [i for i in range(lead, len(shape))]

    def assign(axis_name: str, axis_size: int, prefer: Optional[int],
               from_end: bool):
        if axis_name not in names or axis_size <= 1:
            return
        cands = []
        if prefer is not None and prefer in free and \
                shape[prefer] % axis_size == 0 and shape[prefer] >= axis_size:
            cands = [prefer]
        else:
            idxs = list(reversed(free)) if from_end else list(free)
            cands = [
                i for i in idxs
                if shape[i] % axis_size == 0 and shape[i] >= axis_size
            ]
        if cands:
            i = cands[0]
            spec[i] = axis_name
            free.remove(i)

    # attention projections (L, d, n_heads, hd): prefer heads for "model"
    prefer_model = None
    if re.search(r"(attn|xattn)/w[qkv]$", path) and len(shape) == 2 + lead:
        prefer_model = lead + 1          # the heads dim
    if re.search(r"(attn|xattn)/w[qkv]$", path) and len(shape) == 3 + lead:
        prefer_model = lead + 1
    if re.search(r"(attn|xattn)/wo$", path) and len(shape) == 3 + lead:
        prefer_model = lead              # (nq, hd, d): heads dim
    if "/moe/" in path and last in ("w_gate", "w_up", "w_down"):
        prefer_model = lead              # expert dim -> expert parallelism

    assign("model", model_n, prefer_model, from_end=True)
    # FSDP over the (pod, data) product on the first remaining eligible dim
    if len(data_axes) == 1:
        assign(data_axes[0], data_n, None, from_end=False)
    elif len(data_axes) == 2:
        cands = [
            i for i in free
            if shape[i] % data_n == 0 and shape[i] >= data_n
        ]
        if cands:
            spec[cands[0]] = data_axes
            free.remove(cands[0])
        else:
            # try just the larger "data" axis
            assign("data", mesh.shape.get("data", 1), None, from_end=False)
    return P(*spec)


def param_shardings(spec_tree: PyTree, mesh: Mesh,
                    fsdp: bool = True) -> PyTree:
    """tree of ShapeDtypeStructs -> tree of NamedShardings.

    ``fsdp=False`` strips the data axes (weights shard over "model" only,
    replicated across data): the DECODE layout — FSDP'd weights would be
    all-gathered on every generated token, which the roofline shows
    dominating the per-token collective term."""
    data_axes = set(data_axis_names(mesh))

    def strip(spec: P) -> P:
        entries = []
        for e in spec:
            if e is None:
                entries.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a not in data_axes)
                entries.append(
                    kept if len(kept) > 1 else (kept[0] if kept else None)
                )
            else:
                entries.append(None if e in data_axes else e)
        return P(*entries)

    def go(path, leaf):
        spec = spec_for_param(_path_str(path), leaf.shape, mesh)
        if not fsdp:
            spec = strip(spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(go, spec_tree)


def batch_shardings(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    """Train/prefill inputs: batch dim over the data axes."""
    dp = data_axis_names(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    dn = n_data_shards(mesh)

    def go(leaf):
        if leaf.shape and leaf.shape[0] % dn == 0 and leaf.shape[0] >= dn:
            return NamedSharding(
                mesh, P(dp_spec, *([None] * (len(leaf.shape) - 1)))
            )
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree_util.tree_map(go, spec_tree)


def cache_shardings(spec_tree: PyTree, mesh: Mesh, batch: int) -> PyTree:
    """Decode caches: batch over the data axes when divisible, else the
    cache sequence dim (context parallelism); the last divisible feature
    dim (kv heads, else head_dim; SSM channels/state) over model — the
    32k caches are hundreds of GB and MUST shard on both mesh axes."""
    dp = data_axis_names(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    dn = n_data_shards(mesh)
    model_n = mesh.shape.get("model", 1)
    batch_ok = batch % dn == 0 and batch >= dn

    def go(path, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        used_data = False
        if shape and shape[0] == batch and batch_ok:
            spec[0] = dp_spec
            used_data = True
        # attention KV ring buffers: (B, S, n_kv, hd) with a long S dim.
        # Context-parallel layout: S over model (+ data when batch isn't
        # shardable). Sharding n_kv/hd instead forces an SPMD reshard
        # against the head-sharded q — XLA replicates the cache per layer.
        is_attn_kv = len(shape) == 4 and shape[1] >= 2048
        if is_attn_kv:
            axes = [] if used_data else list(dp)
            axes.append("model")
            total = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[1] % total == 0 and shape[1] >= total:
                spec[1] = tuple(axes) if len(axes) > 1 else axes[0]
            elif shape[1] % model_n == 0 and shape[1] >= model_n:
                spec[1] = "model"
            return NamedSharding(mesh, P(*spec))
        if not used_data and len(shape) >= 2:
            if shape[1] % dn == 0 and shape[1] >= dn:
                spec[1] = dp_spec  # context parallelism
        # SSM/recurrent states: model axis on the last divisible feature dim
        for i in range(len(shape) - 1, 0, -1):
            if spec[i] is None and shape[i] % model_n == 0 \
                    and shape[i] >= model_n:
                spec[i] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(go, spec_tree)
