"""Batched serving driver: prefill a batch of prompts, then decode with
per-layer KV/state caches (CPU-runnable on reduced configs).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def generate(model, params, prompt: jnp.ndarray, n_new: int,
             cache_len: int, temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature decode. prompt: (B, T0) int32."""
    B, T0 = prompt.shape
    cache = model.init_cache(B, cache_len)
    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos)
    )
    rng = jax.random.PRNGKey(seed)
    toks = [prompt]
    logits = None
    # teacher-forced prefill through the decode path (cache warmup)
    for t in range(T0):
        cache, logits = step(params, cache, prompt[:, t: t + 1],
                             jnp.int32(t))
    cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [cur]
    for i in range(n_new - 1):
        cache, logits = step(params, cache, cur, jnp.int32(T0 + i))
        if temperature > 0:
            rng, k = jax.random.split(rng)
            cur = jax.random.categorical(
                k, logits / temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(cur)
    return jnp.concatenate(toks + out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32,
    )
    t0 = time.time()
    out = generate(model, params, prompt, args.tokens, args.cache_len,
                   args.temperature)
    dt = time.time() - t0
    total_new = args.batch * args.tokens
    print(f"[serve] arch={cfg.arch_id} batch={args.batch} "
          f"new_tokens={args.tokens} -> {total_new/dt:.1f} tok/s (CPU)")
    print("[serve] sample token ids:", np.asarray(out[0, :24]).tolist())


if __name__ == "__main__":
    main()
