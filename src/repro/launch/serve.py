"""Serve the aggregator over HTTP: the Edge ingest front-end + fair
round scheduling, driven end to end by a replayed workload trace.

  PYTHONPATH=src python -m repro.launch.serve --tenants 2 --clients 12 \
      --dim 4000 --rounds 2 --spread 0.3

Starts an ``EdgeAggregatorServer`` (token-authenticated uploads,
per-tenant rate limits, quota pre-checks, batched IngestQueue commits
— ``repro.serving``, docs/SERVING.md), then replays a seeded
``WorkloadSpec`` trace where every client is a REAL HTTP uploader
(``HttpStoreClient`` over a socket, one keep-alive connection per
tenant writer), and runs each tenant's round through the weighted-fair
scheduler while uploads are still landing.

``--compress`` uploads int8 codes + fp32 scales frames instead of
dense fp32; ``--rate``/``--burst`` turn on per-tenant token buckets
(shed uploads retry on Retry-After and still land — watch the
``shed_429`` counter); ``--quota-updates``/``--quota-bytes`` install
store quotas that both the admission gate and the store enforce.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import AggregationService, UpdateStore
from repro.fl import EdgeAggregatorServer
from repro.serving import HttpStoreClient
from repro.utils.mem import bytes_to_human
from repro.workload import (
    FixedSize,
    RegimeSchedule,
    UniformArrivals,
    WorkloadSpec,
    start_writer,
)


def build_spec(args) -> WorkloadSpec:
    return WorkloadSpec(
        tenants=tuple(f"app{i}" for i in range(args.tenants)),
        n_clients=args.clients,
        rounds=args.rounds,
        regimes=RegimeSchedule.single(
            UniformArrivals(spread=args.spread)
        ),
        sizes=FixedSize(dim=args.dim),
    )


def main():
    ap = argparse.ArgumentParser(
        description="HTTP ingest front-end + fair round scheduling "
                    "over one AggregationService (docs/SERVING.md)."
    )
    ap.add_argument("--tenants", type=int, default=2,
                    help="tenant count (tokens are tok-app0, tok-app1, "
                         "...)")
    ap.add_argument("--clients", type=int, default=12,
                    help="HTTP uploaders per tenant per round")
    ap.add_argument("--dim", type=int, default=4_000,
                    help="update parameter count P")
    ap.add_argument("--rounds", type=int, default=1)
    ap.add_argument("--spread", type=float, default=0.3,
                    help="seconds each round's uploads are spread over")
    ap.add_argument("--compress", action="store_true",
                    help="upload int8 codes + fp32 scales frames "
                         "(client-side quantization, error feedback)")
    ap.add_argument("--fusion", default="fedavg")
    ap.add_argument("--threshold-frac", type=float, default=1.0,
                    help="close the round at this fraction of clients")
    ap.add_argument("--timeout", type=float, default=10.0,
                    help="round gate deadline")
    ap.add_argument("--max-running", type=int, default=2,
                    help="rounds admitted concurrently by the fair "
                         "scheduler")
    ap.add_argument("--rate", type=float, default=None,
                    help="per-tenant upload token-bucket rate "
                         "(uploads/s; None disables rate limiting)")
    ap.add_argument("--burst", type=float, default=None,
                    help="token-bucket burst (defaults to --rate)")
    ap.add_argument("--quota-updates", type=int, default=None,
                    help="per-tenant resident-update quota on the store")
    ap.add_argument("--quota-bytes", type=int, default=None,
                    help="per-tenant resident-byte quota on the store")
    ap.add_argument("--queue-size", type=int, default=256,
                    help="IngestQueue bound (backpressure horizon)")
    ap.add_argument("--batch-max", type=int, default=32,
                    help="max uploads per batched store commit")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0: ephemeral)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    store = UpdateStore()
    svc = AggregationService(
        fusion=args.fusion, store=store, local_strategy="jnp",
        threshold_frac=args.threshold_frac,
        monitor_timeout=args.timeout, compress=args.compress,
    )
    tenants = [f"app{i}" for i in range(args.tenants)]
    tokens = {f"tok-{t}": t for t in tenants}
    if args.quota_updates is not None or args.quota_bytes is not None:
        for t in tenants:
            store.set_quota(t, max_updates=args.quota_updates,
                            max_bytes=args.quota_bytes,
                            policy="reject")
    trace = build_spec(args).build(args.seed)
    with EdgeAggregatorServer(
        svc, tokens, port=args.port, max_running=args.max_running,
        rate=args.rate, burst=args.burst,
        queue_size=args.queue_size, batch_max=args.batch_max,
    ) as edge:
        print(f"[serve] listening on {edge.url} tenants={tenants} "
              f"dim={args.dim} "
              f"frame={'int8+scales' if args.compress else 'fp32'}")
        for rt in trace.rounds:
            t0 = time.time()
            writers = []
            for tr in rt.tenants:
                cli = HttpStoreClient(
                    "127.0.0.1", edge.port, token=f"tok-{tr.tenant}",
                )
                transform = (
                    (lambda cid, u, _t=tr.tenant:
                     svc.compress_update(cid, u, tenant=_t))
                    if args.compress else None
                )
                writers.append(start_writer(
                    None, tr, args.seed, transform=transform,
                    writer=cli.write,
                ))
            results = edge.run_rounds(
                [tr.tenant for tr in rt.tenants],
                expected_clients=args.clients,
            )
            for w in writers:
                w.join()
            for t, (fused, report) in sorted(results.items()):
                print(f"[serve] round={rt.index} tenant={t} "
                      f"engine={report.plan.engine} "
                      f"included={report.n_clients}/{args.clients} "
                      f"ingest={bytes_to_human(report.bytes_ingested)} "
                      f"fuse={report.fuse_seconds:.3f}s "
                      f"fused[:3]={np.asarray(fused[:3])}")
            store.clear()   # synchronous rounds don't consume
            print(f"[serve] round={rt.index} wall="
                  f"{time.time() - t0:.2f}s")
        m = edge.metrics()
        uploads = m.get("accepted", 0)
        print(f"[serve] uploads={uploads} batches={m.get('batches', 0)} "
              f"max_batch={m.get('max_batch', 0)} "
              f"shed_429={m.get('shed_429', 0)} "
              f"backpressure={m.get('backpressure', 0)} "
              f"admission_order={edge.scheduler.admission_order()}")


if __name__ == "__main__":
    main()
