"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests must see
the real single CPU device).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def data_axis_names(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_data_shards(mesh: Mesh) -> int:
    n = 1
    for a in data_axis_names(mesh):
        n *= mesh.shape[a]
    return n
