"""Standalone aggregation driver over the paper's Table-I CNN workloads.

Simulates n clients writing updates of a chosen model size to the
UpdateStore, runs the monitor, and fuses with the adaptive service —
the paper's end-to-end flow (Fig. 12/13) in one command.

  PYTHONPATH=src python -m repro.launch.aggregate --model CNN4.6 \
      --clients 64 --fusion fedavg

``--async-rounds`` overlaps fusion with the straggler wait: a writer
thread spreads client arrivals over ``--spread`` seconds while the
service folds partial sums off the arrival stream (Algorithm 1 with the
monitor inside the ingest loop).

``--adaptive`` enables the learned gate: the controller records each
round's arrival curve and replaces the static ``--threshold-frac`` /
``--timeout`` gate with a learned threshold/deadline that optimizes the
``--cost-bias`` knob (0 = fastest rounds, 1 = maximum update inclusion).
Run several ``--rounds`` to watch the policy move from ``static`` to
``learned`` as the curve accumulates — the report line prints the gate
each round used, labeled with its tenant.

``--compress`` turns on quantized transport: every client write is
int8 block-quantized with per-tenant error feedback
(``repro.core.compress``) before it hits the store, and the round
streams codes + scales through the engines' dequant-folding step —
~4x fewer ingest bytes at one quantization step of error. The report
line's ``ingest=`` field shows the actual payload bytes fused.

``--tenant`` tags every write and round with a tenant label (store
partition + service continuity key). ``--concurrent-tenants K`` runs K
tenants' rounds GENUINELY CONCURRENTLY on ONE store and ONE service:
a ``RoundScheduler`` worker per tenant executes all K rounds at once
(device execution bounded by ``--device-concurrency``, default 1),
their writers land interleaved while every round is open, and each
round folds only its own tenant's partition — watch the per-tenant
report lines show full inclusion and ``compile=0.000s`` for every
tenant after the first (single-flight compile cache: K racing tenants
pay ONE cold compile). ``--quota-updates`` / ``--quota-bytes`` /
``--quota-policy`` install a per-tenant capacity quota on the shared
store (the noisy-neighbor bound; see docs/MULTITENANCY.md).
"""
from __future__ import annotations

import argparse
import threading
import time
import zlib

import numpy as np

from repro.configs import CNN_SUITE
from repro.core import (
    AggregationService,
    QuotaExceededError,
    RoundScheduler,
    UpdateStore,
    Workload,
    classify,
)
from repro.utils.mem import bytes_to_human


def _report_line(report, gate: str) -> str:
    """One round's outcome, labeled with its tenant so interleaved
    multi-tenant logs stay unambiguous."""
    st = report.store_stats
    stats = (f" writes={st.writes} wbytes={st.bytes_written}"
             f" evictions={st.evictions}") if st is not None else ""
    for note in report.notes:
        stats += f" note={note!r}"
    return (f"[aggregate] tenant={report.tenant} "
            f"engine={report.plan.engine} "
            f"class={report.plan.workload_class.value} "
            f"streamed={report.streamed} "
            f"monitor_ready={report.monitor.ready} "
            f"gate={gate} "
            f"ingest={bytes_to_human(report.bytes_ingested)} "
            f"fuse={report.fuse_seconds:.3f}s "
            f"overlap={report.overlap_seconds:.3f}s "
            f"compile={report.phase_seconds.get('compile', 0.0):.3f}s "
            f"est={report.plan.est_seconds:.4f}s(model) "
            f"route_next_to_store={report.route_next_to_store}"
            + stats)


def _gate_str(report) -> str:
    pol = report.close_policy
    if not pol:
        return "static"
    return (f"{pol.source}(frac={pol.threshold_frac:.2f} "
            f"deadline={pol.deadline:.2f}s)")


def main():
    ap = argparse.ArgumentParser(
        description="End-to-end aggregation rounds over the UpdateStore "
                    "(paper Fig. 12/13)."
    )
    ap.add_argument("--model", default="CNN4.6", choices=sorted(CNN_SUITE),
                    help="Table-I CNN workload (sets the update size)")
    ap.add_argument("--clients", type=int, default=32,
                    help="simulated clients writing one update each "
                         "(per tenant)")
    ap.add_argument("--fusion", default="fedavg",
                    help="fusion algorithm (repro.core.fusion.REGISTRY)")
    ap.add_argument("--local-strategy", default="jnp",
                    help='single-chip engine: "jnp" or "pallas"')
    ap.add_argument("--compress", action="store_true",
                    help="quantize client writes to int8 codes + fp32 "
                         "per-block scales (error feedback per tenant); "
                         "rounds stream them through the dequant-folding "
                         "step — ~4x fewer ingest bytes")
    ap.add_argument("--threshold-frac", type=float, default=0.8,
                    help="static gate: close at this fraction of clients")
    ap.add_argument("--timeout", type=float, default=5.0,
                    help="static gate deadline (and learned-deadline cap)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--async-rounds", action="store_true",
                    help="fold arrivals while stragglers write "
                         "(monitor-overlapped round)")
    ap.add_argument("--spread", type=float, default=1.0,
                    help="seconds over which async-round client arrivals "
                         "are spread")
    ap.add_argument("--adaptive", action="store_true",
                    help="learn the arrival curve and close rounds with "
                         "the adaptive controller's policy")
    ap.add_argument("--cost-bias", type=float, default=0.5,
                    help="adaptive knob in [0,1]: 0 optimizes round "
                         "wall-clock, 1 optimizes update inclusion")
    ap.add_argument("--rounds", type=int, default=1,
                    help="rounds to run (adaptive gates need >1 to learn)")
    ap.add_argument("--tenant", default="default",
                    help="tenant label for writes and rounds (store "
                         "partition + service continuity key)")
    ap.add_argument("--concurrent-tenants", type=int, default=0,
                    help="run this many tenants' rounds CONCURRENTLY on "
                         "ONE shared store/service via the RoundScheduler "
                         "(overrides --tenant; writers for all tenants "
                         "run while every round is open)")
    ap.add_argument("--device-concurrency", type=int, default=1,
                    help="bound on concurrent device execution across "
                         "tenants' rounds (the scheduler's hardware "
                         "semaphore; 1 serializes folds, waits overlap)")
    ap.add_argument("--quota-updates", type=int, default=None,
                    help="per-tenant resident-update budget on the "
                         "shared store (None: unbounded)")
    ap.add_argument("--quota-bytes", type=int, default=None,
                    help="per-tenant resident-byte budget on the shared "
                         "store (None: unbounded)")
    ap.add_argument("--quota-policy", default="reject",
                    choices=["reject", "evict"],
                    help="over-budget writes: reject (raise) or evict "
                         "the tenant's oldest resident updates")
    args = ap.parse_args()

    spec = CNN_SUITE[args.model]
    n_params = spec.num_params
    store = UpdateStore()
    svc = AggregationService(
        fusion=args.fusion, store=store,
        local_strategy=args.local_strategy,
        threshold_frac=args.threshold_frac, monitor_timeout=args.timeout,
        adaptive=args.adaptive, cost_bias=args.cost_bias,
        compress=args.compress,
        device_concurrency=args.device_concurrency,
    )
    tenants = (
        [f"app{i}" for i in range(args.concurrent_tenants)]
        if args.concurrent_tenants else [args.tenant]
    )
    if args.quota_updates is not None or args.quota_bytes is not None:
        for t in tenants:
            store.set_quota(
                t, max_updates=args.quota_updates,
                max_bytes=args.quota_bytes, policy=args.quota_policy,
            )
    scheduler = (
        RoundScheduler(svc) if args.concurrent_tenants else None
    )
    overlapped = args.async_rounds or args.adaptive \
        or args.concurrent_tenants > 0
    # classify on the REAL wire size: --compress rounds move int8
    # codes + scales, ~4x smaller than fp32 — at fp32 bytes the banner
    # could report DISTRIBUTED for work that fits one chip's HBM
    load = Workload.for_params(n_params, args.clients,
                               compressed=args.compress)
    print(f"[aggregate] model={args.model} w_s={bytes_to_human(load.update_bytes)} "
          f"n={args.clients} S={bytes_to_human(load.total_bytes)} "
          f"class={classify(load).value}"
          + (f" adaptive(cost_bias={args.cost_bias})" if args.adaptive
             else "")
          + (f" tenants={tenants}" if len(tenants) > 1 else ""))

    for rnd in range(args.rounds):
        t0 = time.time()
        write_lat = []
        rejected = []

        def write_all(tenant):
            pause = args.spread / max(args.clients, 1) if overlapped else 0.0
            # crc32, not hash(): per-tenant streams must stay
            # reproducible across processes under one --seed — and
            # unreduced, so distinct tenant labels get distinct streams
            trng = np.random.default_rng(
                args.seed + rnd * 1009 + zlib.crc32(tenant.encode())
            )
            for i in range(args.clients):
                if pause:
                    time.sleep(pause)
                u = trng.normal(size=(n_params,)).astype(np.float32)
                if args.compress:
                    # client-side quantization: spool int8 codes + fp32
                    # scales; the residual stays with the client (EF)
                    u = svc.compress_update(f"client{i:05d}", u,
                                            tenant=tenant)
                try:
                    write_lat.append(
                        store.write(f"client{i:05d}", u,
                                    weight=float(trng.integers(1, 100)),
                                    tenant=tenant)
                    )
                except QuotaExceededError:
                    # reject policy: the write is refused, the writer
                    # keeps going — the round closes on whatever the
                    # quota admitted (reported below)
                    rejected.append(tenant)

        if overlapped:
            # arrivals land WHILE rounds are open (the overlapped round,
            # or a serialized monitor wait the controller can actually
            # observe an arrival curve from) — with several tenants,
            # every tenant's writer runs under every tenant's round
            writers = [
                threading.Thread(target=write_all, args=(t,), daemon=True)
                for t in tenants
            ]
            for w in writers:
                w.start()
            if scheduler is not None:
                # truly concurrent execution: every tenant's round runs
                # NOW on its scheduler worker — monitor waits overlap,
                # device folds share the execution semaphore
                results = scheduler.run_round(
                    tenants, from_store=True,
                    expected_clients=args.clients,
                    async_round=args.async_rounds,
                )
                reports = [results[t] for t in tenants]
            else:
                reports = [
                    svc.aggregate(from_store=True,
                                  expected_clients=args.clients,
                                  async_round=args.async_rounds,
                                  tenant=t)
                    for t in tenants
                ]
            for w in writers:
                w.join()
        else:
            for t in tenants:
                write_all(t)
            reports = [
                svc.aggregate(from_store=True,
                              expected_clients=args.clients, tenant=t)
                for t in tenants
            ]
        if not args.async_rounds:
            for t in tenants:
                store.clear(tenant=t)   # serialized rounds don't consume
        avg_write = np.mean(write_lat) * 1e3 if write_lat else 0.0
        print(f"[aggregate] round={rnd} {len(write_lat)} updates written "
              f"(modeled avg write {avg_write:.1f} ms, "
              f"wall {time.time()-t0:.2f}s)"
              + (f" [{len(rejected)} writes rejected by quota]"
                 if rejected else ""))
        for fused, report in reports:
            if report.empty:
                print(f"[aggregate] tenant={report.tenant} empty round "
                      "(monitor timed out with no arrivals)")
                continue
            print(_report_line(report, _gate_str(report)))
            print(f"[aggregate] tenant={report.tenant} "
                  f"fused[:5]={np.asarray(fused[:5])}")
    if scheduler is not None:
        scheduler.shutdown()


if __name__ == "__main__":
    main()
