"""Pure-jnp oracle for the robust (coordinate-wise) fusion kernel."""
from __future__ import annotations

import jax.numpy as jnp


def coordmedian_ref(updates: jnp.ndarray) -> jnp.ndarray:
    """(n, P) -> (P,) per-coordinate median (fp32)."""
    return jnp.median(updates.astype(jnp.float32), axis=0)


def trimmedmean_ref(updates: jnp.ndarray, trim: int) -> jnp.ndarray:
    """(n, P) -> (P,) mean of each coordinate with the ``trim`` smallest
    and largest values dropped."""
    n = updates.shape[0]
    s = jnp.sort(updates.astype(jnp.float32), axis=0)
    if trim > 0:
        s = s[trim: n - trim]
    return jnp.mean(s, axis=0)


def topk_carve_ref(block, valid, ssum, topk, botk):
    """Oracle for the streaming carve fold: merge a (c, P) block into
    carry (ssum (P,), topk (K, P) ascending, botk (K, P) ascending).
    Rows with valid == 0 are masked to -/+inf and never survive."""
    u = block.astype(jnp.float32)
    k_cap = topk.shape[0]
    vm = (valid > 0)[:, None]
    ssum = ssum + jnp.sum(jnp.where(vm, u, 0.0), axis=0)
    hi = jnp.where(vm, u, -jnp.inf)
    topk = jnp.sort(jnp.concatenate([topk, hi], axis=0), axis=0)[-k_cap:]
    lo = jnp.where(vm, u, jnp.inf)
    botk = jnp.sort(jnp.concatenate([botk, lo], axis=0), axis=0)[:k_cap]
    return ssum, topk, botk
