"""Pure-jnp oracle for the robust (coordinate-wise) fusion kernel."""
from __future__ import annotations

import jax.numpy as jnp


def coordmedian_ref(updates: jnp.ndarray) -> jnp.ndarray:
    """(n, P) -> (P,) per-coordinate median (fp32)."""
    return jnp.median(updates.astype(jnp.float32), axis=0)


def trimmedmean_ref(updates: jnp.ndarray, trim: int) -> jnp.ndarray:
    """(n, P) -> (P,) mean of each coordinate with the ``trim`` smallest
    and largest values dropped."""
    n = updates.shape[0]
    s = jnp.sort(updates.astype(jnp.float32), axis=0)
    if trim > 0:
        s = s[trim: n - trim]
    return jnp.mean(s, axis=0)
