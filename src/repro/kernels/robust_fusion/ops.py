"""Jit'd public wrappers for the robust-fusion kernels."""
from repro.kernels.robust_fusion.kernel import (
    coordmedian_pallas,
    trimmedmean_pallas,
)

__all__ = ["coordmedian_pallas", "trimmedmean_pallas"]
