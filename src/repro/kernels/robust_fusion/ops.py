"""Jit'd public wrappers for the robust-fusion kernels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.robust_fusion.kernel import (
    coordmedian_pallas,
    topk_carve_pallas,
    trimmedmean_pallas,
)
from repro.kernels.robust_fusion.ref import topk_carve_ref

__all__ = [
    "coordmedian_pallas",
    "trimmedmean_pallas",
    "topk_carve_pallas",
    "topk_carve_ref",
    "carve_stream_dense",
]


def carve_stream_dense(updates, trim: int, *, chunk: int = 8,
                       use_pallas: bool = True, interpret: bool = True):
    """Dense-parity harness: stream a dense (n, P) matrix through the
    carve fold in (chunk, P) blocks and finalize. Must equal
    ``trimmedmean_ref(updates, trim)`` (trim = (n-1)//2 gives the
    median) — used by tests to pin the streamed path to the oracle."""
    n, p = updates.shape
    if not 2 * trim < n:
        raise ValueError(f"trim {trim} too large for n={n}")
    k_cap = max(trim, 1)
    ssum = jnp.zeros((p,), jnp.float32)
    topk = jnp.full((k_cap, p), -jnp.inf, jnp.float32)
    botk = jnp.full((k_cap, p), jnp.inf, jnp.float32)
    fold = topk_carve_pallas if use_pallas else topk_carve_ref
    kw = {"interpret": interpret} if use_pallas else {}
    for i in range(0, n, chunk):
        blk = updates[i: i + chunk]
        rows = blk.shape[0]
        if rows < chunk:  # ragged tail: zero rows masked out by valid
            blk = jnp.pad(blk, ((0, chunk - rows), (0, 0)))
        valid = (jnp.arange(chunk) < rows).astype(jnp.float32)
        ssum, topk, botk = fold(blk, valid, ssum, topk, botk, **kw)
    s = ssum
    if trim > 0:
        s = s - jnp.sum(topk[k_cap - trim:], axis=0)
        s = s - jnp.sum(botk[:trim], axis=0)
    return s / float(n - 2 * trim)
