"""Pallas TPU kernel: tiled coordinate-wise median / trimmed mean.

Robust fusions need every client's value per coordinate, so the tiling is
columnar: each grid step loads a (n x PARAM_TILE) strip into VMEM, sorts
along the client axis in-register, and emits the statistic for that strip.
One HBM pass; n is bounded by VMEM (n * PARAM_TILE * 4 bytes <= ~8 MiB for
the default tile), which is exactly the VMEM_RESIDENT workload class —
larger n goes through the distributed engine's all-to-all path instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PARAM_TILE = 1024


def _trimmed_kernel(u_ref, out_ref, *, trim: int):
    u = u_ref[...].astype(jnp.float32)          # (n, TP)
    n = u.shape[0]
    s = jnp.sort(u, axis=0)
    if trim > 0:
        s = jax.lax.slice_in_dim(s, trim, n - trim, axis=0)
    out_ref[...] = jnp.mean(s, axis=0, keepdims=True)


def _exact_median_kernel(u_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)
    n = u.shape[0]
    s = jnp.sort(u, axis=0)
    mid = n // 2
    if n % 2 == 1:
        med = s[mid]
    else:
        med = 0.5 * (s[mid - 1] + s[mid])
    out_ref[...] = med[None, :]


@functools.partial(jax.jit, static_argnames=("param_tile", "interpret"))
def coordmedian_pallas(updates: jnp.ndarray, *, param_tile: int = PARAM_TILE,
                       interpret: bool = True) -> jnp.ndarray:
    n, P = updates.shape
    tp = min(param_tile, P)
    p_pad = (-P) % tp
    if p_pad:
        updates = jnp.pad(updates, ((0, 0), (0, p_pad)))
    PP = updates.shape[1]
    out = pl.pallas_call(
        _exact_median_kernel,
        grid=(PP // tp,),
        in_specs=[pl.BlockSpec((n, tp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, PP), jnp.float32),
        interpret=interpret,
    )(updates)
    return out[0, :P]


@functools.partial(
    jax.jit, static_argnames=("trim", "param_tile", "interpret")
)
def trimmedmean_pallas(updates: jnp.ndarray, trim: int,
                       *, param_tile: int = PARAM_TILE,
                       interpret: bool = True) -> jnp.ndarray:
    n, P = updates.shape
    tp = min(param_tile, P)
    p_pad = (-P) % tp
    if p_pad:
        updates = jnp.pad(updates, ((0, 0), (0, p_pad)))
    PP = updates.shape[1]
    out = pl.pallas_call(
        functools.partial(_trimmed_kernel, trim=trim),
        grid=(PP // tp,),
        in_specs=[pl.BlockSpec((n, tp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, PP), jnp.float32),
        interpret=interpret,
    )(updates)
    return out[0, :P]
