"""Pallas TPU kernel: tiled coordinate-wise median / trimmed mean.

Robust fusions need every client's value per coordinate, so the tiling is
columnar: each grid step loads a (n x PARAM_TILE) strip into VMEM, sorts
along the client axis in-register, and emits the statistic for that strip.
One HBM pass; n is bounded by VMEM (n * PARAM_TILE * 4 bytes <= ~8 MiB for
the default tile), which is exactly the VMEM_RESIDENT workload class —
larger n goes through the distributed engine's all-to-all path instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PARAM_TILE = 1024


def _trimmed_kernel(u_ref, out_ref, *, trim: int):
    u = u_ref[...].astype(jnp.float32)          # (n, TP)
    n = u.shape[0]
    s = jnp.sort(u, axis=0)
    if trim > 0:
        s = jax.lax.slice_in_dim(s, trim, n - trim, axis=0)
    out_ref[...] = jnp.mean(s, axis=0, keepdims=True)


def _exact_median_kernel(u_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)
    n = u.shape[0]
    s = jnp.sort(u, axis=0)
    mid = n // 2
    if n % 2 == 1:
        med = s[mid]
    else:
        med = 0.5 * (s[mid - 1] + s[mid])
    out_ref[...] = med[None, :]


@functools.partial(jax.jit, static_argnames=("param_tile", "interpret"))
def coordmedian_pallas(updates: jnp.ndarray, *, param_tile: int = PARAM_TILE,
                       interpret: bool = True) -> jnp.ndarray:
    n, P = updates.shape
    tp = min(param_tile, P)
    p_pad = (-P) % tp
    if p_pad:
        updates = jnp.pad(updates, ((0, 0), (0, p_pad)))
    PP = updates.shape[1]
    out = pl.pallas_call(
        _exact_median_kernel,
        grid=(PP // tp,),
        in_specs=[pl.BlockSpec((n, tp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, PP), jnp.float32),
        interpret=interpret,
    )(updates)
    return out[0, :P]


def _carve_kernel(v_ref, u_ref, s_ref, t_ref, b_ref,
                  so_ref, to_ref, bo_ref):
    """Merge one (c, TP) block strip into the carried running sum and
    per-coordinate top-K / bottom-K buffers. ``v_ref`` is the (1, c)
    validity row — 0 marks ragged-tail padding rows, which are masked to
    -/+inf so the sort carries them straight out of the kept slices.
    One sort per buffer per strip; one HBM pass over the block."""
    u = u_ref[...].astype(jnp.float32)                     # (c, TP)
    vm = v_ref[...].reshape(-1, 1) > 0                     # (c, 1)
    so_ref[...] = s_ref[...] + jnp.sum(
        jnp.where(vm, u, 0.0), axis=0, keepdims=True)
    k_cap = t_ref.shape[0]
    m = k_cap + u.shape[0]
    hi = jnp.sort(jnp.concatenate(
        [t_ref[...], jnp.where(vm, u, -jnp.inf)], axis=0), axis=0)
    to_ref[...] = jax.lax.slice_in_dim(hi, m - k_cap, m, axis=0)
    lo = jnp.sort(jnp.concatenate(
        [b_ref[...], jnp.where(vm, u, jnp.inf)], axis=0), axis=0)
    bo_ref[...] = jax.lax.slice_in_dim(lo, 0, k_cap, axis=0)


@functools.partial(jax.jit, static_argnames=("param_tile", "interpret"))
def topk_carve_pallas(block: jnp.ndarray, valid: jnp.ndarray,
                      ssum: jnp.ndarray, topk: jnp.ndarray,
                      botk: jnp.ndarray, *, param_tile: int = PARAM_TILE,
                      interpret: bool = True):
    """Streaming fold for exact trimmed mean / median: merge a (c, P)
    block into carry (ssum (P,), topk (K, P), botk (K, P)). ``valid``
    (c,) is 0/1 (0 = padded row). Returns the updated carry triple."""
    c, P = block.shape
    k_cap = topk.shape[0]
    tp = min(param_tile, P)
    p_pad = (-P) % tp
    if p_pad:
        # zero-pad the param axis; padded columns produce garbage carry
        # values that the [:P] slices below discard
        block = jnp.pad(block, ((0, 0), (0, p_pad)))
        ssum = jnp.pad(ssum, (0, p_pad))
        topk = jnp.pad(topk, ((0, 0), (0, p_pad)))
        botk = jnp.pad(botk, ((0, 0), (0, p_pad)))
    PP = P + p_pad
    so, to, bo = pl.pallas_call(
        _carve_kernel,
        grid=(PP // tp,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((c, tp), lambda i: (0, i)),
            pl.BlockSpec((1, tp), lambda i: (0, i)),
            pl.BlockSpec((k_cap, tp), lambda i: (0, i)),
            pl.BlockSpec((k_cap, tp), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, tp), lambda i: (0, i)),
            pl.BlockSpec((k_cap, tp), lambda i: (0, i)),
            pl.BlockSpec((k_cap, tp), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, PP), jnp.float32),
            jax.ShapeDtypeStruct((k_cap, PP), jnp.float32),
            jax.ShapeDtypeStruct((k_cap, PP), jnp.float32),
        ],
        interpret=interpret,
    )(valid.astype(jnp.float32).reshape(1, c), block,
      ssum.reshape(1, PP), topk, botk)
    return so[0, :P], to[:, :P], bo[:, :P]


@functools.partial(
    jax.jit, static_argnames=("trim", "param_tile", "interpret")
)
def trimmedmean_pallas(updates: jnp.ndarray, trim: int,
                       *, param_tile: int = PARAM_TILE,
                       interpret: bool = True) -> jnp.ndarray:
    n, P = updates.shape
    tp = min(param_tile, P)
    p_pad = (-P) % tp
    if p_pad:
        updates = jnp.pad(updates, ((0, 0), (0, p_pad)))
    PP = updates.shape[1]
    out = pl.pallas_call(
        functools.partial(_trimmed_kernel, trim=trim),
        grid=(PP // tp,),
        in_specs=[pl.BlockSpec((n, tp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, PP), jnp.float32),
        interpret=interpret,
    )(updates)
    return out[0, :P]
