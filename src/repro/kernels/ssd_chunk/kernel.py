"""Pallas TPU kernel: SSD (Mamba2) chunked scan, one (batch, head) lane.

The SSM hot spot: within a chunk the recurrence collapses to two
MXU-shaped matmuls (the (L x L) decay-masked C·B tile and the state
read/write einsums); across chunks the (N x P) state carries in VMEM
scratch. Grid = (batch*heads, n_chunks) with chunks innermost — scratch
persists across the chunk dimension and re-initializes at chunk 0, so
the whole per-head scan runs without touching HBM for the state.

VMEM @ defaults (L=256, N=64, P=64, fp32): inputs ~196 KiB + (L x L)
decay tile 256 KiB + state 16 KiB — comfortably resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(lam_ref, b_ref, c_ref, x_ref, y_ref, h_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    lam = lam_ref[0, 0].astype(jnp.float32)       # (L,)
    B_ = b_ref[0, 0].astype(jnp.float32)          # (L, N)
    C_ = c_ref[0, 0].astype(jnp.float32)          # (L, N)
    x_ = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    L = lam.shape[0]

    cum = jnp.cumsum(lam)
    cb = jnp.dot(C_, B_.T, preferred_element_type=jnp.float32)  # (L, L)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    w = jnp.where(causal, cb * decay, 0.0)
    y = jnp.dot(w, x_, preferred_element_type=jnp.float32)      # (L, P)
    # inter-chunk: read the carried state
    y = y + jnp.dot(
        C_ * jnp.exp(cum)[:, None], h_ref[...],
        preferred_element_type=jnp.float32,
    )
    # state update to chunk end
    dte = jnp.exp(cum[-1] - cum)
    S = jnp.dot((B_ * dte[:, None]).T, x_,
                preferred_element_type=jnp.float32)             # (N, P)
    h_ref[...] = h_ref[...] * jnp.exp(cum[-1]) + S
    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(lam, Bm, Cm, xdt, *, interpret: bool = True):
    """lam (G, nc, L); Bm/Cm (G, nc, L, N); xdt (G, nc, L, P) where
    G = batch*heads lanes. Returns y (G, nc, L, P)."""
    G, nc, L = lam.shape
    N = Bm.shape[-1]
    P = xdt.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(G, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, 1, L, N), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, L, P), lambda g, c: (g, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, P), lambda g, c: (g, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, nc, L, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(lam, Bm, Cm, xdt)
