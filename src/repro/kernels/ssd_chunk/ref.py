"""Pure-jnp oracle for the SSD (Mamba2) chunk kernel.

One head's chunked scan: inputs per chunk c of length L —
  lam (L,)    log-decay dt*A (negative)
  B   (L, N)  input projection
  C   (L, N)  output projection
  xdt (L, P)  dt-scaled inputs
carrying state h (N, P). Mirrors models/layers/mamba2.chunk_step (which
tests assert against the full model)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(lam, Bm, Cm, xdt, h0):
    """lam (nc, L); Bm/Cm (nc, L, N); xdt (nc, L, P); h0 (N, P).
    Returns (y (nc, L, P), h_final (N, P))."""
    nc, L = lam.shape
    causal = jnp.tril(jnp.ones((L, L), bool))

    def step(h, inp):
        lam_, B_, C_, x_ = inp
        cum = jnp.cumsum(lam_)                        # (L,)
        cb = jnp.einsum("tm,sm->ts", C_, B_)          # (L, L)
        decay = jnp.exp(cum[:, None] - cum[None, :])
        w = cb * jnp.where(causal, decay, 0.0)
        y = jnp.einsum("ts,sp->tp", w, x_)
        y = y + jnp.einsum("tm,mp->tp", C_ * jnp.exp(cum)[:, None], h)
        dte = jnp.exp(cum[-1] - cum)                  # (L,)
        S = jnp.einsum("l,lm,lp->mp", dte, B_, x_)
        h_new = h * jnp.exp(cum[-1]) + S
        return h_new, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         (lam.astype(jnp.float32), Bm.astype(jnp.float32),
                          Cm.astype(jnp.float32), xdt.astype(jnp.float32)))
    return ys, h
