"""Jit'd wrapper: full (B, T, H, ...) SSD via the per-lane Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(lam, Bm, Cm, xdt, *, chunk: int = 256, interpret: bool = True):
    """lam (B,T,H); Bm/Cm (B,T,N); xdt (B,T,H,P) -> y (B,T,H,P) fp32.

    Mirrors models.layers.mamba2 semantics (B/C shared across heads)."""
    B, T, H = lam.shape
    N = Bm.shape[-1]
    P = xdt.shape[-1]
    L = min(chunk, T)
    if T % L:
        L = T
    nc = T // L
    # lanes = (B, H): broadcast B/C across heads
    lam_l = lam.transpose(0, 2, 1).reshape(B * H, nc, L)
    B_l = jnp.broadcast_to(
        Bm[:, None], (B, H, T, N)
    ).reshape(B * H, nc, L, N)
    C_l = jnp.broadcast_to(
        Cm[:, None], (B, H, T, N)
    ).reshape(B * H, nc, L, N)
    x_l = xdt.transpose(0, 2, 1, 3).reshape(B * H, nc, L, P)
    y = ssd_chunk_pallas(lam_l, B_l, C_l, x_l, interpret=interpret)
    return y.reshape(B, H, T, P).transpose(0, 2, 1, 3)
