"""Pure-jnp oracle for the fused weighted-sum fusion kernel."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_sum_ref(updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """updates (n, P), weights (n,) -> (P,) fp32 weighted sum."""
    return jnp.einsum(
        "np,n->p", updates.astype(jnp.float32), weights.astype(jnp.float32)
    )


def fedavg_ref(updates: jnp.ndarray, weights: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    """The paper's Eq. (1)."""
    w = weights.astype(jnp.float32)
    return weighted_sum_ref(updates, weights) / (jnp.sum(w) + eps)


def weighted_sum_dequant_ref(codes: jnp.ndarray, scales: jnp.ndarray,
                             weights: jnp.ndarray,
                             block: int = 2048) -> jnp.ndarray:
    """Oracle for the scale-folding kernel: dequantize int8 codes
    (n, Pq) with per-block fp32 scales (n, Pq // block), then weighted
    sum -> (Pq,) fp32."""
    n, Pq = codes.shape
    u = codes.astype(jnp.float32).reshape(n, Pq // block, block)
    u = (u * scales.astype(jnp.float32)[:, :, None]).reshape(n, Pq)
    return weighted_sum_ref(u, weights)
