"""Pallas TPU kernel: streaming weighted-sum fusion.

The TPU adaptation of the paper's Numba single-node path. The (n, P)
update matrix streams through VMEM in (CLIENT_TILE x PARAM_TILE) blocks;
each parameter tile's fp32 accumulator lives in the output VMEM block and
is revisited across the client-tile grid dimension — one HBM pass over the
updates, one HBM write of the result, MXU-shaped (the inner op is a
(1, TN) x (TN, TP) matmul).

Ragged shapes are handled INSIDE the kernel: the final client/param tile
is masked with an iota row test instead of `jnp.pad`-copying the entire
updates matrix (the seed behavior, which doubled HBM traffic and peak
memory exactly when the matrix was largest). Boundary blocks' padding
lanes have unspecified contents, so the mask zeroes both the weight lane
and the update rows before the dot — 0 * garbage would still poison the
accumulator if the garbage were NaN/Inf.

Grid: (ceil(P / PARAM_TILE), ceil(n / CLIENT_TILE)); the output block
index ignores the client dim, so Pallas keeps it resident in VMEM across
that dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.jitcache import note_trace

# lane-aligned defaults: PARAM_TILE a multiple of 128 (lanes), CLIENT_TILE
# a multiple of 8 (sublanes). VMEM budget @ defaults:
# 256*2048*4 B (updates tile) + 2048*4 (acc) ~= 2.1 MiB.
PARAM_TILE = 2048
CLIENT_TILE = 256


def _wsum_kernel(w_ref, u_ref, out_ref, *, n_rows, tn, ragged):
    """w: (1, TN) fp32; u: (TN, TP); out: (1, TP) fp32 accumulator."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u = u_ref[...].astype(jnp.float32)
    w = w_ref[...]
    if ragged:
        # rows valid in this client tile: tn everywhere except the last
        valid = n_rows - j * tn
        ids = jax.lax.broadcasted_iota(jnp.int32, (1, tn), 1)
        w = jnp.where(ids < valid, w, 0.0)
        u = jnp.where(ids.reshape(tn, 1) < valid, u, 0.0)
    out_ref[...] += jnp.dot(w, u, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("param_tile", "client_tile", "interpret")
)
def weighted_sum_pallas(
    updates: jnp.ndarray,        # (n, P) any float dtype
    weights: jnp.ndarray,        # (n,) fp32
    *,
    param_tile: int = PARAM_TILE,
    client_tile: int = CLIENT_TILE,
    interpret: bool = True,      # CPU container: interpret mode
) -> jnp.ndarray:
    note_trace()
    n, P = updates.shape
    tn = min(client_tile, n)
    tp = min(param_tile, P)
    w2 = weights.astype(jnp.float32).reshape(1, n)

    kernel = functools.partial(
        _wsum_kernel, n_rows=n, tn=tn, ragged=bool(n % tn),
    )
    out = pl.pallas_call(
        kernel,
        grid=(pl.cdiv(P, tp), pl.cdiv(n, tn)),
        in_specs=[
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tn, tp), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, tp), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, P), jnp.float32),
        interpret=interpret,
    )(w2, updates)
    return out[0]
