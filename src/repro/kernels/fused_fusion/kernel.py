"""Pallas TPU kernel: streaming weighted-sum fusion.

The TPU adaptation of the paper's Numba single-node path. The (n, P)
update matrix streams through VMEM in (CLIENT_TILE x PARAM_TILE) blocks;
each parameter tile's fp32 accumulator lives in the output VMEM block and
is revisited across the client-tile grid dimension — one HBM pass over the
updates, one HBM write of the result, MXU-shaped (the inner op is a
(1, TN) x (TN, TP) matmul).

Ragged shapes are handled INSIDE the kernel: the final client/param tile
is masked with an iota row test instead of `jnp.pad`-copying the entire
updates matrix (the seed behavior, which doubled HBM traffic and peak
memory exactly when the matrix was largest). Boundary blocks' padding
lanes have unspecified contents, so the mask zeroes both the weight lane
and the update rows before the dot — 0 * garbage would still poison the
accumulator if the garbage were NaN/Inf.

Grid: (ceil(P / PARAM_TILE), ceil(n / CLIENT_TILE)); the output block
index ignores the client dim, so Pallas keeps it resident in VMEM across
that dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.jitcache import note_trace

# lane-aligned defaults: PARAM_TILE a multiple of 128 (lanes), CLIENT_TILE
# a multiple of 8 (sublanes). VMEM budget @ defaults:
# 256*2048*4 B (updates tile) + 2048*4 (acc) ~= 2.1 MiB.
PARAM_TILE = 2048
CLIENT_TILE = 256


def _wsum_kernel(w_ref, u_ref, out_ref, *, n_rows, tn, ragged):
    """w: (1, TN) fp32; u: (TN, TP); out: (1, TP) fp32 accumulator."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u = u_ref[...].astype(jnp.float32)
    w = w_ref[...]
    if ragged:
        # rows valid in this client tile: tn everywhere except the last
        valid = n_rows - j * tn
        ids = jax.lax.broadcasted_iota(jnp.int32, (1, tn), 1)
        w = jnp.where(ids < valid, w, 0.0)
        u = jnp.where(ids.reshape(tn, 1) < valid, u, 0.0)
    out_ref[...] += jnp.dot(w, u, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("param_tile", "client_tile", "interpret")
)
def weighted_sum_pallas(
    updates: jnp.ndarray,        # (n, P) any float dtype
    weights: jnp.ndarray,        # (n,) fp32
    *,
    param_tile: int = PARAM_TILE,
    client_tile: int = CLIENT_TILE,
    interpret: bool = True,      # CPU container: interpret mode
) -> jnp.ndarray:
    note_trace()
    n, P = updates.shape
    tn = min(client_tile, n)
    tp = min(param_tile, P)
    w2 = weights.astype(jnp.float32).reshape(1, n)

    kernel = functools.partial(
        _wsum_kernel, n_rows=n, tn=tn, ragged=bool(n % tn),
    )
    out = pl.pallas_call(
        kernel,
        grid=(pl.cdiv(P, tp), pl.cdiv(n, tn)),
        in_specs=[
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tn, tp), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, tp), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, P), jnp.float32),
        interpret=interpret,
    )(w2, updates)
    return out[0]


def _wsum_dequant_kernel(w_ref, q_ref, s_ref, out_ref, *, n_rows, tn, blk,
                         ragged):
    """w: (1, TN) fp32; q: (TN, TP) int8; s: (TN, TP//blk) fp32 per-block
    scales; out: (1, TP) fp32 accumulator.

    Dequantization is folded into the weighted sum: the int8 tile is
    upcast in VMEM, scaled by its per-block fp32 scales (broadcast over
    the blk lanes of each quantization block), and fed straight to the
    same (1, TN) x (TN, TP) dot as the dense kernel — the fp32 update
    matrix never exists in HBM, only one (TN, TP) tile at a time in
    VMEM. Ragged client tiles mask both the weight lane and the
    dequantized rows (scale lanes past n_rows are unspecified VMEM, so
    0 * garbage could still be NaN)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)          # (tn, tp)
    s = s_ref[...]                              # (tn, tp // blk)
    w = w_ref[...]
    tp = q.shape[1]
    u = (q.reshape(tn, tp // blk, blk) * s[:, :, None]).reshape(tn, tp)
    if ragged:
        valid = n_rows - j * tn
        ids = jax.lax.broadcasted_iota(jnp.int32, (1, tn), 1)
        w = jnp.where(ids < valid, w, 0.0)
        u = jnp.where(ids.reshape(tn, 1) < valid, u, 0.0)
    out_ref[...] += jnp.dot(w, u, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block", "param_tile", "client_tile",
                              "interpret")
)
def weighted_sum_dequant_pallas(
    codes: jnp.ndarray,          # (n, Pq) int8, Pq a multiple of block
    scales: jnp.ndarray,         # (n, Pq // block) fp32 per-block scales
    weights: jnp.ndarray,        # (n,) fp32
    *,
    block: int = 2048,           # quantization block (compress.BLOCK)
    param_tile: int = PARAM_TILE,
    client_tile: int = CLIENT_TILE,
    interpret: bool = True,      # CPU container: interpret mode
) -> jnp.ndarray:
    """Weighted sum of block-quantized rows with the dequant scales
    folded in-kernel: out[p] = sum_i w[i] * s[i, p//block] * q[i, p].

    Returns the (Pq,) fp32 weighted sum over the PADDED parameter axis
    (codes past the logical dim are zero by the CompressedUpdate
    contract, so callers just slice [:dim])."""
    note_trace()
    n, Pq = codes.shape
    if Pq % block:
        raise ValueError(f"codes width {Pq} not a multiple of block {block}")
    tn = min(client_tile, n)
    # the param tile must cover whole quantization blocks so each grid
    # cell sees its own scales; Pq is always a multiple of block
    tp = min(max(block, (param_tile // block) * block), Pq)
    w2 = weights.astype(jnp.float32).reshape(1, n)

    kernel = functools.partial(
        _wsum_dequant_kernel, n_rows=n, tn=tn, blk=block,
        ragged=bool(n % tn),
    )
    m = tp // block
    out = pl.pallas_call(
        kernel,
        grid=(pl.cdiv(Pq, tp), pl.cdiv(n, tn)),
        in_specs=[
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tn, tp), lambda i, j: (j, i)),
            pl.BlockSpec((tn, m), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, tp), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Pq), jnp.float32),
        interpret=interpret,
    )(w2, codes, scales)
    return out[0]
