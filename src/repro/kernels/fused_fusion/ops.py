"""Jit'd public wrappers for the fused-fusion kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.fusion.base import EPS
from repro.kernels.fused_fusion.kernel import (
    weighted_sum_dequant_pallas,
    weighted_sum_pallas,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_fused(updates: jnp.ndarray, weights: jnp.ndarray,
                 interpret: bool = True) -> jnp.ndarray:
    """Paper Eq. (1) with the streaming Pallas weighted-sum."""
    wsum = weighted_sum_pallas(updates, weights, interpret=interpret)
    return wsum / (jnp.sum(weights.astype(jnp.float32)) + EPS)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fedavg_fused_dequant(codes: jnp.ndarray, scales: jnp.ndarray,
                         weights: jnp.ndarray, block: int = 2048,
                         interpret: bool = True) -> jnp.ndarray:
    """Paper Eq. (1) straight from int8 codes + fp32 per-block scales:
    dequantization folds into the weighted-sum kernel, so the fp32
    update matrix never materializes."""
    wsum = weighted_sum_dequant_pallas(codes, scales, weights, block=block,
                                       interpret=interpret)
    return wsum / (jnp.sum(weights.astype(jnp.float32)) + EPS)


@functools.partial(jax.jit, static_argnames=("interpret",))
def iteravg_fused(updates: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    n = updates.shape[0]
    w = jnp.ones((n,), jnp.float32)
    return weighted_sum_pallas(updates, w, interpret=interpret) / (n + EPS)
