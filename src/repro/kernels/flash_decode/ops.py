"""Jit'd public wrapper for the flash-decode kernel."""
from repro.kernels.flash_decode.kernel import flash_decode

__all__ = ["flash_decode"]
