"""Pallas TPU kernel: single-token GQA decode attention over a (ring)
KV cache.

Decode is the memory-roofline step: per token, the whole live cache
streams HBM->VMEM once. This kernel tiles the cache sequence dim,
keeps the online-softmax state (acc, m, l) in VMEM scratch across the
sequence grid dim, and evaluates the ring-buffer validity mask in
registers — one pass, no fp32 cache copy, no score materialization
beyond a (group x BLOCK_S) tile.

Grid: (batch*n_kv, S // BLOCK_S), sequence innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_S = 512


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, block_s: int, n_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)              # (group, hd)
    k = k_ref[0].astype(jnp.float32)              # (bs, hd)
    v = v_ref[0].astype(jnp.float32)
    hd = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * hd ** -0.5
    # ring validity: slot index <= pos OR the ring has wrapped
    pos = pos_ref[0]
    S_total = n_s * block_s
    idx = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    live = (idx <= pos) | (pos >= S_total)
    s = jnp.where(live, s, NEG_INF)

    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(
    q: jnp.ndarray,        # (B, 1, nq, hd)
    k_cache: jnp.ndarray,  # (B, S, nkv, hd)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,      # scalar int32: tokens written so far - 1
    *,
    block_s: int = BLOCK_S,
    interpret: bool = True,
) -> jnp.ndarray:
    B, _, nq, hd = q.shape
    S, nkv = k_cache.shape[1], k_cache.shape[2]
    group = nq // nkv
    bs = min(block_s, S)
    assert S % bs == 0
    n_s = S // bs

    qg = q.reshape(B, nkv, group, hd).reshape(B * nkv, group, hd)
    kh = jnp.moveaxis(k_cache, 2, 1).reshape(B * nkv, S, hd)
    vh = jnp.moveaxis(v_cache, 2, 1).reshape(B * nkv, S, hd)
    pos_arr = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32), (B * nkv,)
    )

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_s=bs, n_s=n_s),
        grid=(B * nkv, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda h, s: (h,)),
            pl.BlockSpec((1, group, hd), lambda h, s: (h, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda h, s: (h, s, 0)),
            pl.BlockSpec((1, bs, hd), lambda h, s: (h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, hd), lambda h, s: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, kh, vh)
    return out.reshape(B, 1, nq, hd)
