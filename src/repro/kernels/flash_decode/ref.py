"""Pure-jnp oracle for the flash-decode kernel (= the model's
decode_attention, re-exported so the kernel's contract is explicit)."""
from repro.models.layers.attention import decode_attention as decode_attention_ref

__all__ = ["decode_attention_ref"]
