"""Jit'd public wrapper for the flash-attention kernel."""
from repro.kernels.flash_attention.kernel import flash_attention

__all__ = ["flash_attention"]
