"""Pure-jnp oracle for the flash-attention kernel (naive full softmax)."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B, T, nq, hd), k/v (B, S, nkv, hd) -> (B, T, nq, hd).

    Naive O(T*S) reference with GQA head grouping.
    """
    B, T, nq, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    group = nq // nkv
    qf = q.astype(jnp.float32).reshape(B, T, nkv, group, hd) * hd ** -0.5
    s = jnp.einsum("btngh,bsnh->bngts", qf, k.astype(jnp.float32))
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bngts,bsnh->btngh", p, v.astype(jnp.float32))
    return o.reshape(B, T, nq, hd).astype(q.dtype)
