"""Pallas TPU kernel: FlashAttention-2-style causal GQA attention with an
optional sliding window.

Grid: (batch*q_heads, T // BLOCK_Q, S // BLOCK_K), kv-tile innermost. The
fp32 accumulator, running max m and denominator l live in VMEM scratch and
persist across the kv dimension (the out block index ignores it); the
output is written on the last kv step. Tiles are (BLOCK_Q x hd) and
(BLOCK_K x hd) — hd in {64, 128, 256} is lane-aligned, BLOCK_Q/BLOCK_K are
sublane multiples. GQA is handled by indexing the kv head as qh // group
in the BlockSpec index maps, so no KV duplication in HBM or VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_Q = 256
BLOCK_K = 256


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, window: int, block_q: int, block_k: int,
                  n_k: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = kj * block_k

    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window > 0:
        live = jnp.logical_and(
            live, k_start + block_k - 1 > q_start - window
        )

    @pl.when(live)
    def _attend():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,   # (B, T, nq, hd)
    k: jnp.ndarray,   # (B, S, nkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    B, T, nq, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    group = nq // nkv
    bq = min(block_q, T)
    bk = min(block_k, S)
    assert T % bq == 0 and S % bk == 0, "seq dims must tile"
    scale = hd ** -0.5

    # (B, H, T, hd) layout for clean 2D tiles per (batch, head)
    qh = jnp.moveaxis(q, 2, 1).reshape(B * nq, T, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * nkv, S, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * nkv, S, hd)

    n_k = S // bk
    grid = (B * nq, T // bq, n_k)

    def kv_index(h, i, j):
        # map flat q-head index -> flat kv-head index (GQA)
        b = h // nq
        qh_ = h % nq
        return (b * nkv + qh_ // group, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, causal=causal, window=window, block_q=bq,
            block_k=bk, n_k=n_k, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nq, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # denominator l
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(B, nq, T, hd), 1, 2)
