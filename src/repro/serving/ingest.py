"""Bounded ingest queue — concurrent uploads coalesce into batched
store commits.

The front-end's handler threads do NOT call ``store.write`` directly
(thread-per-client commit was the seed's implied model). Instead each
admitted upload is enqueued as a :class:`concurrent.futures.Future`;
ONE committer thread drains up to ``batch_max`` pending uploads at a
time and lands them through ``store.write_batch`` — one registration
lock acquisition and one arrival notification per batch instead of per
update. The handler replies 200 only after its future resolves, i.e.
after the update is DURABLY registered (and, on a disk store, its blob
and sidecars staged).

Backpressure is explicit: a full queue raises
:class:`BackpressureError` immediately (the front-end maps it to 503 +
Retry-After) — the socket is never used as an invisible buffer.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import List, Optional, Tuple

from repro.core.store import DEFAULT_TENANT

_SENTINEL = object()


class BackpressureError(RuntimeError):
    """The ingest queue is full — retry after ``retry_after`` s (503)."""

    def __init__(self, msg: str, retry_after: float = 0.05):
        super().__init__(msg)
        self.retry_after = retry_after


class IngestQueue:
    """Bounded queue of pending uploads + one batching committer.

    ``maxsize`` bounds queued-but-uncommitted uploads (the
    backpressure horizon); ``batch_max`` caps how many the committer
    folds into one ``store.write_batch`` call."""

    def __init__(self, store, maxsize: int = 256, batch_max: int = 32,
                 retry_after: float = 0.05):
        if maxsize < 1 or batch_max < 1:
            raise ValueError("maxsize and batch_max must be >= 1")
        self.store = store
        self.batch_max = int(batch_max)
        self.retry_after = float(retry_after)
        self._q: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self._lock = threading.Lock()
        self._closed = False  # guarded-by: _lock
        self._submitted = 0  # guarded-by: _lock
        self._committed = 0  # guarded-by: _lock
        self._rejected = 0  # guarded-by: _lock
        self._shed = 0  # guarded-by: _lock
        self._batches = 0  # guarded-by: _lock
        self._max_batch = 0  # guarded-by: _lock
        self._thread = threading.Thread(
            target=self._run, name="ingest-committer", daemon=True
        )
        self._thread.start()

    # -- producer side -------------------------------------------------------
    def submit(self, client_id: str, update, weight: float = 1.0,
               tenant: str = DEFAULT_TENANT) -> "Future":
        """Enqueue one upload; resolves to the modeled write latency,
        or raises the store's exception (e.g. ``QuotaExceededError``).
        Raises :class:`BackpressureError` without queueing when full."""
        with self._lock:
            if self._closed:
                raise RuntimeError("IngestQueue is closed")
            self._submitted += 1
        fut: Future = Future()
        try:
            self._q.put_nowait((fut, (client_id, update, weight, tenant)))
        except queue.Full:
            with self._lock:
                self._shed += 1
            raise BackpressureError(
                f"ingest queue full ({self._q.maxsize} pending)",
                retry_after=self.retry_after,
            ) from None
        return fut

    # -- committer -----------------------------------------------------------
    def _drain(self) -> Tuple[List, bool]:
        """Block for one upload, then opportunistically batch whatever
        else is already queued (bounded by ``batch_max``)."""
        head = self._q.get()
        if head is _SENTINEL:
            return [], True
        batch = [head]
        stop = False
        while len(batch) < self.batch_max:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                break
            if nxt is _SENTINEL:
                stop = True
                break
            batch.append(nxt)
        return batch, stop

    def _run(self) -> None:
        while True:
            batch, stop = self._drain()
            if batch:
                futs = [f for f, _ in batch]
                items = [it for _, it in batch]
                try:
                    results = self.store.write_batch(items)
                except BaseException as exc:   # store hard-failed
                    for f in futs:
                        f.set_exception(exc)
                else:
                    ok = 0
                    for f, res in zip(futs, results):
                        if isinstance(res, BaseException):
                            f.set_exception(res)
                        else:
                            ok += 1
                            f.set_result(res)
                    with self._lock:
                        self._batches += 1
                        self._max_batch = max(self._max_batch,
                                              len(batch))
                        self._committed += ok
                        self._rejected += len(batch) - ok
            if stop:
                return

    # -- introspection / shutdown --------------------------------------------
    def depth(self) -> int:
        """Uploads queued but not yet handed to the committer."""
        return self._q.qsize()

    def stats(self) -> dict:
        with self._lock:
            return {
                "submitted": self._submitted,
                "committed": self._committed,
                "rejected": self._rejected,
                "shed": self._shed,
                "batches": self._batches,
                "max_batch": self._max_batch,
            }

    def close(self, timeout: Optional[float] = 10.0) -> None:
        """Stop accepting uploads, drain the queue, join the
        committer."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._q.put(_SENTINEL)
        self._thread.join(timeout=timeout)
