"""Threaded HTTP ingest front-end over the ``UpdateStore``.

``IngestServer`` binds a stdlib ``ThreadingHTTPServer`` (no new deps)
and serves:

  * ``POST /v1/upload``    — one wire frame (``repro.serving.protocol``)
                             per request; replies 200 JSON only after
                             the update is durably committed through
                             the batching :class:`IngestQueue`.
  * ``GET  /v1/healthz``   — liveness + queue depth + counters.
  * ``GET  /v1/stats``     — ``StoreStats`` snapshot (``?tenant=``).

Handler threads only authenticate, gate, read and parse — commits are
coalesced by the queue's single committer, so hundreds of concurrent
clients cost hundreds of (cheap, mostly-blocked) reader threads but
only ONE writer into the store's registration lock.

Error surface (all JSON bodies, all fail closed — nothing lands):

  401 bad/missing token            408 read timed out (slow-loris)
  400 malformed frame              411 missing Content-Length
  413 body over the upload cap     429 rate limit / quota, Retry-After
  503 ingest queue full, Retry-After
"""
from __future__ import annotations

import json
import socket
import sys
import threading
from concurrent.futures import TimeoutError as FutureTimeout
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple
from urllib.parse import parse_qs, urlparse

from repro.core.store import QuotaExceededError
from repro.serving.admission import AdmissionController
from repro.serving.ingest import BackpressureError, IngestQueue
from repro.serving.protocol import WireError, parse_update


class _Httpd(ThreadingHTTPServer):
    daemon_threads = True
    # the stdlib default backlog of 5 makes hundreds of clients
    # connecting at once retransmit SYNs (a ~1s latency cliff)
    request_queue_size = 128
    # one IngestServer per httpd, attached after construction
    ingest: "IngestServer"

    def handle_error(self, request, client_address) -> None:
        # torn connections (mid-request RST, keep-alive races) are a
        # counted workload condition, not a stack trace
        exc = sys.exc_info()[1]
        if isinstance(exc, (ConnectionError, socket.timeout,
                            TimeoutError, BrokenPipeError)):
            self.ingest.count("disconnect")
            return
        super().handle_error(request, client_address)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server: _Httpd

    def setup(self) -> None:
        # slow-loris guard: BaseHTTPRequestHandler applies self.timeout
        # to the connection socket, so a stalled body read raises
        # socket.timeout instead of pinning the handler thread forever
        self.timeout = self.server.ingest.read_timeout
        super().setup()

    def log_message(self, fmt, *args) -> None:   # quiet by default
        pass

    # -- plumbing ------------------------------------------------------------
    def _send(self, status: int, payload: dict,
              retry_after: Optional[float] = None,
              close: bool = False) -> None:
        body = json.dumps(payload).encode("utf-8")
        try:
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            if retry_after is not None:
                self.send_header("Retry-After", f"{retry_after:.3f}")
            if close:
                self.send_header("Connection", "close")
                self.close_connection = True
            self.end_headers()
            self.wfile.write(body)
        except OSError:
            # client went away while we replied — nothing to salvage
            self.close_connection = True

    def _token(self) -> Optional[str]:
        auth = self.headers.get("Authorization", "")
        if auth.startswith("Bearer "):
            return auth[len("Bearer "):].strip()
        return self.headers.get("X-Tenant-Token")

    def _read_exact(self, n: int) -> Optional[bytes]:
        """Read exactly ``n`` body bytes. None = client disconnected
        (EOF short of Content-Length); socket.timeout propagates."""
        chunks = []
        remaining = n
        while remaining > 0:
            chunk = self.rfile.read(remaining)
            if not chunk:
                return None
            chunks.append(chunk)
            remaining -= len(chunk)
        return b"".join(chunks)

    # -- routes --------------------------------------------------------------
    def do_POST(self) -> None:
        ing = self.server.ingest
        if self.path != "/v1/upload":
            self._send(404, {"error": f"no such route {self.path}"},
                       close=True)
            return
        tenant = ing.admission.tenant_for(self._token())
        if tenant is None:
            ing.count("unauthorized")
            self._send(401, {"error": "unknown or missing tenant "
                                      "token"}, close=True)
            return
        try:
            length = int(self.headers.get("Content-Length", ""))
        except ValueError:
            length = -1
        if length < 0:
            ing.count("bad_length")
            self._send(411, {"error": "Content-Length required"},
                       close=True)
            return
        decision = ing.admission.admit(tenant, length)
        if not decision.admitted:
            ing.count("shed_429" if decision.status == 429
                      else "shed_413")
            # the body was never read — drop the connection rather
            # than desync keep-alive framing on the unread bytes
            self._send(decision.status, {"error": decision.reason},
                       retry_after=decision.retry_after, close=True)
            return
        try:
            body = self._read_exact(length)
        except (socket.timeout, TimeoutError):
            ing.count("read_timeout")
            self._send(408, {"error": f"body read exceeded "
                                      f"{ing.read_timeout}s"},
                       close=True)
            return
        except (ConnectionError, OSError):
            # hard mid-upload disconnect (RST): nothing landed
            ing.count("disconnect")
            self.close_connection = True
            return
        if body is None:
            # mid-upload disconnect: nothing to reply to, nothing lands
            ing.count("disconnect")
            self.close_connection = True
            return
        try:
            parsed = parse_update(body)
        except WireError as e:
            ing.count("malformed")
            self._send(400, {"error": str(e)})
            return
        try:
            fut = ing.queue.submit(parsed.client_id, parsed.update,
                                   weight=parsed.weight, tenant=tenant)
        except BackpressureError as e:
            ing.count("backpressure")
            self._send(503, {"error": str(e)},
                       retry_after=e.retry_after, close=True)
            return
        try:
            latency = fut.result(timeout=ing.commit_timeout)
        except QuotaExceededError as e:
            ing.count("quota_reject")
            self._send(429, {"error": str(e)},
                       retry_after=ing.admission.quota_retry_after)
            return
        except FutureTimeout:
            ing.count("commit_timeout")
            self._send(504, {"error": "commit timed out"}, close=True)
            return
        except (WireError, ValueError) as e:
            ing.count("malformed")
            self._send(400, {"error": str(e)})
            return
        ing.count("accepted")
        self._send(200, {
            "status": "ok", "tenant": tenant,
            "client_id": parsed.client_id,
            "sim_write_seconds": latency,
        })

    def do_GET(self) -> None:
        ing = self.server.ingest
        url = urlparse(self.path)
        if url.path == "/v1/healthz":
            self._send(200, {
                "status": "ok",
                "queue_depth": ing.queue.depth(),
                "metrics": ing.metrics(),
            })
            return
        if url.path == "/v1/stats":
            qs = parse_qs(url.query)
            tenant = qs.get("tenant", [None])[0]
            st = ing.store.stats_for(tenant)
            self._send(200, {
                "tenant": tenant, "writes": st.writes,
                "bytes_written": st.bytes_written,
                "reads": st.reads, "bytes_read": st.bytes_read,
                "evictions": st.evictions,
            })
            return
        self._send(404, {"error": f"no such route {url.path}"},
                   close=True)


class IngestServer:
    """The network ingest front-end: bind, serve, account, shut down.

    ``tokens`` maps bearer token -> tenant (the auth table). Admission
    and queue knobs pass through to :class:`AdmissionController` /
    :class:`IngestQueue`; pre-built instances can be injected for
    tests. Serving starts on construction; ``close()`` (or the context
    manager) drains the queue and releases the port."""

    def __init__(
        self,
        store,
        tokens: Dict[str, str],
        host: str = "127.0.0.1",
        port: int = 0,
        admission: Optional[AdmissionController] = None,
        ingest_queue: Optional[IngestQueue] = None,
        rate: Optional[float] = None,
        burst: Optional[float] = None,
        per_tenant_rates: Optional[Dict[str, Tuple[float, float]]] = None,
        max_body_bytes: int = 64 << 20,
        read_timeout: float = 5.0,
        commit_timeout: float = 30.0,
        queue_size: int = 256,
        batch_max: int = 32,
    ):
        self.store = store
        self.read_timeout = float(read_timeout)
        self.commit_timeout = float(commit_timeout)
        self.admission = admission or AdmissionController(
            tokens, store=store, rate=rate, burst=burst,
            per_tenant_rates=per_tenant_rates,
            max_body_bytes=max_body_bytes,
        )
        self.queue = ingest_queue or IngestQueue(
            store, maxsize=queue_size, batch_max=batch_max
        )
        self._counters: Dict[str, int] = {}  # guarded-by: _clock_lock
        self._clock_lock = threading.Lock()
        self._httpd = _Httpd((host, port), _Handler)
        self._httpd.ingest = self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.05},
            name=f"ingest-frontend:{self.port}", daemon=True,
        )
        self._thread.start()
        self._closed = False

    # -- accounting ----------------------------------------------------------
    def count(self, name: str) -> None:
        with self._clock_lock:
            self._counters[name] = self._counters.get(name, 0) + 1

    def metrics(self) -> dict:
        with self._clock_lock:
            out = dict(self._counters)
        out.update(self.queue.stats())
        return out

    # -- lifecycle -----------------------------------------------------------
    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._httpd.shutdown()
        self._thread.join(timeout=10.0)
        self._httpd.server_close()
        self.queue.close()

    def __enter__(self) -> "IngestServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
