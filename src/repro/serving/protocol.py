"""Upload wire format — the one frame a client POSTs to ``/v1/upload``.

One frame carries one client update: a fixed header (magic, kind,
client id, weight) followed by a dense payload (dtype + dim + raw
bytes) or a compressed payload (dim + block geometry + int8 codes +
fp32 scales — exactly the ``CompressedUpdate`` container the store
spools, so parsing lands the same object ``store.write`` takes
in-process and fused vectors stay bit-identical across transports).

All integers are little-endian. Layout::

    magic   4s   b"FLU1"
    kind    u8   0 = dense, 1 = compressed
    idlen   u16  client id byte length (1..256)
    id      idlen bytes, utf-8
    weight  f64  finite, > 0

    dense:                         compressed:
      dtlen   u8                     dim      u64  (logical P, >= 1)
      dtype   dtlen bytes ascii      nblocks  u32  (>= 1)
      dim     u64  (>= 1)            block    u32  (>= 1)
      payload dim * itemsize         codes    nblocks * block  int8
                                     scales   nblocks          fp32

Parsing FAILS CLOSED: any truncation, trailing bytes, unknown magic /
kind / dtype, zero dim, non-finite weight or scales, or a block
geometry that does not tile ``dim`` raises :class:`WireError` — a
malformed body must never reach the store.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Union

import numpy as np

from repro.core.compress import CompressedUpdate

MAGIC = b"FLU1"
KIND_DENSE = 0
KIND_COMPRESSED = 1
MAX_CLIENT_ID_BYTES = 256

# the dense dtypes the store round-trips (bf16 via the ml_dtypes
# extension dtype, spooled as raw bytes + a .dtype sidecar)
_DENSE_DTYPES = ("float32", "float16", "float64", "bfloat16")

_HEAD = struct.Struct("<4sBH")      # magic, kind, idlen
_WEIGHT = struct.Struct("<d")
_DIM = struct.Struct("<Q")
_GEOM = struct.Struct("<QII")       # dim, nblocks, block


class WireError(ValueError):
    """A frame failed validation — reject with 400, land nothing."""


@dataclasses.dataclass(frozen=True)
class ParsedUpdate:
    """A validated frame, ready for ``store.write``-shaped ingestion."""

    client_id: str
    weight: float
    update: Union[np.ndarray, CompressedUpdate]

    @property
    def kind(self) -> int:
        return (KIND_COMPRESSED
                if isinstance(self.update, CompressedUpdate)
                else KIND_DENSE)


def _dtype_of(update: np.ndarray) -> np.dtype:
    dt = np.dtype(update.dtype)
    if dt.name not in _DENSE_DTYPES:
        raise WireError(
            f"dense upload dtype {dt.name!r} not on the wire whitelist "
            f"{_DENSE_DTYPES}"
        )
    return dt


def encode_update(client_id: str,
                  update: Union[np.ndarray, CompressedUpdate],
                  weight: float = 1.0) -> bytes:
    """Serialize one update into its upload frame (the client side of
    :func:`parse_update`)."""
    cid = client_id.encode("utf-8")
    if not 1 <= len(cid) <= MAX_CLIENT_ID_BYTES:
        raise WireError(
            f"client id must encode to 1..{MAX_CLIENT_ID_BYTES} bytes, "
            f"got {len(cid)}"
        )
    w = float(weight)
    if not np.isfinite(w) or w <= 0:
        raise WireError(f"weight must be finite and > 0, got {w!r}")
    if isinstance(update, CompressedUpdate):
        head = _HEAD.pack(MAGIC, KIND_COMPRESSED, len(cid))
        codes = np.ascontiguousarray(update.codes, dtype=np.int8)
        scales = np.ascontiguousarray(update.scales, dtype=np.float32)
        return b"".join([
            head, cid, _WEIGHT.pack(w),
            _GEOM.pack(int(update.dim), scales.size, update.block),
            codes.tobytes(), scales.tobytes(),
        ])
    vec = np.ascontiguousarray(np.asarray(update))
    if vec.ndim != 1 or vec.size == 0:
        raise WireError(
            f"dense upload must be a non-empty 1-D vector, "
            f"got shape {vec.shape}"
        )
    dt = _dtype_of(vec)
    name = dt.name.encode("ascii")
    head = _HEAD.pack(MAGIC, KIND_DENSE, len(cid))
    return b"".join([
        head, cid, _WEIGHT.pack(w),
        struct.pack("<B", len(name)), name,
        _DIM.pack(vec.size), vec.tobytes(),
    ])


class _Cursor:
    """Bounds-checked reader over the frame buffer."""

    def __init__(self, buf: bytes):
        self.buf = buf
        self.off = 0

    def take(self, n: int) -> bytes:
        if n < 0 or self.off + n > len(self.buf):
            raise WireError(
                f"truncated frame: wanted {n} bytes at offset "
                f"{self.off}, have {len(self.buf) - self.off}"
            )
        out = self.buf[self.off:self.off + n]
        self.off += n
        return out

    def unpack(self, st: struct.Struct):
        return st.unpack(self.take(st.size))

    def done(self) -> None:
        if self.off != len(self.buf):
            raise WireError(
                f"{len(self.buf) - self.off} trailing bytes after frame"
            )


def parse_update(buf: bytes) -> ParsedUpdate:
    """Validate and decode one upload frame. Raises :class:`WireError`
    on ANY structural problem — fail closed, nothing partial."""
    cur = _Cursor(bytes(buf))
    magic, kind, idlen = cur.unpack(_HEAD)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} (want {MAGIC!r})")
    if kind not in (KIND_DENSE, KIND_COMPRESSED):
        raise WireError(f"unknown frame kind {kind}")
    if not 1 <= idlen <= MAX_CLIENT_ID_BYTES:
        raise WireError(f"client id length {idlen} out of range")
    try:
        client_id = cur.take(idlen).decode("utf-8")
    except UnicodeDecodeError as e:
        raise WireError(f"client id is not valid utf-8: {e}") from e
    (weight,) = cur.unpack(_WEIGHT)
    if not np.isfinite(weight) or weight <= 0:
        raise WireError(f"weight must be finite and > 0, got {weight!r}")

    if kind == KIND_DENSE:
        (dtlen,) = struct.unpack("<B", cur.take(1))
        try:
            dtname = cur.take(dtlen).decode("ascii")
        except UnicodeDecodeError as e:
            raise WireError(f"dtype name is not ascii: {e}") from e
        if dtname not in _DENSE_DTYPES:
            raise WireError(
                f"dense upload dtype {dtname!r} not on the wire "
                f"whitelist {_DENSE_DTYPES}"
            )
        try:
            dt = np.dtype(dtname)
        except TypeError as e:   # bfloat16 without ml_dtypes installed
            raise WireError(f"dtype {dtname!r} unavailable: {e}") from e
        (dim,) = cur.unpack(_DIM)
        if dim < 1:
            raise WireError("dense dim must be >= 1")
        payload = cur.take(dim * dt.itemsize)
        cur.done()
        vec = np.frombuffer(payload, dtype=dt).copy()
        return ParsedUpdate(client_id=client_id, weight=weight,
                            update=vec)

    dim, nblocks, block = cur.unpack(_GEOM)
    if dim < 1 or nblocks < 1 or block < 1:
        raise WireError(
            f"compressed geometry out of range: dim={dim} "
            f"nblocks={nblocks} block={block}"
        )
    # codes are zero-padded to whole blocks COVERING dim, no more: the
    # canonical CompressedUpdate layout (block recoverable from shapes)
    if not (nblocks - 1) * block < dim <= nblocks * block:
        raise WireError(
            f"block geometry does not tile dim: dim={dim} "
            f"nblocks={nblocks} block={block}"
        )
    codes = np.frombuffer(cur.take(nblocks * block),
                          dtype=np.int8).copy()
    scales = np.frombuffer(cur.take(nblocks * 4),
                           dtype="<f4").astype(np.float32)
    cur.done()
    if not np.all(np.isfinite(scales)):
        raise WireError("compressed scales must be finite")
    return ParsedUpdate(
        client_id=client_id, weight=weight,
        update=CompressedUpdate(codes=codes, scales=scales,
                                dim=int(dim)),
    )
