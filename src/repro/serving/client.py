"""HTTP upload client — ``store.write`` over the wire.

``HttpStoreClient.write`` has the same signature as
``UpdateStore.write`` (client_id, update, weight, tenant), so a trace
replay or benchmark writer swaps transports by passing
``writer=client.write`` — everything downstream (payloads, weights,
rounds) is unchanged, which is what makes socket-vs-in-process
bit-identity a testable claim.

Retries honor the server's Retry-After on 429 (rate/quota) and 503
(backpressure), and reconnect on transport errors; any other non-200
raises :class:`IngestError`. NOT thread-safe — one client per writer
thread (each holds one keep-alive connection)."""
from __future__ import annotations

import http.client
import json
import time
from typing import Callable, Dict, Optional

from repro.core.store import DEFAULT_TENANT
from repro.serving.protocol import encode_update


class IngestError(RuntimeError):
    """A non-retryable upload failure (or retries exhausted)."""

    def __init__(self, msg: str, status: Optional[int] = None):
        super().__init__(msg)
        self.status = status


class HttpStoreClient:
    """One tenant-authenticated uploader over a keep-alive connection.

    ``tokens`` maps tenant -> bearer token (a plain ``token=`` works
    for single-tenant writers)."""

    def __init__(
        self,
        host: str,
        port: int,
        token: Optional[str] = None,
        tokens: Optional[Dict[str, str]] = None,
        timeout: float = 10.0,
        max_attempts: int = 8,
        retry_wait_cap: float = 2.0,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.host = host
        self.port = int(port)
        self._tokens = dict(tokens or {})
        self._token = token
        self.timeout = float(timeout)
        self.max_attempts = int(max_attempts)
        self.retry_wait_cap = float(retry_wait_cap)
        self._sleep = sleep
        self._conn: Optional[http.client.HTTPConnection] = None

    def _token_for(self, tenant: str) -> str:
        tok = self._tokens.get(tenant, self._token)
        if tok is None:
            raise IngestError(f"no token configured for tenant "
                              f"{tenant!r}")
        return tok

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout
            )
        return self._conn

    def _drop_connection(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            finally:
                self._conn = None

    def write(self, client_id: str, update, weight: float = 1.0,
              tenant: str = DEFAULT_TENANT) -> float:
        """Upload one update; returns the server-modeled write latency
        (the same float ``store.write`` returns)."""
        body = encode_update(client_id, update, weight=weight)
        headers = {
            "Authorization": f"Bearer {self._token_for(tenant)}",
            "Content-Type": "application/octet-stream",
        }
        last = "no attempt made"
        for _ in range(self.max_attempts):
            conn = self._connection()
            try:
                conn.request("POST", "/v1/upload", body=body,
                             headers=headers)
                resp = conn.getresponse()
                data = resp.read()
            except (OSError, http.client.HTTPException) as e:
                self._drop_connection()
                last = f"transport error: {e}"
                continue
            if resp.getheader("Connection", "") == "close":
                self._drop_connection()
            if resp.status == 200:
                return float(
                    json.loads(data).get("sim_write_seconds", 0.0)
                )
            if resp.status in (429, 503):
                wait = float(resp.getheader("Retry-After", "0.05"))
                self._sleep(min(max(wait, 0.0), self.retry_wait_cap))
                last = f"{resp.status}: {data[:200]!r}"
                continue
            raise IngestError(
                f"upload rejected ({resp.status}): {data[:500]!r}",
                status=resp.status,
            )
        raise IngestError(
            f"upload failed after {self.max_attempts} attempts "
            f"(last: {last})"
        )

    def close(self) -> None:
        self._drop_connection()

    def __enter__(self) -> "HttpStoreClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
