"""Network ingest front-end for the aggregation service.

The paper studies the aggregator in-process; at the Edge its updates
arrive over the wire. This package is that serving layer, stdlib-only:

  protocol.py   the upload wire frame (dense + int8-compressed),
                fail-closed parser
  admission.py  token auth, size cap, per-tenant token buckets,
                quota headroom pre-check
  ingest.py     bounded IngestQueue: concurrent uploads coalesce into
                batched ``store.write_batch`` commits, explicit 503
                backpressure
  frontend.py   IngestServer — threaded HTTP endpoint tying the above
                together
  client.py     HttpStoreClient — ``store.write`` over HTTP, the drop-in
                transport for trace replays and benchmarks
"""
from repro.serving.admission import (
    AdmissionController,
    Decision,
    TokenBucket,
)
from repro.serving.client import HttpStoreClient, IngestError
from repro.serving.frontend import IngestServer
from repro.serving.ingest import BackpressureError, IngestQueue
from repro.serving.protocol import (
    KIND_COMPRESSED,
    KIND_DENSE,
    MAGIC,
    ParsedUpdate,
    WireError,
    encode_update,
    parse_update,
)

__all__ = [
    "AdmissionController",
    "BackpressureError",
    "Decision",
    "HttpStoreClient",
    "IngestError",
    "IngestQueue",
    "IngestServer",
    "KIND_COMPRESSED",
    "KIND_DENSE",
    "MAGIC",
    "ParsedUpdate",
    "TokenBucket",
    "WireError",
    "encode_update",
    "parse_update",
]
