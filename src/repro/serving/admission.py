"""Admission control for the ingest front-end.

Every upload passes four gates, cheapest first, BEFORE its body is
read off the socket:

  1. auth        — the bearer token must map to a tenant (401);
  2. size        — Content-Length within ``max_body_bytes`` (413);
  3. rate        — the tenant's token bucket has a token (429 +
                   Retry-After with the exact refill wait);
  4. quota       — the tenant's :class:`~repro.core.TenantQuota` has
                   headroom for the declared bytes (429 + Retry-After).

The quota gate here is a conservative PRE-check against the declared
Content-Length (an upper bound on stored payload bytes): it sheds
over-budget uploads before they consume socket reads and queue slots.
The store's own quota check at commit time stays authoritative — a
reject there (e.g. a replacement write racing an eviction) surfaces as
the same 429, and in neither case does a rejected upload land a blob.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional, Tuple


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill up to ``burst``
    capacity. Thread-safe; ``try_acquire`` never blocks — on refusal it
    returns the exact wait until a token exists (the Retry-After)."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError(
                f"rate and burst must be > 0, got rate={rate} "
                f"burst={burst}"
            )
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)  # guarded-by: _lock
        self._last = clock()  # guarded-by: _lock
        self._lock = threading.Lock()

    def try_acquire(self, n: float = 1.0) -> Tuple[bool, float]:
        """``(granted, retry_after_seconds)`` — retry_after is 0.0 when
        granted."""
        with self._lock:
            now = self._clock()
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate
            )
            self._last = now
            if self._tokens >= n:
                self._tokens -= n
                return True, 0.0
            return False, (n - self._tokens) / self.rate


@dataclasses.dataclass(frozen=True)
class Decision:
    """One admission verdict, carrying its HTTP shape."""

    admitted: bool
    status: int = 200
    reason: str = ""
    retry_after: Optional[float] = None


class AdmissionController:
    """Token → tenant auth plus the size / rate / quota gates.

    ``tokens`` maps bearer token → tenant name. ``rate``/``burst``
    install one token bucket per authenticated tenant (None disables
    rate limiting); ``per_tenant_rates`` overrides ``(rate, burst)``
    for specific tenants. ``store`` (optional) enables the quota
    headroom pre-check against ``store.quota(tenant)``."""

    #: Retry-After when the quota (not the rate limiter) rejects: the
    #: wait is bounded by round cadence, not a refill rate, so a fixed
    #: hint is the honest answer.
    quota_retry_after = 1.0

    def __init__(
        self,
        tokens: Dict[str, str],
        store=None,
        rate: Optional[float] = None,
        burst: Optional[float] = None,
        per_tenant_rates: Optional[Dict[str, Tuple[float, float]]] = None,
        max_body_bytes: int = 64 << 20,
        clock: Callable[[], float] = time.monotonic,
    ):
        self._tokens = dict(tokens)
        self._store = store
        self._clock = clock
        self.max_body_bytes = int(max_body_bytes)
        self._buckets: Dict[str, TokenBucket] = {}  # guarded-by: _lock
        self._lock = threading.Lock()
        self._default_rate: Optional[Tuple[float, float]] = None
        if rate is not None:
            self._default_rate = (float(rate), float(burst or rate))
        self._per_tenant_rates = dict(per_tenant_rates or {})

    def tenant_for(self, token: Optional[str]) -> Optional[str]:
        """The tenant a bearer token authenticates, or None (401)."""
        if not token:
            return None
        return self._tokens.get(token)

    def _bucket(self, tenant: str) -> Optional[TokenBucket]:
        cfg = self._per_tenant_rates.get(tenant, self._default_rate)
        if cfg is None:
            return None
        with self._lock:
            b = self._buckets.get(tenant)
            if b is None:
                b = self._buckets[tenant] = TokenBucket(
                    cfg[0], cfg[1], clock=self._clock
                )
            return b

    def admit(self, tenant: str, content_length: int) -> Decision:
        """Gate one authenticated upload of ``content_length`` declared
        body bytes."""
        if content_length > self.max_body_bytes:
            return Decision(
                admitted=False, status=413,
                reason=f"body of {content_length} B exceeds the "
                       f"{self.max_body_bytes} B upload cap",
            )
        bucket = self._bucket(tenant)
        if bucket is not None:
            ok, wait = bucket.try_acquire()
            if not ok:
                return Decision(
                    admitted=False, status=429,
                    reason=f"tenant {tenant!r} over its upload rate",
                    retry_after=wait,
                )
        if self._store is not None:
            q = self._store.quota(tenant)
            # evict-policy tenants trade old updates for new ones at
            # the store — only reject-policy quotas shed at the door
            if q is not None and q.policy == "reject":
                count = self._store.count(tenant=tenant)
                tbytes = self._store.tenant_bytes(tenant)
                over_count = (q.max_updates is not None
                              and count + 1 > q.max_updates)
                over_bytes = (q.max_bytes is not None
                              and tbytes + content_length > q.max_bytes)
                if over_count or over_bytes:
                    return Decision(
                        admitted=False, status=429,
                        reason=f"tenant {tenant!r} quota has no "
                               f"headroom for {content_length} B",
                        retry_after=self.quota_retry_after,
                    )
        return Decision(admitted=True)
