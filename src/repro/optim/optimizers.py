"""Optimizers as (init, update) function pairs over arbitrary pytrees.

AdamW keeps fp32 moments regardless of the param dtype (bf16 training);
the update is computed in fp32 and cast back on apply.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, jnp.ndarray], Tuple[PyTree, PyTree]]
    # update(grads, state, step) -> (updates, new_state); caller applies.


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree
    )


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(
            p.dtype
        ),
        params,
        updates,
    )


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def update(grads, state, step, params=None):
        del params
        step_lr = lr_fn(step)
        if momentum == 0.0:
            ups = jax.tree_util.tree_map(
                lambda g: -step_lr * g.astype(jnp.float32), grads
            )
            return ups, state
        new_v = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g.astype(jnp.float32), state, grads
        )
        ups = jax.tree_util.tree_map(lambda v: -step_lr * v, new_v)
        return ups, new_v

    return Optimizer(init=init, update=update)


class AdamState(NamedTuple):
    m: PyTree
    v: PyTree


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32) -> Optimizer:
    """``moment_dtype=jnp.bfloat16`` halves optimizer-state memory and
    traffic (a documented §Perf lever for the biggest training combos) at
    a small second-moment precision cost; updates still compute in fp32."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamState(
            m=jax.tree_util.tree_map(z, params),
            v=jax.tree_util.tree_map(z, params),
        )

    def update(grads, state, step, params=None):
        step_lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        new_m = jax.tree_util.tree_map(
            lambda m, g: (
                b1 * m.astype(jnp.float32)
                + (1 - b1) * g.astype(jnp.float32)
            ).astype(moment_dtype),
            state.m, grads,
        )
        new_v = jax.tree_util.tree_map(
            lambda v, g: (
                b2 * v.astype(jnp.float32)
                + (1 - b2) * jnp.square(g.astype(jnp.float32))
            ).astype(moment_dtype),
            state.v, grads,
        )
        ups = jax.tree_util.tree_map(
            lambda m, v: -step_lr * (m.astype(jnp.float32) / bc1)
            / (jnp.sqrt(v.astype(jnp.float32) / bc2) + eps),
            new_m, new_v,
        )
        if weight_decay and params is not None:
            # decoupled (AdamW) decay
            ups = jax.tree_util.tree_map(
                lambda u, p: u - step_lr * weight_decay
                * p.astype(jnp.float32),
                ups, params,
            )
        return ups, AdamState(m=new_m, v=new_v)

    return Optimizer(init=init, update=update)
