"""Federated-learning runtime: clients, server rounds, orchestration."""
from repro.fl.client import Client
from repro.fl.server import (
    EdgeAggregatorServer,
    FederatedServer,
    RoundResult,
)

__all__ = [
    "Client",
    "EdgeAggregatorServer",
    "FederatedServer",
    "RoundResult",
]
