"""FL client: local training on private data, emits a model update.

Update semantics (IBMFL-compatible):
  * fedavg/iteravg/robust fusions — the update is the client's POST-
    training weights (the paper aggregates weights, Eq. (1)).
  * gradavg/fedavgm/fedadam — the update is the weight DELTA (pseudo-
    gradient) after local steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import Model
from repro.optim import Optimizer, apply_updates, clip_by_global_norm

PyTree = Any


@dataclasses.dataclass
class Client:
    client_id: int
    model: Model
    optimizer: Optimizer
    local_steps: int = 1
    clip_norm: Optional[float] = None
    send_delta: bool = False     # True for gradavg-family fusions

    def __post_init__(self):
        loss_fn = self.model.loss

        def one_step(params, opt_state, batch, step):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
            if self.clip_norm:
                grads = clip_by_global_norm(grads, self.clip_norm)
            ups, opt_state = self.optimizer.update(
                grads, opt_state, step, params
            )
            return apply_updates(params, ups), opt_state, loss

        self._step = jax.jit(one_step)

    def train_round(
        self, global_params: PyTree, batch_fn: Callable[[int], Dict],
        round_idx: int,
    ) -> Tuple[PyTree, float]:
        """Runs ``local_steps`` steps from the global params. Returns
        (update, last_loss)."""
        params = global_params
        opt_state = self.optimizer.init(params)
        loss = jnp.inf
        for s in range(self.local_steps):
            batch = batch_fn(s)
            params, opt_state, loss = self._step(
                params, opt_state, batch, jnp.asarray(s, jnp.int32)
            )
        if self.send_delta:
            update = jax.tree_util.tree_map(
                lambda new, old: (
                    new.astype(jnp.float32) - old.astype(jnp.float32)
                ),
                params, global_params,
            )
        else:
            update = params
        return update, float(loss)
