"""FL servers: orchestrate rounds through the AggregationService.

``FederatedServer`` is deliberately thin — client selection, broadcast,
collect, aggregate, apply — because the aggregation SERVICE is the
paper's object of study. The server consumes RoundReports (which
engine ran, monitor state, seamless-transition routing) and exposes
them to benchmarks.

``EdgeAggregatorServer`` is the Edge deployment composition: one
``repro.serving.IngestServer`` (HTTP uploads with admission control)
feeding one ``UpdateStore``, with rounds admitted through a
``FairRoundScheduler`` on one shared ``AggregationService`` — the
object ``repro.launch.serve`` runs and ``benchmarks/ingest_service.py``
measures.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.service import (
    AggregationService,
    FairRoundScheduler,
    RoundReport,
)
from repro.data.loader import FederatedLoader
from repro.fl.client import Client
from repro.models.base import Model

PyTree = Any


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    mean_client_loss: float
    report: RoundReport
    n_selected: int


class FederatedServer:
    def __init__(
        self,
        model: Model,
        clients: Sequence[Client],
        loader: FederatedLoader,
        service: AggregationService,
        rng_seed: int = 0,
        clients_per_round: Optional[int] = None,
    ):
        self.model = model
        self.clients = list(clients)
        self.loader = loader
        self.service = service
        self.rng = np.random.default_rng(rng_seed)
        self.clients_per_round = clients_per_round or len(self.clients)
        self.params = model.init(jax.random.PRNGKey(rng_seed))
        self.results: List[RoundResult] = []

    def run_round(self, round_idx: int) -> RoundResult:
        sel = self.rng.choice(
            len(self.clients), size=self.clients_per_round, replace=False
        )
        updates, weights, losses = [], [], []
        send_delta = any(self.clients[i].send_delta for i in sel)
        for i in sel:
            c = self.clients[i]
            batch_fn = lambda s, i=i: self.loader.client_batch(
                c.client_id, round_idx * 1000 + s
            )
            upd, loss = c.train_round(self.params, batch_fn, round_idx)
            updates.append(upd)
            weights.append(self.loader.client_weight(c.client_id))
            losses.append(loss)

        fused, report = self.service.aggregate(
            updates=updates, weights=weights, template=self.params,
        )
        if send_delta:
            # pseudo-gradient: apply fused delta to the global weights
            self.params = jax.tree_util.tree_map(
                lambda p, d: (
                    p.astype(jnp.float32) + d.astype(jnp.float32)
                ).astype(p.dtype),
                self.params, fused,
            )
        else:
            self.params = jax.tree_util.tree_map(
                lambda p, f: f.astype(p.dtype), self.params, fused
            )
        res = RoundResult(
            round_idx=round_idx,
            mean_client_loss=float(np.mean(losses)),
            report=report,
            n_selected=len(sel),
        )
        self.results.append(res)
        return res

    def run(self, n_rounds: int) -> List[RoundResult]:
        return [self.run_round(r) for r in range(n_rounds)]


class EdgeAggregatorServer:
    """The network-facing aggregator: HTTP ingest + fair round
    admission over ONE AggregationService.

    Composition, not new machinery: an ``IngestServer`` (token auth,
    rate limits, quota pre-checks, batched ``IngestQueue`` commits)
    lands uploads in ``service.store``; a ``FairRoundScheduler``
    admits rounds with weighted-fair tenant selection under a
    concurrency cap. ``tokens`` maps bearer token -> tenant.

        svc = AggregationService(fusion="fedavg", store=UpdateStore(),
                                 threshold_frac=1.0, monitor_timeout=5)
        with EdgeAggregatorServer(svc, {"tok-a": "appA"}) as edge:
            ...clients POST to edge.url...
            fused, report = edge.run_round("appA", expected_clients=48)

    ``frontend_kwargs`` pass through to ``IngestServer`` (rate, burst,
    queue_size, batch_max, read_timeout, max_body_bytes, ...);
    scheduler knobs are explicit."""

    def __init__(
        self,
        service: AggregationService,
        tokens: Dict[str, str],
        host: str = "127.0.0.1",
        port: int = 0,
        max_running: int = 2,
        weights: Optional[Dict[str, float]] = None,
        capacity_bytes: Optional[int] = None,
        **frontend_kwargs,
    ):
        # imported here: repro.fl must stay importable without the
        # serving layer's http machinery loaded for in-process use
        from repro.serving.frontend import IngestServer

        if service.store is None:
            raise ValueError(
                "EdgeAggregatorServer needs a store-backed service "
                "(AggregationService(store=UpdateStore(...)))"
            )
        self.service = service
        self.frontend = IngestServer(
            service.store, tokens, host=host, port=port,
            **frontend_kwargs,
        )
        self.scheduler = FairRoundScheduler(
            service, max_running=max_running, weights=weights,
            capacity_bytes=capacity_bytes,
        )

    @property
    def port(self) -> int:
        return self.frontend.port

    @property
    def url(self) -> str:
        return self.frontend.url

    def submit_round(self, tenant: str, **aggregate_kwargs):
        """Queue one round through the fair scheduler (Future of
        ``(fused, RoundReport)``)."""
        return self.scheduler.submit(
            tenant, from_store=True, **aggregate_kwargs
        )

    def run_round(self, tenant: str, **aggregate_kwargs):
        """One tenant's round, synchronously."""
        return self.submit_round(tenant, **aggregate_kwargs).result()

    def run_rounds(
        self, tenants: Sequence[str], **aggregate_kwargs
    ) -> Dict[str, Tuple[PyTree, RoundReport]]:
        """A fair fan-out across tenants; waits for all."""
        futs = {t: self.submit_round(t, **aggregate_kwargs)
                for t in tenants}
        return {t: f.result() for t, f in futs.items()}

    def metrics(self) -> dict:
        out = self.frontend.metrics()
        out["rounds_admitted"] = len(self.scheduler.admission_order())
        out["rounds_running"] = len(self.scheduler.running())
        return out

    def close(self) -> None:
        self.scheduler.shutdown()
        self.frontend.close()

    def __enter__(self) -> "EdgeAggregatorServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
