"""FL server: orchestrates rounds through the AggregationService.

The server is deliberately thin — client selection, broadcast, collect,
aggregate, apply — because the aggregation SERVICE is the paper's object
of study. The server consumes RoundReports (which engine ran, monitor
state, seamless-transition routing) and exposes them to benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.service import AggregationService, RoundReport
from repro.data.loader import FederatedLoader
from repro.fl.client import Client
from repro.models.base import Model
from repro.utils.pytree import flat_vector_to_tree

PyTree = Any


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    mean_client_loss: float
    report: RoundReport
    n_selected: int


class FederatedServer:
    def __init__(
        self,
        model: Model,
        clients: Sequence[Client],
        loader: FederatedLoader,
        service: AggregationService,
        rng_seed: int = 0,
        clients_per_round: Optional[int] = None,
    ):
        self.model = model
        self.clients = list(clients)
        self.loader = loader
        self.service = service
        self.rng = np.random.default_rng(rng_seed)
        self.clients_per_round = clients_per_round or len(self.clients)
        self.params = model.init(jax.random.PRNGKey(rng_seed))
        self.results: List[RoundResult] = []

    def run_round(self, round_idx: int) -> RoundResult:
        sel = self.rng.choice(
            len(self.clients), size=self.clients_per_round, replace=False
        )
        updates, weights, losses = [], [], []
        send_delta = any(self.clients[i].send_delta for i in sel)
        for i in sel:
            c = self.clients[i]
            batch_fn = lambda s, i=i: self.loader.client_batch(
                c.client_id, round_idx * 1000 + s
            )
            upd, loss = c.train_round(self.params, batch_fn, round_idx)
            updates.append(upd)
            weights.append(self.loader.client_weight(c.client_id))
            losses.append(loss)

        fused, report = self.service.aggregate(
            updates=updates, weights=weights, template=self.params,
        )
        if send_delta:
            # pseudo-gradient: apply fused delta to the global weights
            self.params = jax.tree_util.tree_map(
                lambda p, d: (
                    p.astype(jnp.float32) + d.astype(jnp.float32)
                ).astype(p.dtype),
                self.params, fused,
            )
        else:
            self.params = jax.tree_util.tree_map(
                lambda p, f: f.astype(p.dtype), self.params, fused
            )
        res = RoundResult(
            round_idx=round_idx,
            mean_client_loss=float(np.mean(losses)),
            report=report,
            n_selected=len(sel),
        )
        self.results.append(res)
        return res

    def run(self, n_rounds: int) -> List[RoundResult]:
        return [self.run_round(r) for r in range(n_rounds)]
