"""Regime schedules — mid-run arrival shifts with exact boundaries.

A ``RegimeSchedule`` is a piecewise map from round index to arrival
process. Boundaries are EXACT: the round at ``start_round`` already
samples from the NEW regime (segment ``i`` covers
``[start_round_i, start_round_{i+1})``). This is what the adaptive
gate's drift/rewarm machinery gets measured against.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.workload.arrivals import ArrivalProcess, arrival_from_dict


@dataclasses.dataclass(frozen=True)
class Regime:
    """One named segment: ``arrivals`` in force from ``start_round``."""

    name: str
    arrivals: ArrivalProcess
    start_round: int = 0


@dataclasses.dataclass(frozen=True)
class RegimeSchedule:
    segments: Tuple[Regime, ...]

    def __init__(self, segments: Sequence[Regime]):
        segs = tuple(sorted(segments, key=lambda s: s.start_round))
        if not segs:
            raise ValueError("RegimeSchedule needs at least one regime")
        if segs[0].start_round != 0:
            raise ValueError("first regime must start at round 0 "
                             f"(got {segs[0].start_round})")
        starts = [s.start_round for s in segs]
        if len(set(starts)) != len(starts):
            raise ValueError(f"duplicate regime start rounds: {starts}")
        object.__setattr__(self, "segments", segs)

    @classmethod
    def single(cls, arrivals: ArrivalProcess,
               name: str = "steady") -> "RegimeSchedule":
        return cls([Regime(name, arrivals, 0)])

    def at(self, round_index: int) -> Regime:
        """The regime in force for ``round_index`` (new regime applies
        AT its start round)."""
        if round_index < 0:
            raise ValueError(f"round_index must be >= 0, "
                             f"got {round_index}")
        chosen = self.segments[0]
        for seg in self.segments:
            if seg.start_round <= round_index:
                chosen = seg
            else:
                break
        return chosen

    def to_dict(self) -> dict:
        return {"segments": [
            {"name": s.name, "start_round": s.start_round,
             "arrivals": s.arrivals.to_dict()}
            for s in self.segments
        ]}

    @classmethod
    def from_dict(cls, d: dict) -> "RegimeSchedule":
        return cls([
            Regime(name=s["name"],
                   arrivals=arrival_from_dict(s["arrivals"]),
                   start_round=int(s["start_round"]))
            for s in d["segments"]
        ])
