"""Arrival processes — when, within a round, each client's update lands.

Each process turns a seeded ``numpy.random.Generator`` into ONE round's
client-arrival offsets (seconds from round open, sorted ascending).
Returning fewer than ``n`` offsets models client dropout: absent
clients never write, and the round's gate has to decide how long to
wait for them — exactly the regime the adaptive controller targets.

All processes are frozen dataclasses. ``to_dict`` emits a plain dict
(a ``kind`` tag plus the constructor fields) and ``arrival_from_dict``
reconstitutes it bit-identically — the contract the trace file format
(``repro.workload.trace``) is built on. Sampling must depend only on
``(rng, n, round_index)`` so a trace built twice from one seed is
byte-identical.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Dict, Tuple, Type

import numpy as np

_REGISTRY: Dict[str, Type["ArrivalProcess"]] = {}


def register_arrival(cls):
    """Class decorator: adds the process to the ``kind`` registry that
    ``arrival_from_dict`` dispatches on."""
    _REGISTRY[cls.kind] = cls
    return cls


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base: one round's arrival offsets from a seeded Generator."""

    kind: ClassVar[str] = "base"

    def sample(self, rng: np.random.Generator, n: int,
               round_index: int = 0) -> np.ndarray:
        """Offsets (seconds from round open) for the clients that DO
        arrive this round, sorted ascending, length <= n."""
        raise NotImplementedError

    def to_dict(self) -> dict:
        d: dict = {"kind": self.kind}
        # pure-JSON values only (tuples -> lists), so the in-memory
        # dict equals its JSON round-trip, not just hash-equals it
        d.update({k: list(v) if isinstance(v, tuple) else v
                  for k, v in dataclasses.asdict(self).items()})
        return d


def arrival_from_dict(d: dict) -> "ArrivalProcess":
    """Inverse of ``to_dict`` for every registered process."""
    d = dict(d)
    kind = d.pop("kind")
    if kind not in _REGISTRY:
        raise ValueError(f"unknown arrival kind {kind!r} "
                         f"(known: {sorted(_REGISTRY)})")
    cls = _REGISTRY[kind]
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(f"{kind}: unknown fields {sorted(unknown)}")
    # JSON has no tuples: window/range fields come back as lists
    kw = {k: tuple(v) if isinstance(v, list) else v for k, v in d.items()}
    return cls(**kw)


@register_arrival
@dataclasses.dataclass(frozen=True)
class UniformArrivals(ArrivalProcess):
    """Evenly spaced over ``spread`` seconds — the benchmarks' classic
    ``(i+1) * spread / n`` schedule. ``arrive_frac < 1`` drops the
    tail (the latest clients never show)."""

    kind: ClassVar[str] = "uniform"

    spread: float = 1.0
    arrive_frac: float = 1.0

    def sample(self, rng, n, round_index=0):
        arrive = max(int(n * self.arrive_frac), 1)
        return np.linspace(self.spread / n, self.spread, n)[:arrive]


@register_arrival
@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at ``rate`` clients/second (exponential
    inter-arrival gaps)."""

    kind: ClassVar[str] = "poisson"

    rate: float = 10.0
    arrive_frac: float = 1.0

    def sample(self, rng, n, round_index=0):
        arrive = max(int(n * self.arrive_frac), 1)
        gaps = rng.exponential(1.0 / self.rate, size=arrive)
        return np.cumsum(gaps)


@register_arrival
@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """A front-loaded burst with dropout: ``arrive_frac`` of the fleet
    lands uniformly inside ``window`` (fractions of ``spread``), the
    rest never arrive — the scenario where a static full-inclusion
    gate burns its whole timeout every round."""

    kind: ClassVar[str] = "bursty"

    spread: float = 1.0
    arrive_frac: float = 0.9
    window: Tuple[float, float] = (0.05, 0.15)

    def sample(self, rng, n, round_index=0):
        arrive = max(int(n * self.arrive_frac), 1)
        lo, hi = self.window
        burst = rng.uniform(lo * self.spread, hi * self.spread,
                            size=arrive)
        return np.sort(burst)


@register_arrival
@dataclasses.dataclass(frozen=True)
class LognormalArrivals(ArrivalProcess):
    """Heavy-tailed: most clients early (median at ``median_frac *
    spread``), a long straggler tail clipped to ``spread``;
    ``drop_clients`` of the fleet never arrive."""

    kind: ClassVar[str] = "lognormal"

    spread: float = 1.0
    sigma: float = 0.6
    median_frac: float = 0.2
    drop_clients: int = 2

    def sample(self, rng, n, round_index=0):
        arrive = max(n - self.drop_clients, 1)
        body = rng.lognormal(mean=math.log(self.median_frac * self.spread),
                             sigma=self.sigma, size=arrive)
        return np.sort(np.clip(body, 0.0, self.spread))


@register_arrival
@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Non-homogeneous Poisson over one ``period``-second window with a
    sinusoidal rate between ``base_rate`` and ``peak_rate`` (thinning
    sampler). ``round_advance`` shifts the phase every round, so a
    soak sweeps through peak and trough traffic — clients that don't
    arrive before the window closes are dropped."""

    kind: ClassVar[str] = "diurnal"

    period: float = 4.0
    base_rate: float = 2.0
    peak_rate: float = 16.0
    phase: float = 0.0
    round_advance: float = 0.125

    def rate_at(self, t: float, phase: float) -> float:
        cyc = 0.5 * (1.0 - math.cos(
            2.0 * math.pi * (t / self.period + phase)))
        return self.base_rate + (self.peak_rate - self.base_rate) * cyc

    def sample(self, rng, n, round_index=0):
        lam_max = max(self.peak_rate, self.base_rate, 1e-12)
        phase = self.phase + round_index * self.round_advance
        out = []
        t = 0.0
        while len(out) < n:
            t += rng.exponential(1.0 / lam_max)
            if t >= self.period:
                break
            if rng.uniform() * lam_max <= self.rate_at(t, phase):
                out.append(t)
        return np.asarray(out, dtype=np.float64)
