"""Tenant churn — cold-start tenants joining (and leaving) mid-soak.

The base tenants in a ``WorkloadSpec`` run the whole horizon; churn
adds tenants that appear at some round with NO arrival history — the
cross-tenant prior's target population — and optionally retire after a
lifetime. Joins are either scheduled exactly (``scheduled_joins``,
deterministic regardless of seed) or Poisson-random per round
(``join_rate``, deterministic given the trace seed).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantChurn:
    """``schedule`` expands to per-round active churn-tenant lists."""

    join_rate: float = 0.0
    # mean lifetime in rounds for random joins (geometric); None: stays
    lifetime_rounds: Optional[int] = None
    # exact (join_round, lifetime_or_None) pairs, independent of seed
    scheduled_joins: Tuple[Tuple[int, Optional[int]], ...] = ()
    prefix: str = "churn"

    def schedule(self, rng: np.random.Generator,
                 rounds: int) -> List[List[str]]:
        """Per-round sorted lists of active churn tenants
        (``f"{prefix}{i}"``, numbered in join order)."""
        spans: List[Tuple[int, int, str]] = []
        idx = 0
        for join, life in self.scheduled_joins:
            if not 0 <= join < rounds:
                raise ValueError(f"scheduled join at round {join} outside "
                                 f"horizon [0, {rounds})")
            end = rounds if life is None else min(join + life, rounds)
            spans.append((join, end, f"{self.prefix}{idx}"))
            idx += 1
        if self.join_rate > 0.0:
            for r in range(rounds):
                for _ in range(int(rng.poisson(self.join_rate))):
                    if self.lifetime_rounds is None:
                        end = rounds
                    else:
                        life = int(rng.geometric(
                            1.0 / max(self.lifetime_rounds, 1)))
                        end = min(r + life, rounds)
                    spans.append((r, end, f"{self.prefix}{idx}"))
                    idx += 1
        active: List[List[str]] = [[] for _ in range(rounds)]
        for start, end, name in spans:
            for r in range(start, end):
                active[r].append(name)
        for names in active:
            names.sort()
        return active

    def to_dict(self) -> dict:
        return {
            "join_rate": self.join_rate,
            "lifetime_rounds": self.lifetime_rounds,
            "scheduled_joins": [list(j) for j in self.scheduled_joins],
            "prefix": self.prefix,
        }


def churn_from_dict(d: dict) -> TenantChurn:
    return TenantChurn(
        join_rate=d.get("join_rate", 0.0),
        lifetime_rounds=d.get("lifetime_rounds"),
        scheduled_joins=tuple(
            (int(j[0]), None if j[1] is None else int(j[1]))
            for j in d.get("scheduled_joins", ())
        ),
        prefix=d.get("prefix", "churn"),
    )
