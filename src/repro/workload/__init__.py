"""Trace-driven workload generation — the evaluation substrate.

Benchmarks used to script one arrival pattern inline per scenario;
this package makes the workload itself a first-class, seeded,
serializable object:

  * ``arrivals`` — ``ArrivalProcess`` hierarchy (uniform / Poisson /
    bursty / lognormal heavy-tail / diurnal), each turning a seeded
    Generator into one round's client-arrival offsets; returning fewer
    than ``n`` offsets models dropout.
  * ``sizes`` — ``SizeDistribution`` (fixed / lognormal /
    per-model-config via the Table-I CNN suite): params per update,
    sampled once per tenant.
  * ``churn`` — ``TenantChurn``: cold-start tenants joining (and
    leaving) mid-soak, scheduled or Poisson-random.
  * ``regime`` — ``RegimeSchedule``: piecewise arrival regimes with
    exact round boundaries, for mid-run shifts.
  * ``trace`` — ``WorkloadSpec.build(seed)`` compiles the above into a
    ``WorkloadTrace`` (every round, tenant, client offset and weight),
    serializable to/from a canonical JSON file bit-for-bit; identical
    seeds hash identically (``trace_hash``).
  * ``replay`` — drives a trace against a live ``UpdateStore`` on a
    real or scripted clock, with deterministic payloads.

The classifier in ``repro.core.workload`` (the paper's Algorithm 1
condition) is re-exported here so ``repro.workload`` is the single
import point for "what load is this" AND "generate that load".
"""
from repro.core.workload import (           # noqa: F401  (re-export)
    HBM_HEADROOM,
    Workload,
    WorkloadClass,
    classify,
    max_clients_single_node,
)
from repro.workload.arrivals import (       # noqa: F401
    ArrivalProcess,
    BurstyArrivals,
    DiurnalArrivals,
    LognormalArrivals,
    PoissonArrivals,
    UniformArrivals,
    arrival_from_dict,
)
from repro.workload.churn import TenantChurn, churn_from_dict  # noqa: F401
from repro.workload.regime import Regime, RegimeSchedule       # noqa: F401
from repro.workload.replay import (         # noqa: F401
    replay_round,
    start_writer,
    trace_payload,
)
from repro.workload.sizes import (          # noqa: F401
    FixedSize,
    LognormalSize,
    ModelConfigSize,
    SizeDistribution,
    size_from_dict,
)
from repro.workload.trace import (          # noqa: F401
    ClientEvent,
    RoundTrace,
    TenantRound,
    WorkloadSpec,
    WorkloadTrace,
    build_trace,
)

__all__ = [
    "HBM_HEADROOM",
    "Workload",
    "WorkloadClass",
    "classify",
    "max_clients_single_node",
    "ArrivalProcess",
    "UniformArrivals",
    "PoissonArrivals",
    "BurstyArrivals",
    "LognormalArrivals",
    "DiurnalArrivals",
    "arrival_from_dict",
    "SizeDistribution",
    "FixedSize",
    "LognormalSize",
    "ModelConfigSize",
    "size_from_dict",
    "TenantChurn",
    "churn_from_dict",
    "Regime",
    "RegimeSchedule",
    "ClientEvent",
    "TenantRound",
    "RoundTrace",
    "WorkloadSpec",
    "WorkloadTrace",
    "build_trace",
    "replay_round",
    "start_writer",
    "trace_payload",
]
