"""Trace compilation + canonical serialization.

``WorkloadSpec.build(seed)`` expands the generator stack (regimes x
sizes x churn) into a ``WorkloadTrace``: every round, every active
tenant, every arriving client's offset and weight. Determinism is
per-stream: each (round, tenant) gets its own
``default_rng([seed, stream, round, crc32(tenant)])``, so traces are
reproducible bit-for-bit and insensitive to iteration order.

Serialization is CANONICAL — ``canonical_json`` is the one string form
(sorted keys, compact separators), ``to_json`` writes exactly it, and
``trace_hash`` is its sha256 — so "identical seed => identical trace
file" is a byte-level guarantee, not a float-tolerance one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.workload.churn import TenantChurn, churn_from_dict
from repro.workload.regime import RegimeSchedule
from repro.workload.sizes import FixedSize, SizeDistribution, size_from_dict

TRACE_VERSION = 1

# independent seed streams: churn schedule / per-tenant size /
# per-(round, tenant) arrivals+weights / replay payloads
_CHURN_STREAM = 1
_SIZE_STREAM = 2
_ROUND_STREAM = 3
PAYLOAD_STREAM = 4


def _crc(name: str) -> int:
    # crc32, not hash(): streams must be stable across processes
    return zlib.crc32(name.encode())


@dataclasses.dataclass(frozen=True)
class ClientEvent:
    """One client's write: arrives ``offset`` seconds after round open."""

    client_id: str
    offset: float
    weight: float


@dataclasses.dataclass(frozen=True)
class TenantRound:
    tenant: str
    expected: int     # the gate's denominator (dropped clients included)
    dim: int          # params per update for this tenant
    regime: str       # regime name in force this round
    events: Tuple[ClientEvent, ...]


@dataclasses.dataclass(frozen=True)
class RoundTrace:
    index: int
    tenants: Tuple[TenantRound, ...]

    def tenant(self, name: str) -> TenantRound:
        for tr in self.tenants:
            if tr.tenant == name:
                return tr
        raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """The generator stack; ``build(seed)`` compiles it to a trace."""

    tenants: Tuple[str, ...]
    n_clients: int
    rounds: int
    regimes: RegimeSchedule
    sizes: SizeDistribution = dataclasses.field(default_factory=FixedSize)
    churn: Optional[TenantChurn] = None
    weight_range: Tuple[float, float] = (1.0, 7.0)

    def build(self, seed: int) -> "WorkloadTrace":
        churn_rng = np.random.default_rng([seed, _CHURN_STREAM])
        churn_active = (
            self.churn.schedule(churn_rng, self.rounds)
            if self.churn is not None
            else [[] for _ in range(self.rounds)]
        )
        dims: Dict[str, int] = {}

        def dim_for(tenant: str) -> int:
            if tenant not in dims:
                srng = np.random.default_rng(
                    [seed, _SIZE_STREAM, _crc(tenant)])
                dims[tenant] = int(self.sizes.sample(srng))
            return dims[tenant]

        rounds = []
        for r in range(self.rounds):
            regime = self.regimes.at(r)
            active = list(self.tenants) + churn_active[r]
            tenant_rounds = []
            for t in active:
                rng = np.random.default_rng(
                    [seed, _ROUND_STREAM, r, _crc(t)])
                offsets = np.sort(np.asarray(
                    regime.arrivals.sample(rng, self.n_clients,
                                           round_index=r),
                    dtype=np.float64))
                lo, hi = self.weight_range
                weights = rng.uniform(lo, hi, size=len(offsets))
                events = tuple(
                    ClientEvent(f"client{i:05d}", float(o), float(w))
                    for i, (o, w) in enumerate(zip(offsets, weights))
                )
                tenant_rounds.append(TenantRound(
                    tenant=t, expected=self.n_clients, dim=dim_for(t),
                    regime=regime.name, events=events,
                ))
            rounds.append(RoundTrace(index=r, tenants=tuple(tenant_rounds)))
        return WorkloadTrace(seed=seed, spec=self.to_dict(),
                             rounds=tuple(rounds))

    def to_dict(self) -> dict:
        return {
            "tenants": list(self.tenants),
            "n_clients": self.n_clients,
            "rounds": self.rounds,
            "regimes": self.regimes.to_dict(),
            "sizes": self.sizes.to_dict(),
            "churn": self.churn.to_dict() if self.churn else None,
            "weight_range": list(self.weight_range),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        return cls(
            tenants=tuple(d["tenants"]),
            n_clients=int(d["n_clients"]),
            rounds=int(d["rounds"]),
            regimes=RegimeSchedule.from_dict(d["regimes"]),
            sizes=size_from_dict(d["sizes"]),
            churn=(churn_from_dict(d["churn"])
                   if d.get("churn") else None),
            weight_range=tuple(d.get("weight_range", (1.0, 7.0))),
        )


@dataclasses.dataclass(frozen=True)
class WorkloadTrace:
    seed: int
    spec: dict
    rounds: Tuple[RoundTrace, ...]

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def to_dict(self) -> dict:
        return {
            "version": TRACE_VERSION,
            "seed": self.seed,
            "spec": self.spec,
            "rounds": [
                {
                    "index": rt.index,
                    "tenants": [
                        {
                            "tenant": tr.tenant,
                            "expected": tr.expected,
                            "dim": tr.dim,
                            "regime": tr.regime,
                            "events": [[e.client_id, e.offset, e.weight]
                                       for e in tr.events],
                        }
                        for tr in rt.tenants
                    ],
                }
                for rt in self.rounds
            ],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadTrace":
        version = d.get("version")
        if version != TRACE_VERSION:
            raise ValueError(f"trace version {version!r} != "
                             f"{TRACE_VERSION}")
        return cls(
            seed=int(d["seed"]),
            spec=d["spec"],
            rounds=tuple(
                RoundTrace(
                    index=int(rt["index"]),
                    tenants=tuple(
                        TenantRound(
                            tenant=tr["tenant"],
                            expected=int(tr["expected"]),
                            dim=int(tr["dim"]),
                            regime=tr["regime"],
                            events=tuple(
                                ClientEvent(cid, float(off), float(w))
                                for cid, off, w in tr["events"]
                            ),
                        )
                        for tr in rt["tenants"]
                    ),
                )
                for rt in d["rounds"]
            ),
        )

    def canonical_json(self) -> str:
        """THE string form: sorted keys, compact separators. Hash and
        file contents both derive from it."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def trace_hash(self) -> str:
        return hashlib.sha256(
            self.canonical_json().encode()).hexdigest()

    def to_json(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.canonical_json())
            f.write("\n")
        return path

    @classmethod
    def from_json(cls, path: str) -> "WorkloadTrace":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def build_trace(spec: WorkloadSpec, seed: int) -> WorkloadTrace:
    """Module-level convenience mirror of ``spec.build(seed)``."""
    return spec.build(seed)
