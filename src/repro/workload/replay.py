"""Replay — drive a trace's tenant-round against a live ``UpdateStore``.

``replay_round`` writes each traced client at its offset on an
injectable clock: real ``time.perf_counter``/``time.sleep`` in
benchmarks (``start_writer`` wraps it in a daemon thread, the
``spread_writer`` idiom), or a test's scripted clock for fully
deterministic arrival timestamps. Payloads are deterministic in
``(seed, tenant, client_id, dim)`` via ``trace_payload``, so a replay
is reproducible end to end — the fused vector included.
"""
from __future__ import annotations

import threading
import time
import zlib
from functools import lru_cache
from typing import Callable, Optional

import numpy as np

from repro.workload.trace import PAYLOAD_STREAM, TenantRound


@lru_cache(maxsize=1024)
def _payload_cached(seed: int, tenant: str, client_id: str,
                    dim: int) -> np.ndarray:
    rng = np.random.default_rng([
        seed, PAYLOAD_STREAM,
        zlib.crc32(tenant.encode()), zlib.crc32(client_id.encode()),
    ])
    arr = rng.normal(size=(dim,)).astype(np.float32)
    arr.flags.writeable = False
    return arr


def trace_payload(seed: int, tenant: str, client_id: str,
                  dim: int) -> np.ndarray:
    """The deterministic fp32 update a traced client writes.

    Round-independent by design — a client re-sends the same update
    every round, like the fixed client matrices of the per-scenario
    benches — so payloads are cached (read-only) across rounds and
    synthesis is paid once per client, not once per round."""
    return _payload_cached(seed, tenant, client_id, dim)


def replay_round(
    store,
    tenant_round: TenantRound,
    seed: int,
    clock: Callable[[], float] = time.perf_counter,
    sleep: Callable[[float], None] = time.sleep,
    transform: Optional[Callable[[str, np.ndarray], object]] = None,
    writer: Optional[Callable[..., float]] = None,
) -> int:
    """Write every traced event at its offset (measured on ``clock``,
    waited on ``sleep``). ``transform(client_id, update)`` hooks
    client-side processing — e.g. ``svc.compress_update`` for int8
    transport. Returns the number of writes.

    ``writer`` swaps the transport: it defaults to ``store.write`` but
    takes any callable with the same ``(client_id, update, weight=,
    tenant=)`` signature — pass an
    ``repro.serving.HttpStoreClient.write`` bound method to replay the
    SAME trace over real sockets through the ingest front-end (then
    ``store`` may be None).

    Payloads (and transforms) are materialized BEFORE the replay clock
    starts: the trace's offsets model network arrival times, and a
    client's update exists before it is sent — synthesis cost must not
    skew the arrival schedule or the measured round wall."""
    if writer is None:
        writer = store.write
    ready = []
    for ev in tenant_round.events:
        u = trace_payload(seed, tenant_round.tenant, ev.client_id,
                          tenant_round.dim)
        if transform is not None:
            u = transform(ev.client_id, u)
        ready.append((ev, u))
    t0 = clock()
    for ev, u in ready:
        lag = ev.offset - (clock() - t0)
        if lag > 0:
            sleep(lag)
        writer(ev.client_id, u, weight=ev.weight,
               tenant=tenant_round.tenant)
    return len(tenant_round.events)


def start_writer(
    store,
    tenant_round: TenantRound,
    seed: int,
    clock: Callable[[], float] = time.perf_counter,
    sleep: Callable[[float], None] = time.sleep,
    transform: Optional[Callable[[str, np.ndarray], object]] = None,
    writer: Optional[Callable[..., float]] = None,
) -> threading.Thread:
    """``replay_round`` on a started daemon thread — arrivals land
    WHILE the round is open (the benchmarks' writer idiom)."""
    t = threading.Thread(  # lint: disable=thread-join -- the handle is RETURNED; callers (benchmarks, soak harness) own the join
        target=replay_round,
        args=(store, tenant_round, seed),
        kwargs={"clock": clock, "sleep": sleep, "transform": transform,
                "writer": writer},
        name=f"trace-writer-{tenant_round.tenant}",
        daemon=True,
    )
    t.start()
    return t
