"""Update-size distributions — params per client update, per tenant.

A tenant's clients all train one model, so the dimension is sampled
ONCE per tenant (the engines require homogeneous P within a round);
across tenants the sizes vary per the distribution. Same
``to_dict`` / ``size_from_dict`` contract as the arrival processes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Dict, Tuple, Type

import numpy as np

from repro.configs import CNN_SUITE

_REGISTRY: Dict[str, Type["SizeDistribution"]] = {}


def register_size(cls):
    _REGISTRY[cls.kind] = cls
    return cls


@dataclasses.dataclass(frozen=True)
class SizeDistribution:
    """Base: one tenant's update dimension from a seeded Generator."""

    kind: ClassVar[str] = "base"

    def sample(self, rng: np.random.Generator) -> int:
        raise NotImplementedError

    def to_dict(self) -> dict:
        d: dict = {"kind": self.kind}
        # pure-JSON values only (tuples -> lists), matching the
        # arrival processes' round-trip contract
        d.update({k: list(v) if isinstance(v, tuple) else v
                  for k, v in dataclasses.asdict(self).items()})
        return d


def size_from_dict(d: dict) -> "SizeDistribution":
    d = dict(d)
    kind = d.pop("kind")
    if kind not in _REGISTRY:
        raise ValueError(f"unknown size kind {kind!r} "
                         f"(known: {sorted(_REGISTRY)})")
    cls = _REGISTRY[kind]
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(f"{kind}: unknown fields {sorted(unknown)}")
    kw = {k: tuple(v) if isinstance(v, list) else v for k, v in d.items()}
    return cls(**kw)


@register_size
@dataclasses.dataclass(frozen=True)
class FixedSize(SizeDistribution):
    kind: ClassVar[str] = "fixed"

    dim: int = 20_000

    def sample(self, rng):
        return self.dim


@register_size
@dataclasses.dataclass(frozen=True)
class LognormalSize(SizeDistribution):
    """Median ``median_dim`` params with multiplicative spread
    ``sigma`` — mixed fleets where some tenants run much bigger
    models, floored at ``min_dim``."""

    kind: ClassVar[str] = "lognormal"

    median_dim: int = 20_000
    sigma: float = 0.5
    min_dim: int = 64

    def sample(self, rng):
        dim = self.median_dim * math.exp(self.sigma * rng.normal())
        return max(int(round(dim)), self.min_dim)


@register_size
@dataclasses.dataclass(frozen=True)
class ModelConfigSize(SizeDistribution):
    """Pick a Table-I CNN workload per tenant; ``scale`` divides its
    parameter count so benches stay tractable (the CNN suite is
    10^6-10^7 params)."""

    kind: ClassVar[str] = "model_config"

    models: Tuple[str, ...] = ("CNN1.3", "CNN4.6")
    scale: int = 1000

    def __post_init__(self):
        unknown = [m for m in self.models if m not in CNN_SUITE]
        if unknown:
            raise ValueError(f"unknown CNN suite models {unknown} "
                             f"(known: {sorted(CNN_SUITE)})")

    def sample(self, rng):
        name = self.models[int(rng.integers(len(self.models)))]
        return max(CNN_SUITE[name].num_params // self.scale, 1)
