"""Synthetic language-model data with learnable structure.

A fixed random bigram table (per seed) generates token streams, so a
trained model's loss genuinely decreases — federated examples and the
end-to-end driver verify learning, not just plumbing. Clients can get
*skewed* bigram tables (non-IID knob) by mixing a client-specific table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seed: int = 0
    skew: float = 0.0          # 0 = IID across clients, 1 = fully client-local
    temperature: float = 1.0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # low-rank logits keep the table cheap for big vocabs
        r = 16
        self._a = rng.normal(size=(self.vocab, r)).astype(np.float32)
        self._b = rng.normal(size=(r, self.vocab)).astype(np.float32)

    def _probs_from(self, prev: np.ndarray, rng: np.random.Generator,
                    client_shift: Optional[np.ndarray]) -> np.ndarray:
        logits = self._a[prev] @ self._b / np.sqrt(16.0)
        if client_shift is not None:
            logits = (1 - self.skew) * logits + self.skew * client_shift[prev]
        logits = logits / self.temperature
        logits -= logits.max(axis=-1, keepdims=True)
        p = np.exp(logits)
        return p / p.sum(axis=-1, keepdims=True)

    def sample(self, batch: int, seq_len: int, rng_seed: int,
               client_id: Optional[int] = None) -> np.ndarray:
        rng = np.random.default_rng(rng_seed)
        shift = None
        if client_id is not None and self.skew > 0:
            crng = np.random.default_rng(self.seed * 7919 + client_id)
            shift = crng.normal(
                size=(self.vocab, self.vocab)
            ).astype(np.float32) if self.vocab <= 512 else None
        toks = np.empty((batch, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(1, seq_len):
            p = self._probs_from(toks[:, t - 1], rng, shift)
            cum = np.cumsum(p, axis=-1)
            u = rng.random((batch, 1))
            toks[:, t] = (u < cum).argmax(axis=-1)
        return toks


def make_batch(vocab: int, batch: int, seq_len: int, seed: int,
               gen: Optional[SyntheticLM] = None,
               client_id: Optional[int] = None) -> Dict[str, np.ndarray]:
    gen = gen or SyntheticLM(vocab=vocab, seed=0)
    toks = gen.sample(batch, seq_len, rng_seed=seed, client_id=client_id)
    return {"tokens": toks, "labels": toks.copy()}
