"""Federated data partitioning (cross-device FL: many clients, skewed)."""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(n_samples: int, n_clients: int, alpha: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Sample-index partition with Dirichlet(alpha) client proportions —
    the standard non-IID quantity split. Every client gets >= 1 sample."""
    rng = np.random.default_rng(seed)
    props = rng.dirichlet([alpha] * n_clients)
    counts = np.maximum((props * n_samples).astype(int), 1)
    # fix rounding drift
    while counts.sum() > n_samples:
        counts[np.argmax(counts)] -= 1
    while counts.sum() < n_samples:
        counts[np.argmin(counts)] += 1
    idx = rng.permutation(n_samples)
    out, off = [], 0
    for c in counts:
        out.append(np.sort(idx[off: off + c]))
        off += c
    return out


def shard_partition(n_samples: int, n_clients: int) -> List[np.ndarray]:
    """Equal contiguous shards (IID baseline)."""
    return [np.arange(n_samples)[i::n_clients] for i in range(n_clients)]
