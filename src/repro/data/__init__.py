"""Data pipeline: synthetic token streams, non-IID federated partitioning,
and host-side batch sharding."""
from repro.data.synthetic import SyntheticLM, make_batch
from repro.data.partition import dirichlet_partition, shard_partition
from repro.data.loader import FederatedLoader

__all__ = [
    "SyntheticLM",
    "make_batch",
    "dirichlet_partition",
    "shard_partition",
    "FederatedLoader",
]
