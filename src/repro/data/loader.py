"""Per-client batch loader over the synthetic generator."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.data.synthetic import SyntheticLM


@dataclasses.dataclass
class FederatedLoader:
    gen: SyntheticLM
    n_clients: int
    batch: int
    seq_len: int
    samples_per_client: List[int] | None = None  # -> client weights

    def __post_init__(self):
        if self.samples_per_client is None:
            rng = np.random.default_rng(self.gen.seed + 1)
            self.samples_per_client = list(
                rng.integers(50, 500, size=self.n_clients)
            )

    def client_weight(self, client_id: int) -> float:
        return float(self.samples_per_client[client_id])

    def client_batch(self, client_id: int, round_idx: int) -> Dict[str, np.ndarray]:
        toks = self.gen.sample(
            self.batch, self.seq_len,
            rng_seed=round_idx * 100003 + client_id,
            client_id=client_id,
        )
        return {"tokens": toks, "labels": toks.copy()}
