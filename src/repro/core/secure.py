"""Secure aggregation via pairwise additive masks (paper §V discussion;
Bonawitz et al., CCS'17 §4 semantics, without the dropout-recovery
protocol — mask *cancellation* under summation is what interacts with the
aggregation engines, and only sum-reducible fusions preserve it).

Client i adds sum_{j>i} PRG(seed_ij) - sum_{j<i} PRG(seed_ji) to its
update; the pairwise terms cancel exactly in the fused sum. Masks are
generated with JAX's counter-based PRNG keyed by fold_in(seed, i, j), so
client i and j derive the same stream without communication (stand-in for
the DH key agreement)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SecureMasking:
    n_clients: int
    seed: int = 0
    scale: float = 1.0

    def _pair_mask(self, i: int, j: int, n_params: int) -> jnp.ndarray:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), i), j
        )
        return self.scale * jax.random.normal(key, (n_params,), jnp.float32)

    def mask_for(self, client: int, n_params: int) -> jnp.ndarray:
        """The net mask client ``client`` adds to its update."""
        m = jnp.zeros((n_params,), jnp.float32)
        for j in range(self.n_clients):
            if j == client:
                continue
            lo, hi = min(client, j), max(client, j)
            pm = self._pair_mask(lo, hi, n_params)
            m = m + pm if client == lo else m - pm
        return m

    def mask_update(self, client: int, update: jnp.ndarray) -> jnp.ndarray:
        return update.astype(jnp.float32) + self.mask_for(
            client, update.shape[-1]
        )
