"""UpdateStore — the HDFS analogue.

Clients write model updates here instead of pushing them over a single
server's NIC (the paper's webHDFS path, §III-D2). The store is the
communication substrate of the distributed engine: placement is sharded
(round-robin over simulated datanodes), capacity is cluster-level rather
than single-node, and reads hand the distributed engine per-shard slices.

Two backends:
  * memory — dict of flat vectors in the CLIENT'S dtype (fast; benchmarks).
  * disk   — one .npy per update under a spool dir (restart-safe; the
             end-to-end example and fault-tolerance tests use this).

The aggregator-side read path is STREAMING-first: ``iter_chunks`` hands
the engine fixed-size (chunk, P) blocks with the next block prefetched on
a reader thread (double buffering), so a round never materializes the
dense (n, P) matrix on the host — peak ingest allocation is O(chunk * P).
``read_stacked`` remains for order-statistic fusions that genuinely need
all rows at once.

Stored dtype is preserved (bf16 updates stay 2 bytes on the wire and in
the spool; the seed force-cast to fp32, doubling bytes); only integer /
bool inputs are promoted to fp32.

Ingest-time accounting mirrors the paper's Fig. 12 'average write time':
bytes / per-datanode bandwidth with ``replication`` copies.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.utils.pytree import tree_to_flat_vector


@dataclasses.dataclass
class StoreStats:
    writes: int = 0
    bytes_written: int = 0
    sim_write_seconds: float = 0.0  # modeled (bandwidth-based), not wall
    reads: int = 0
    bytes_read: int = 0
    peak_block_bytes: int = 0       # largest single ingest block staged


class UpdateStore:
    """Thread-safe spool of (client_id -> flat update, weight).

    Locking discipline: ``self._lock`` guards ONLY the in-memory index
    (``_mem`` / ``_weights``) and stats. Disk I/O happens outside the
    critical section so concurrent client writes overlap on the
    (simulated) datanodes instead of serializing behind one spindle.
    Readers snapshot the index under the lock, then read blob data
    lock-free.
    """

    def __init__(
        self,
        backend: str = "memory",
        spool_dir: Optional[str] = None,
        n_datanodes: int = 3,
        replication: int = 2,
        datanode_bw: float = 117e6,  # ~1 GbE in bytes/s, paper's testbed
    ):
        assert backend in ("memory", "disk")
        self.backend = backend
        self.spool_dir = spool_dir
        if backend == "disk":
            assert spool_dir, "disk backend needs spool_dir"
            os.makedirs(spool_dir, exist_ok=True)
        self.n_datanodes = n_datanodes
        self.replication = replication
        self.datanode_bw = datanode_bw
        self._mem: Dict[str, Tuple[np.ndarray, float]] = {}
        self._weights: Dict[str, float] = {}
        self._lock = threading.Lock()
        self.stats = StoreStats()
        if backend == "disk":
            # fault tolerance (the HDFS property the paper leans on):
            # recover updates spooled by a previous aggregator incarnation
            # — weights persist in a sidecar next to each blob
            self._weights.update(self._recover())

    # -- client side --------------------------------------------------------
    def write(self, client_id: str, update, weight: float = 1.0) -> float:
        """Store one update (pytree or flat vector). Returns the modeled
        write latency (bandwidth model, paper Fig. 12). Concurrent writes
        to the SAME client_id are last-writer-wins."""
        vec = np.asarray(
            update if getattr(update, "ndim", None) == 1
            else tree_to_flat_vector(update)
        )
        if vec.dtype.kind in "biu":   # ints/bools promote; floats keep dtype
            vec = vec.astype(np.float32)
        nbytes = vec.nbytes * self.replication
        latency = nbytes / (self.datanode_bw * self.n_datanodes)
        if self.backend == "disk":
            # blob + sidecar land on the datanode OUTSIDE the lock.
            # np.save can't round-trip ml_dtypes (bf16 reloads as raw V2),
            # so extension floats spool as raw bytes + a dtype sidecar.
            dpath = self._path(client_id) + ".dtype"
            if vec.dtype.kind == "V":
                np.save(self._path(client_id), np.ascontiguousarray(vec)
                        .view(np.uint8))
                with open(dpath, "w") as f:
                    f.write(vec.dtype.name)
            else:
                np.save(self._path(client_id), vec)
                try:
                    os.remove(dpath)   # stale sidecar from a prior dtype
                except FileNotFoundError:
                    pass
            with open(self._path(client_id) + ".w", "w") as f:
                f.write(repr(float(weight)))
        with self._lock:
            if self.backend == "memory":
                self._mem[client_id] = (vec, weight)
            else:
                self._weights[client_id] = weight
            self.stats.writes += 1
            self.stats.bytes_written += nbytes
            self.stats.sim_write_seconds += latency
        return latency

    # -- aggregator side ----------------------------------------------------
    def count(self) -> int:
        with self._lock:
            if self.backend == "memory":
                return len(self._mem)
            return len(self._weights)

    def client_ids(self) -> List[str]:
        with self._lock:
            src = self._mem if self.backend == "memory" else self._weights
            return sorted(src.keys())

    def read(self, client_id: str) -> Tuple[np.ndarray, float]:
        if self.backend == "memory":
            with self._lock:
                return self._mem[client_id]
        with self._lock:
            weight = self._weights[client_id]
        blob = np.load(self._path(client_id))
        dt = self._sidecar_dtype(client_id)
        if dt is not None:
            blob = blob.view(dt)
        return blob, weight

    def _sidecar_dtype(self, client_id: str) -> Optional[np.dtype]:
        try:
            with open(self._path(client_id) + ".dtype") as f:
                return np.dtype(f.read().strip())
        except FileNotFoundError:
            return None

    def meta(self) -> Tuple[int, int, np.dtype]:
        """(n_clients, update_dim, stored dtype) without loading the set —
        what the planner needs BEFORE choosing an engine."""
        ids = self.client_ids()
        if not ids:
            raise LookupError("empty store")
        if self.backend == "memory":
            with self._lock:
                vec, _ = self._mem[ids[0]]
            return len(ids), int(vec.shape[0]), vec.dtype
        blob = np.load(self._path(ids[0]), mmap_mode="r")  # header only
        dt = self._sidecar_dtype(ids[0])
        if dt is not None:
            return len(ids), int(blob.nbytes // dt.itemsize), dt
        return len(ids), int(blob.shape[0]), blob.dtype

    def iter_chunks(
        self,
        chunk_rows: int,
        prefetch: bool = True,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield (updates (c, P) stored-dtype, weights (c,) fp32) blocks,
        c == chunk_rows except for the ragged final block.

        With ``prefetch`` a reader thread stages block k+1 while the
        engine consumes block k (double buffering): at most two blocks are
        resident, so peak host-side ingest memory is O(2 * chunk * P)
        regardless of n. The iterator works over a snapshot of the client
        index — updates written after the call don't shift the blocks.
        """
        ids = self.client_ids()
        chunk_rows = max(int(chunk_rows), 1)
        batches = [
            ids[i:i + chunk_rows] for i in range(0, len(ids), chunk_rows)
        ]

        def load(batch):
            ups, ws = [], []
            for cid in batch:
                u, w = self.read(cid)
                ups.append(u)
                ws.append(w)
            block = np.stack(ups)
            with self._lock:
                self.stats.reads += len(batch)
                self.stats.bytes_read += block.nbytes
                self.stats.peak_block_bytes = max(
                    self.stats.peak_block_bytes, block.nbytes
                )
            return block, np.asarray(ws, np.float32)

        if not prefetch:
            for batch in batches:
                yield load(batch)
            return

        q: "queue.Queue" = queue.Queue(maxsize=1)
        stop = threading.Event()   # set when the consumer abandons us

        def put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def reader():
            try:
                for batch in batches:
                    if stop.is_set() or not put(("block", load(batch))):
                        return
                put(("done", None))
            except BaseException as exc:  # surface in the consumer
                put(("error", exc))

        t = threading.Thread(
            target=reader, name="updatestore-prefetch", daemon=True
        )
        t.start()
        try:
            while True:
                kind, payload = q.get()
                if kind == "done":
                    break
                if kind == "error":
                    raise payload
                yield payload
        finally:
            # consumer done or bailed early (exception / dropped
            # generator): release the reader so it never blocks holding
            # a staged block
            stop.set()
            t.join()

    def read_stacked(self) -> Tuple[np.ndarray, np.ndarray]:
        """All updates as (n, P) + weights (n,) — the DENSE engine input.
        Order-statistic fusions still need this; reducible rounds should
        stream via ``iter_chunks`` instead."""
        ups, ws = [], []
        for block, w in self.iter_chunks(chunk_rows=1 << 62, prefetch=False):
            ups.append(block)
            ws.append(w)
        return np.concatenate(ups), np.concatenate(ws)

    def partition(self, n_parts: int) -> List[List[str]]:
        """Round-robin client placement over partitions (Spark-style)."""
        ids = self.client_ids()
        return [ids[i::n_parts] for i in range(n_parts)]

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            if self.backend == "disk":
                for cid in list(self._weights):
                    for path in (self._path(cid), self._path(cid) + ".w",
                                 self._path(cid) + ".dtype"):
                        try:
                            os.remove(path)
                        except FileNotFoundError:
                            pass
                self._weights.clear()

    def _path(self, client_id: str) -> str:
        return os.path.join(self.spool_dir, f"{client_id}.npy")

    def _recover(self) -> Dict[str, float]:
        """Rebuild the weight index from the spool after a restart."""
        weights: Dict[str, float] = {}
        for name in os.listdir(self.spool_dir):
            if name.endswith(".npy"):
                cid = name[: -len(".npy")]
                wpath = os.path.join(self.spool_dir, name + ".w")
                try:
                    with open(wpath) as f:
                        weights[cid] = float(f.read())
                except (FileNotFoundError, ValueError):
                    weights[cid] = 1.0
        return weights
