"""UpdateStore — the HDFS analogue.

Clients write model updates here instead of pushing them over a single
server's NIC (the paper's webHDFS path, §III-D2). The store is the
communication substrate of the distributed engine: placement is sharded
(round-robin over simulated datanodes), capacity is cluster-level rather
than single-node, and reads hand the distributed engine per-shard slices.

Two backends:
  * memory — dict of flat vectors in the CLIENT'S dtype (fast; benchmarks).
  * disk   — one .npy per update under a spool dir (restart-safe; the
             end-to-end example and fault-tolerance tests use this).

The aggregator-side read path is STREAMING-first: ``iter_chunks`` hands
the engine fixed-size (chunk, P) blocks with the next block prefetched on
a reader thread (double buffering), so a round never materializes the
dense (n, P) matrix on the host — peak ingest allocation is O(chunk * P).
``iter_arrivals`` is the arrival-driven variant (the async-round
substrate): it yields a block as soon as ``chunk_rows`` NEW updates land,
snapshot-free, with the caller's threshold/timeout gate deciding when the
stream *closes* rather than when it starts — fusion overlaps the
straggler wait. ``read_stacked`` remains for order-statistic fusions that
genuinely need all rows at once.

Stored dtype is preserved (bf16 updates stay 2 bytes on the wire and in
the spool; the seed force-cast to fp32, doubling bytes); only integer /
bool inputs are promoted to fp32.

Every registered write is TIMESTAMPED on the store's injectable clock
(``arrival_times()``) — the adaptive controller's training signal — and
notifies an arrival condition, so arrival-driven readers
(``iter_arrivals``, ``Monitor.wait``) wake event-driven instead of
sleep-polling. ``SpoolTailer`` extends the same arrival path to blobs
written DIRECTLY into a disk spool by external processes: inotify when
the platform has it, directory polling elsewhere.

Ingest-time accounting mirrors the paper's Fig. 12 'average write time':
bytes / per-datanode bandwidth with ``replication`` copies.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.utils.pytree import tree_to_flat_vector


@dataclasses.dataclass
class StoreStats:
    writes: int = 0
    bytes_written: int = 0
    sim_write_seconds: float = 0.0  # modeled (bandwidth-based), not wall
    reads: int = 0
    bytes_read: int = 0
    peak_block_bytes: int = 0       # largest single ingest block staged


class UpdateStore:
    """Thread-safe spool of (client_id -> flat update, weight).

    Locking discipline: ``self._lock`` guards ONLY the in-memory index
    (``_mem`` / ``_weights``) and stats. Disk I/O happens outside the
    critical section so concurrent client writes overlap on the
    (simulated) datanodes instead of serializing behind one spindle.
    Readers snapshot the index under the lock, then read blob data
    lock-free.
    """

    def __init__(
        self,
        backend: str = "memory",
        spool_dir: Optional[str] = None,
        n_datanodes: int = 3,
        replication: int = 2,
        datanode_bw: float = 117e6,  # ~1 GbE in bytes/s, paper's testbed
        clock: Callable[[], float] = time.monotonic,
        sidecar_grace_seconds: float = 0.5,
    ):
        assert backend in ("memory", "disk")
        self.backend = backend
        self.spool_dir = spool_dir
        if backend == "disk":
            assert spool_dir, "disk backend needs spool_dir"
            os.makedirs(spool_dir, exist_ok=True)
        self.n_datanodes = n_datanodes
        self.replication = replication
        self.datanode_bw = datanode_bw
        self.clock = clock   # arrival timestamping; injectable for tests
        self._mem: Dict[str, Tuple[np.ndarray, float]] = {}
        self._weights: Dict[str, float] = {}
        # per-id write counter: lets a version-aware remove() keep an
        # update that was re-written after a round folded its predecessor
        self._versions: Dict[str, int] = {}
        # per-id arrival timestamp (self.clock timebase) — the adaptive
        # controller's training signal (repro/core/adaptive.py)
        self._arrivals: Dict[str, float] = {}
        # external blobs first sighted without a weight sidecar:
        # cid -> wall time first seen. They register at the default
        # weight only after sidecar_grace_seconds, so a sidecar landing
        # just behind its blob (the documented writer order) wins.
        self.sidecar_grace_seconds = sidecar_grace_seconds
        self._ext_seen: Dict[str, float] = {}
        self._lock = threading.Lock()
        # notified on every registered arrival: arrival-driven readers
        # (iter_arrivals) block here instead of sleep-polling
        self._arrival_cv = threading.Condition(self._lock)
        self.stats = StoreStats()
        if backend == "disk":
            # fault tolerance (the HDFS property the paper leans on):
            # recover updates spooled by a previous aggregator incarnation
            # — weights persist in a sidecar next to each blob
            recovered = self._recover()
            self._weights.update(recovered)
            now = self.clock()
            self._arrivals.update({cid: now for cid in recovered})

    # -- client side --------------------------------------------------------
    def write(self, client_id: str, update, weight: float = 1.0) -> float:
        """Store one update (pytree or flat vector). Returns the modeled
        write latency (bandwidth model, paper Fig. 12). Concurrent writes
        to the SAME client_id are last-writer-wins."""
        vec = np.asarray(
            update if getattr(update, "ndim", None) == 1
            else tree_to_flat_vector(update)
        )
        if vec.dtype.kind in "biu":   # ints/bools promote; floats keep dtype
            vec = vec.astype(np.float32)
        nbytes = vec.nbytes * self.replication
        latency = nbytes / (self.datanode_bw * self.n_datanodes)
        if self.backend == "disk":
            # blob + sidecar land on the datanode OUTSIDE the lock.
            # np.save can't round-trip ml_dtypes (bf16 reloads as raw V2),
            # so extension floats spool as raw bytes + a dtype sidecar.
            dpath = self._path(client_id) + ".dtype"
            if vec.dtype.kind == "V":
                np.save(self._path(client_id), np.ascontiguousarray(vec)
                        .view(np.uint8))
                with open(dpath, "w") as f:
                    f.write(vec.dtype.name)
            else:
                np.save(self._path(client_id), vec)
                try:
                    os.remove(dpath)   # stale sidecar from a prior dtype
                except FileNotFoundError:
                    pass
            with open(self._path(client_id) + ".w", "w") as f:
                f.write(repr(float(weight)))
        with self._lock:
            if self.backend == "memory":
                self._mem[client_id] = (vec, weight)
            else:
                self._weights[client_id] = weight
            self._versions[client_id] = self._versions.get(client_id, 0) + 1
            self._arrivals[client_id] = self.clock()
            self.stats.writes += 1
            self.stats.bytes_written += nbytes
            self.stats.sim_write_seconds += latency
            self._arrival_cv.notify_all()
        return latency

    # -- aggregator side ----------------------------------------------------
    def count(self) -> int:
        with self._lock:
            if self.backend == "memory":
                return len(self._mem)
            return len(self._weights)

    def client_ids(self) -> List[str]:
        with self._lock:
            src = self._mem if self.backend == "memory" else self._weights
            return sorted(src.keys())

    def arrival_times(self) -> Dict[str, float]:
        """Snapshot of {client_id -> arrival timestamp} on the store's
        ``clock`` timebase (``time.monotonic`` by default). This is the
        adaptive controller's training signal: the service subtracts the
        round's start time to get per-client arrival offsets."""
        with self._lock:
            return dict(self._arrivals)

    def wait_for_arrival(self, timeout: float, sleep=time.sleep) -> None:
        """Block until a new arrival is registered or ``timeout`` elapses.
        Event-driven (condition wait, woken by ``write`` /
        ``ingest_external``) under the real clock; with an INJECTED sleep
        (scripted test clocks) the caller's sleep drives time instead."""
        if sleep is not time.sleep:
            sleep(timeout)
            return
        with self._arrival_cv:
            self._arrival_cv.wait(timeout)

    def read(self, client_id: str) -> Tuple[np.ndarray, float]:
        u, w, _ = self._read_versioned(client_id)
        return u, w

    def _read_versioned(
        self, client_id: str
    ) -> Tuple[np.ndarray, float, int]:
        """(update, weight, write-version). For the memory backend the
        array and version are captured under ONE lock, so version-checked
        removal is exact; the disk backend's blob read is lock-free as
        ever, so a racing overwrite can at worst cause a harmless re-fold
        next round (never a lost update)."""
        if self.backend == "memory":
            with self._lock:
                arr, weight = self._mem[client_id]
                version = self._versions.get(client_id, 0)
            # hand out a read-only VIEW: the spool keeps the only mutable
            # reference, so a caller scribbling on a block cannot corrupt
            # what a concurrent (or later) round will read
            view = arr.view()
            view.flags.writeable = False
            return view, weight, version
        with self._lock:
            weight = self._weights[client_id]
            version = self._versions.get(client_id, 0)
        blob = np.load(self._path(client_id))
        dt = self._sidecar_dtype(client_id)
        if dt is not None:
            blob = blob.view(dt)
        return blob, weight, version

    def _sidecar_dtype(self, client_id: str) -> Optional[np.dtype]:
        try:
            with open(self._path(client_id) + ".dtype") as f:
                return np.dtype(f.read().strip())
        except FileNotFoundError:
            return None

    def meta(self) -> Tuple[int, int, np.dtype]:
        """(n_clients, update_dim, stored dtype) without loading the set —
        what the planner needs BEFORE choosing an engine."""
        ids = self.client_ids()
        if not ids:
            raise LookupError("empty store")
        if self.backend == "memory":
            with self._lock:
                vec, _ = self._mem[ids[0]]
            return len(ids), int(vec.shape[0]), vec.dtype
        blob = np.load(self._path(ids[0]), mmap_mode="r")  # header only
        dt = self._sidecar_dtype(ids[0])
        if dt is not None:
            return len(ids), int(blob.nbytes // dt.itemsize), dt
        return len(ids), int(blob.shape[0]), blob.dtype

    def iter_chunks(
        self,
        chunk_rows: int,
        prefetch: bool = True,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield (updates (c, P) stored-dtype, weights (c,) fp32) blocks,
        c == chunk_rows except for the ragged final block.

        With ``prefetch`` a reader thread stages block k+1 while the
        engine consumes block k (double buffering): at most two blocks are
        resident, so peak host-side ingest memory is O(2 * chunk * P)
        regardless of n. The iterator works over a snapshot of the client
        index — updates written after the call don't shift the blocks.
        """
        ids = self.client_ids()
        chunk_rows = max(int(chunk_rows), 1)
        batches = [
            ids[i:i + chunk_rows] for i in range(0, len(ids), chunk_rows)
        ]
        load = self._load_block

        if not prefetch:
            for batch in batches:
                yield load(batch)
            return

        q: "queue.Queue" = queue.Queue(maxsize=1)
        stop = threading.Event()   # set when the consumer abandons us

        def put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def reader():
            try:
                for batch in batches:
                    if stop.is_set() or not put(("block", load(batch))):
                        return
                put(("done", None))
            except BaseException as exc:  # surface in the consumer
                put(("error", exc))

        t = threading.Thread(
            target=reader, name="updatestore-prefetch", daemon=True
        )
        t.start()
        try:
            while True:
                kind, payload = q.get()
                if kind == "done":
                    break
                if kind == "error":
                    raise payload
                yield payload
        finally:
            # consumer done or bailed early (exception / dropped
            # generator): release the reader so it never blocks holding
            # a staged block
            stop.set()
            t.join()

    def _load_block(
        self,
        batch: List[str],
        versions_out: Optional[Dict[str, int]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Stack one batch of client ids into ((c, P) block, (c,) weights)
        — blob reads happen lock-free, stats update under the lock.
        ``versions_out`` collects each id's write-version AS READ, for
        version-checked consumption (``remove``)."""
        ups, ws = [], []
        for cid in batch:
            u, w, v = self._read_versioned(cid)
            if versions_out is not None:
                versions_out[cid] = v
            ups.append(u)
            ws.append(w)
        block = np.stack(ups)
        with self._lock:
            self.stats.reads += len(batch)
            self.stats.bytes_read += block.nbytes
            self.stats.peak_block_bytes = max(
                self.stats.peak_block_bytes, block.nbytes
            )
        return block, np.asarray(ws, np.float32)

    def iter_arrivals(
        self,
        chunk_rows: int,
        should_close: Callable[[int, float], bool],
        poll_interval: float = 0.01,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        versions_out: Optional[Dict[str, int]] = None,
        stats_out: Optional[Dict[str, float]] = None,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, List[str]]]:
        """Arrival-driven streaming read — the async-round substrate.

        Yields ((c, P) block, (c,) weights, client_ids) as soon as
        ``chunk_rows`` NEW updates have landed, without snapshotting the
        index up front: updates written while the stream is live are
        picked up on the next poll, so an engine can fold partial sums
        while stragglers are still writing. ``should_close(count, waited)``
        — the Monitor's threshold/timeout gate — is consulted every poll
        with the total number of updates observed so far and the seconds
        since the call; once it returns True the stream CLOSES: everything
        already landed is drained (full blocks, then one ragged remainder)
        and iteration stops. Only the final block can be ragged, which is
        the contract the engines' fixed-shape step executables rely on.
        Updates written after the close belong to the next round.

        NOTE the third tuple element is the block's client ids — the
        engines' ``fuse_stream`` block protocol instead expects an
        optional numeric per-row scale there, so adapt (as
        ``AggregationService._aggregate_async`` does) rather than feeding
        this iterator to an engine directly. ``versions_out`` collects
        write-versions as read (for version-checked ``remove``);
        ``stats_out["load_seconds"]`` accumulates actual block-staging
        I/O time, separate from the idle poll wait.
        """
        chunk_rows = max(int(chunk_rows), 1)
        seen: set = set()
        pending: List[str] = []
        start = clock()
        while True:
            fresh = [cid for cid in self.client_ids() if cid not in seen]
            seen.update(fresh)
            pending.extend(fresh)
            closed = should_close(len(seen), clock() - start)
            while len(pending) >= chunk_rows or (closed and pending):
                batch, pending = pending[:chunk_rows], pending[chunk_rows:]
                t0 = time.perf_counter()
                block, w = self._load_block(batch, versions_out=versions_out)
                if stats_out is not None:
                    stats_out["load_seconds"] = (
                        stats_out.get("load_seconds", 0.0)
                        + time.perf_counter() - t0
                    )
                yield block, w, batch
            if closed:
                return
            # event-driven under the real clock: wake on the next write's
            # condition notify instead of burning the full poll interval
            self.wait_for_arrival(poll_interval, sleep)

    def read_stacked(self) -> Tuple[np.ndarray, np.ndarray]:
        """All updates as (n, P) + weights (n,) — the DENSE engine input.
        Order-statistic fusions still need this; reducible rounds should
        stream via ``iter_chunks`` instead."""
        ups, ws = [], []
        for block, w in self.iter_chunks(chunk_rows=1 << 62, prefetch=False):
            ups.append(block)
            ws.append(w)
        return np.concatenate(ups), np.concatenate(ws)

    def partition(self, n_parts: int) -> List[List[str]]:
        """Round-robin client placement over partitions (Spark-style)."""
        ids = self.client_ids()
        return [ids[i::n_parts] for i in range(n_parts)]

    def remove(
        self,
        client_ids: Iterable[str],
        versions: Optional[Dict[str, int]] = None,
    ) -> None:
        """Consume updates — async rounds treat the store as a queue and
        remove what they fold, so late stragglers are what remains for the
        next round. With ``versions`` (id -> write-version as folded, from
        ``iter_arrivals``), an id whose version has since advanced is
        KEPT: a client that re-wrote mid-round keeps its newer update for
        the next round instead of losing it. Index entries drop under the
        lock; blob deletion, like all disk I/O, happens outside the
        critical section.

        The version guard is exact for the memory backend. On disk,
        ``write`` saves the blob before registering it, so a re-write
        racing the unlink batch is re-checked per id right before its
        files go; a write landing inside that last microsecond window can
        still lose its blob (lock-free spool limitation)."""
        ids = list(client_ids)
        doomed = []
        with self._lock:
            for cid in ids:
                if versions is not None and \
                        self._versions.get(cid, 0) != versions.get(cid, -1):
                    continue    # re-written since the fold: keep it
                self._mem.pop(cid, None)
                self._weights.pop(cid, None)
                self._arrivals.pop(cid, None)
                doomed.append(cid)
        if self.backend != "disk":
            return
        for cid in doomed:
            if versions is not None:
                with self._lock:
                    if self._versions.get(cid, 0) != versions.get(cid, -1):
                        continue    # re-registered while we were unlinking
            self._unlink([cid])

    def clear(self) -> None:
        """Drop every update and reset stats for a fresh round sequence.
        Ids are snapshotted under the lock; spool blobs are deleted outside
        it (the store's locking discipline: no disk I/O in the critical
        section)."""
        with self._lock:
            doomed = list(self._weights) if self.backend == "disk" else []
            self._mem.clear()
            self._weights.clear()
            self._arrivals.clear()
            self._ext_seen.clear()
            self.stats = StoreStats()
        self._unlink(doomed)

    def _unlink(self, client_ids: Iterable[str]) -> None:
        for cid in client_ids:
            for path in (self._path(cid), self._path(cid) + ".w",
                         self._path(cid) + ".dtype"):
                try:
                    os.remove(path)
                except FileNotFoundError:
                    pass

    def _path(self, client_id: str) -> str:
        return os.path.join(self.spool_dir, f"{client_id}.npy")

    # -- external spool writers (tailing) ------------------------------------
    def ingest_external(self) -> List[str]:
        """Register spool blobs written DIRECTLY into ``spool_dir`` by
        external processes (clients mounting the spool, not calling
        ``write``). Disk backend only; returns the newly registered ids.

        An unreadable blob (a write still in flight under the polling
        fallback) is skipped and picked up on a later pass — external
        writers should write-to-temp-then-rename so the inotify
        ``IN_MOVED_TO`` event always sees a complete file. Weight comes
        from the ``.w`` sidecar when present. A blob with NO sidecar yet
        is deferred for ``sidecar_grace_seconds`` (wall clock) before it
        registers at weight 1.0: writers emit blob-then-sidecar, so
        registering on first sight would race the sidecar and freeze the
        weight at the default — the sidecar's own close event (or the
        next poll tick) re-passes within the grace window."""
        if self.backend != "disk":
            return []
        with self._lock:
            known = set(self._weights)
        new: List[str] = []
        for name in sorted(os.listdir(self.spool_dir)):
            if not name.endswith(".npy"):
                continue
            cid = name[: -len(".npy")]
            if cid in known:
                continue
            try:
                blob = np.load(self._path(cid), mmap_mode="r")
                nbytes = int(blob.nbytes)
            except Exception:
                continue   # partial write: next pass gets it
            try:
                with open(self._path(cid) + ".w") as f:
                    weight = float(f.read())
            except (FileNotFoundError, ValueError):
                now = time.monotonic()   # real elapsed, not self.clock
                first = self._ext_seen.setdefault(cid, now)
                if now - first < self.sidecar_grace_seconds:
                    continue   # sidecar may still be in flight
                weight = 1.0
            self._ext_seen.pop(cid, None)
            with self._arrival_cv:
                if cid in self._weights:
                    continue   # a concurrent write() beat us to it
                self._weights[cid] = weight
                self._versions[cid] = self._versions.get(cid, 0) + 1
                self._arrivals[cid] = self.clock()
                self.stats.writes += 1
                self.stats.bytes_written += nbytes * self.replication
                self._arrival_cv.notify_all()
            new.append(cid)
        return new

    def _recover(self) -> Dict[str, float]:
        """Rebuild the weight index from the spool after a restart."""
        weights: Dict[str, float] = {}
        for name in os.listdir(self.spool_dir):
            if name.endswith(".npy"):
                cid = name[: -len(".npy")]
                wpath = os.path.join(self.spool_dir, name + ".w")
                try:
                    with open(wpath) as f:
                        weights[cid] = float(f.read())
                except (FileNotFoundError, ValueError):
                    weights[cid] = 1.0
        return weights


class _InotifyWatch:
    """Minimal ctypes inotify(7) binding: block until something lands in
    a directory. Raises ``OSError`` where inotify is unavailable (non-
    Linux, exhausted watch quota) — callers fall back to polling."""

    # no IN_CREATE: waking on creation would pass over files whose
    # contents (and sidecars) are still being written
    _IN_CLOSE_WRITE = 0x00000008
    _IN_MOVED_TO = 0x00000080

    def __init__(self, path: str):
        import ctypes
        import ctypes.util

        libc_name = ctypes.util.find_library("c") or "libc.so.6"
        self._libc = ctypes.CDLL(libc_name, use_errno=True)
        self._fd = self._libc.inotify_init()
        if self._fd < 0:
            raise OSError(ctypes.get_errno(), "inotify_init failed")
        mask = self._IN_CLOSE_WRITE | self._IN_MOVED_TO
        wd = self._libc.inotify_add_watch(
            self._fd, os.fsencode(path), mask
        )
        if wd < 0:
            err = ctypes.get_errno()
            os.close(self._fd)
            raise OSError(err, f"inotify_add_watch({path}) failed")

    def wait(self, timeout: float) -> bool:
        """True if at least one filesystem event fired within ``timeout``
        seconds (the event buffer is drained either way)."""
        import select

        ready, _, _ = select.select([self._fd], [], [], timeout)
        if not ready:
            return False
        try:
            os.read(self._fd, 65536)   # drain; content doesn't matter
        except OSError:
            return False
        return True

    def close(self) -> None:
        try:
            os.close(self._fd)
        except OSError:
            pass


class SpoolTailer:
    """Arrival-driven tailing of a DISK spool written by external
    processes: a daemon thread registers foreign blobs into the store
    index the moment they land, so ``iter_arrivals`` / the monitor see
    them like any ``write()``.

    Uses inotify (``IN_CLOSE_WRITE`` / ``IN_MOVED_TO``) when the
    platform provides it — arrivals wake the tailer immediately instead
    of on the next poll tick — and degrades to mtime-free directory
    polling at ``poll_interval`` elsewhere; ``event_driven`` reports
    which mode is live. Use as a context manager around a round::

        with SpoolTailer(store) as tailer:
            service.aggregate(from_store=True, async_round=True)
    """

    def __init__(self, store: UpdateStore, poll_interval: float = 0.25):
        if store.backend != "disk":
            raise ValueError("SpoolTailer tails DISK spools only")
        self.store = store
        self.poll_interval = poll_interval
        self.event_driven = False
        self._watch: Optional[_InotifyWatch] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "SpoolTailer":
        try:
            self._watch = _InotifyWatch(self.store.spool_dir)
            self.event_driven = True
        except Exception:
            self._watch = None   # polling fallback
        self.store.ingest_external()   # catch anything already spooled
        self._thread = threading.Thread(
            target=self._run, name="spool-tailer", daemon=True
        )
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.is_set():
            if self._watch is not None:
                self._watch.wait(self.poll_interval)
            else:
                self._stop.wait(self.poll_interval)
            if self._stop.is_set():
                return
            self.store.ingest_external()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._watch is not None:
            self._watch.close()
            self._watch = None

    def __enter__(self) -> "SpoolTailer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
