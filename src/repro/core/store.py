"""UpdateStore — the HDFS analogue.

Clients write model updates here instead of pushing them over a single
server's NIC (the paper's webHDFS path, §III-D2). The store is the
communication substrate of the distributed engine: placement is sharded
(round-robin over simulated datanodes), capacity is cluster-level rather
than single-node, and reads hand the distributed engine per-shard slices.

Two backends:
  * memory — dict of flat vectors in the CLIENT'S dtype (fast; benchmarks).
  * disk   — one .npy per update under a spool dir (restart-safe; the
             end-to-end example and fault-tolerance tests use this).

The spool is TENANT-PARTITIONED: every write lands in exactly one
tenant's partition (``tenant="default"`` unless tagged), and every read
path — ``count`` / ``client_ids`` / ``meta`` / ``iter_chunks`` /
``iter_arrivals`` / ``arrival_times`` / ``read_stacked`` — takes a
``tenant`` filter, so concurrent applications sharing one store (the
paper's multi-application edge aggregator) interleave open rounds
without folding each other's updates. ``remove`` consumes within a
single tenant's partition; client ids only need to be unique WITHIN a
tenant. ``tenant=None`` on the read paths means the legacy whole-spool
view. On disk, the default tenant spools at the root (restart-compatible
with pre-tenant spools) and every other tenant under
``spool_dir/<tenant>/``.

The aggregator-side read path is STREAMING-first: ``iter_chunks`` hands
the engine fixed-size (chunk, P) blocks with the next block prefetched on
a reader thread (double buffering), so a round never materializes the
dense (n, P) matrix on the host — peak ingest allocation is O(chunk * P).
``iter_arrivals`` is the arrival-driven variant (the async-round
substrate): it yields a block as soon as ``chunk_rows`` NEW updates land,
snapshot-free, with the caller's threshold/timeout gate deciding when the
stream *closes* rather than when it starts — fusion overlaps the
straggler wait. ``read_stacked`` remains for order-statistic fusions that
genuinely need all rows at once.

Stored dtype is preserved (bf16 updates stay 2 bytes on the wire and in
the spool; the seed force-cast to fp32, doubling bytes); only integer /
bool inputs are promoted to fp32.

COMPRESSED TRANSPORT: ``write`` also accepts a
:class:`repro.core.compress.CompressedUpdate` (int8 block-quantized
codes + fp32 per-block scales). On disk the codes spool as the ``.npy``
blob with a ``.scale`` sidecar (the fp32 scale vector, npy format) and
a ``.dim`` sidecar (the logical parameter count, text) — the same
sidecar mechanism the ``.dtype`` sidecar uses for extension floats.
External writers route compressed blobs the same way (codes blob +
``.scale`` next to it); ``ingest_external`` / ``SpoolTailer`` move and
register the sidecar set atomically-enough (blob last). The streaming
read paths — ``iter_chunks`` / ``iter_arrivals`` — yield compressed
rows as :class:`repro.core.compress.CompressedBlock` WITHOUT host-side
dequantization (the engines fold the scales in-kernel); a round may mix
dense and compressed entries (stragglers may be uncompressed), in which
case each yielded block is homogeneous: rows are grouped by payload
kind, only the per-kind final block is ragged. Quota/byte accounting
(``tenant_bytes``, ``StoreStats.bytes*``, ``TenantQuota.max_bytes``)
counts the REAL compressed size (codes + scales), not the logical fp32
size — compressing buys actual quota headroom.

Every registered write is TIMESTAMPED on the store's injectable clock
(``arrival_times()``) — the adaptive controller's training signal — and
notifies an arrival condition, so arrival-driven readers
(``iter_arrivals``, ``Monitor.wait``) wake event-driven instead of
sleep-polling. ``SpoolTailer`` extends the same arrival path to blobs
written DIRECTLY into a disk spool by external processes: inotify when
the platform has it, directory polling elsewhere. External writers
route blobs to a tenant by writing into the tenant's subdirectory, or
by dropping a ``<cid>.npy.tenant`` sidecar next to a root-level blob
(the tailer then moves the files into the named partition).

Ingest-time accounting mirrors the paper's Fig. 12 'average write time':
bytes / per-datanode bandwidth with ``replication`` copies — kept both
spool-globally (``stats``, the legacy view) and PER TENANT
(``stats_for(tenant)``: writes, bytes, reads, evictions). Tenants can
carry a capacity quota (``set_quota`` — update-count / byte budgets
with a reject-or-evict policy, :class:`TenantQuota`) so one noisy
application cannot starve the rest of a shared spool; evictions bump
the victim's write-version first, so in-flight streaming reads and
closing rounds skip superseded entries instead of folding
half-unlinked bytes.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import (
    Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple,
)

import numpy as np

from repro.core.compress import CompressedBlock, CompressedUpdate
from repro.utils.pytree import tree_to_flat_vector

# the partition untagged writes land in; also the root of a disk spool
DEFAULT_TENANT = "default"

# (tenant, client_id) — the store's internal index key
_Key = Tuple[str, str]


def _stat_identity(path: str) -> Tuple[int, int, int]:
    """(st_mtime_ns, st_size, st_ino) — the identity a registered root
    blob's bytes are recognized by. Any rewrite moves at least one
    component: in-place writes bump mtime/size, rename-based writers
    change the inode even under coarse filesystem timestamps."""
    st = os.stat(path)
    return (st.st_mtime_ns, st.st_size, st.st_ino)


def _valid_tenant(tenant: str) -> bool:
    """A tenant name must be a single path component: it becomes a
    spool subdirectory, so separators / '..' would escape the spool
    (path traversal via a crafted ``.tenant`` sidecar)."""
    return bool(tenant) and tenant not in (".", "..") \
        and os.path.basename(tenant) == tenant \
        and "/" not in tenant and "\\" not in tenant


@dataclasses.dataclass
class StoreStats:
    writes: int = 0
    bytes_written: int = 0
    sim_write_seconds: float = 0.0  # modeled (bandwidth-based), not wall
    reads: int = 0
    bytes_read: int = 0
    peak_block_bytes: int = 0       # largest single ingest block staged
    evictions: int = 0              # quota / re-submission evictions


class QuotaExceededError(RuntimeError):
    """A write would exceed its tenant's capacity quota under the
    ``reject`` policy (or no eviction could make room under ``evict``:
    the update alone is bigger than the tenant's byte budget)."""


@dataclasses.dataclass
class TenantQuota:
    """Per-tenant capacity budget — the resource-awareness knob that
    keeps one noisy tenant from starving the rest of a shared spool.

    ``max_updates`` / ``max_bytes`` bound the tenant's resident
    partition (logical stored bytes, before replication); ``None``
    leaves that dimension unbounded. ``policy``:

      * ``"reject"`` — an over-budget ``write`` raises
        :class:`QuotaExceededError`; an over-budget external blob stays
        unregistered on disk until capacity frees.
      * ``"evict"``  — the tenant's OLDEST resident updates (by arrival
        time) are evicted to make room; evictions bump the victims'
        write-version so in-flight folds and closing rounds skip them
        (never a half-unlinked fold), and count into the tenant's
        ``StoreStats.evictions``.

    Enforcement is exact while a tenant's writes are serialized (one
    writer, or the RoundScheduler's per-tenant worker); concurrent
    writers to ONE tenant can overshoot by the writes in flight."""

    max_updates: Optional[int] = None
    max_bytes: Optional[int] = None
    policy: str = "reject"

    def __post_init__(self):
        if self.policy not in ("reject", "evict"):
            raise ValueError(
                f"quota policy must be 'reject' or 'evict', "
                f"got {self.policy!r}"
            )


class UpdateStore:
    """Thread-safe, tenant-partitioned spool of
    ``(tenant, client_id) -> (flat update, weight)``.

    Locking discipline: ``self._lock`` guards ONLY the in-memory index
    (``_mem`` / ``_weights``) and stats. Disk I/O happens outside the
    critical section so concurrent client writes overlap on the
    (simulated) datanodes instead of serializing behind one spindle.
    Readers snapshot the index under the lock, then read blob data
    lock-free.
    """

    def __init__(
        self,
        backend: str = "memory",
        spool_dir: Optional[str] = None,
        n_datanodes: int = 3,
        replication: int = 2,
        datanode_bw: float = 117e6,  # ~1 GbE in bytes/s, paper's testbed
        clock: Callable[[], float] = time.monotonic,
        sidecar_grace_seconds: float = 0.5,
        wall_clock: Callable[[], float] = time.monotonic,
    ):
        assert backend in ("memory", "disk")
        self.backend = backend
        self.spool_dir = spool_dir
        if backend == "disk":
            assert spool_dir, "disk backend needs spool_dir"
            os.makedirs(spool_dir, exist_ok=True)
        self.n_datanodes = n_datanodes
        self.replication = replication
        self.datanode_bw = datanode_bw
        self.clock = clock   # arrival timestamping; injectable for tests
        # sidecar grace windows measure REAL elapsed time, not the
        # arrival timebase — separately injectable so grace-expiry
        # tests run on a scripted clock instead of sleeping it out
        self.wall_clock = wall_clock
        # all index maps are keyed (tenant, client_id) — the partition key
        self._mem: Dict[_Key, Tuple[np.ndarray, float]] = {}  # guarded-by: _lock
        self._weights: Dict[_Key, float] = {}  # guarded-by: _lock
        # per-key write counter: lets a version-aware remove() keep an
        # update that was re-written after a round folded its predecessor
        self._versions: Dict[_Key, int] = {}  # guarded-by: _lock
        # per-key arrival timestamp (self.clock timebase) — the adaptive
        # controller's training signal (repro/core/adaptive.py)
        self._arrivals: Dict[_Key, float] = {}  # guarded-by: _lock
        # external blobs first sighted without a weight sidecar:
        # key -> wall time first seen. They register at the default
        # weight only after sidecar_grace_seconds, so a sidecar landing
        # just behind its blob (the documented writer order) wins.
        self.sidecar_grace_seconds = sidecar_grace_seconds
        self._ext_seen: Dict[_Key, float] = {}  # guarded-by: _lock
        # ROOT-blob ownership (disk): a (st_mtime_ns, st_size,
        # st_ino) identity triple recorded at registration. The root
        # staging area is shared between default-tenant clients and
        # sidecar-routed external writers, so ingest_external uses this
        # to tell a stray late ``.tenant`` sidecar (bytes unchanged:
        # live entry wins) from a genuine re-submission (bytes
        # replaced: evict + re-ingest); rename-based rewrites change
        # the inode even on filesystems with coarse mtime ticks.
        self._blob_mtime: Dict[_Key, Tuple[int, int, int]] = {}  # guarded-by: _lock
        # per-tenant entry count — the monitor's per-wake poll reads
        # this, so it must be O(1), not a scan of the whole index
        self._counts: Dict[str, int] = {}  # guarded-by: _lock
        # per-key logical stored bytes + per-tenant running total —
        # what TenantQuota.max_bytes budgets against
        self._nbytes: Dict[_Key, int] = {}  # guarded-by: _lock
        self._tenant_bytes: Dict[str, int] = {}  # guarded-by: _lock
        self._quotas: Dict[str, TenantQuota] = {}  # guarded-by: _lock
        # per-tenant accounting next to the legacy spool-global stats
        self._tenant_stats: Dict[str, StoreStats] = {}  # guarded-by: _lock
        # tenant subdirectories already created (write() hot path must
        # not re-stat the directory on every update)
        self._made_dirs: set = set()
        self._lock = threading.Lock()
        # notified on every registered arrival: arrival-driven readers
        # (iter_arrivals) block here instead of sleep-polling
        self._arrival_cv = threading.Condition(self._lock)
        self.stats = StoreStats()  # guarded-by: _lock
        if backend == "disk":
            # fault tolerance (the HDFS property the paper leans on):
            # recover updates spooled by a previous aggregator incarnation
            # — weights persist in a sidecar next to each blob, tenants
            # in the directory layout
            recovered = self._recover()
            self._weights.update(recovered)
            now = self.clock()
            self._arrivals.update({key: now for key in recovered})
            for t, _ in recovered:
                self._counts[t] = self._counts.get(t, 0) + 1
            for t, cid in recovered:
                # root-blob ownership survives restarts: without the
                # recorded mtime a post-restart external re-submission
                # would misread as "unchanged bytes" and never re-ingest
                if t == DEFAULT_TENANT:
                    try:
                        self._blob_mtime[(t, cid)] = _stat_identity(
                            self._path(cid, t)
                        )
                    except OSError:
                        pass
            for t, cid in recovered:
                # byte accounting survives restarts too, or a recovered
                # partition would look empty to its tenant's quota
                path = self._path(cid, t)
                try:
                    raw = int(np.load(path, mmap_mode="r").nbytes)
                except Exception:
                    raw = 0
                try:
                    # compressed blobs count their .scale sidecar too
                    raw += int(np.load(
                        path + ".scale", mmap_mode="r"
                    ).nbytes)
                except Exception:
                    pass
                self._nbytes[(t, cid)] = raw
                self._tenant_bytes[t] = self._tenant_bytes.get(t, 0) + raw

    # -- per-tenant accounting / quotas --------------------------------------
    def _tstats(self, tenant: str) -> StoreStats:
        """The tenant's live stats record (created on first touch).
        Caller holds ``self._lock``."""
        st = self._tenant_stats.get(tenant)
        if st is None:
            st = self._tenant_stats[tenant] = StoreStats()
        return st

    def stats_for(self, tenant: Optional[str] = None) -> StoreStats:
        """Snapshot of one tenant's accounting (writes / bytes / reads /
        evictions), or of the legacy spool-global aggregate with
        ``tenant=None`` — the aggregate keeps counting everything, so
        pre-tenant dashboards reading ``store.stats`` see no change."""
        with self._lock:
            src = self.stats if tenant is None \
                else self._tenant_stats.get(tenant, StoreStats())
            return dataclasses.replace(src)

    def set_quota(
        self,
        tenant: str,
        max_updates: Optional[int] = None,
        max_bytes: Optional[int] = None,
        policy: str = "reject",
    ) -> None:
        """Install (or, with both bounds ``None``, remove) ``tenant``'s
        capacity quota — see :class:`TenantQuota` for semantics."""
        if not _valid_tenant(tenant):
            raise ValueError(f"invalid tenant name {tenant!r}")
        with self._lock:
            if max_updates is None and max_bytes is None:
                self._quotas.pop(tenant, None)
            else:
                self._quotas[tenant] = TenantQuota(
                    max_updates=max_updates, max_bytes=max_bytes,
                    policy=policy,
                )

    def quota(self, tenant: str) -> Optional[TenantQuota]:
        with self._lock:
            q = self._quotas.get(tenant)
            return dataclasses.replace(q) if q is not None else None

    def tenant_bytes(self, tenant: str) -> int:
        """Logical resident bytes in ``tenant``'s partition (what
        ``TenantQuota.max_bytes`` budgets against)."""
        with self._lock:
            return self._tenant_bytes.get(tenant, 0)

    def _evict_locked(self, key: _Key) -> None:
        """Evict one resident update (quota pressure or external
        re-submission). Bumps the key's write-version FIRST so every
        in-flight version-checked consumer — a closing round's
        ``remove``, a streaming ``_load_block`` read — sees the entry
        as superseded and skips it instead of folding half-unlinked
        bytes or unlinking a successor's blob. Caller holds
        ``self._lock`` and unlinks the spool files outside it."""
        self._versions[key] = self._versions.get(key, 0) + 1
        self._drop_index_entry(key)
        self.stats.evictions += 1
        self._tstats(key[0]).evictions += 1

    def _unlink_evicted(
        self, victims: Dict[_Key, Tuple[int, Optional[Tuple]]]
    ) -> None:
        """Unlink quota-eviction victims' spool files, guarded two ways
        so a victim RE-WRITTEN around the eviction keeps its fresh
        blob: the key's version is re-checked right before its files go
        (the ``remove`` guard — catches rewrites that already
        registered), and the on-disk blob's stat identity is compared
        to the identity the EVICTED entry owned (catches a rewrite that
        has staged its new bytes but not yet registered — ``write``
        saves the blob before taking the lock). ``victims`` maps
        key -> (version at eviction, owned blob identity).

        Residual lock-free-spool window (same class ``remove``
        documents): a rewrite whose ``np.save`` lands in the
        microseconds between the identity stat and the unlink can
        still lose its blob — the guards NARROW the race to that
        window, they cannot close it without per-key file locks."""
        if self.backend != "disk":
            return
        for key, (ver, ident) in victims.items():
            with self._lock:
                if key in self._weights or key in self._mem or \
                        self._versions.get(key, 0) != ver:
                    continue   # re-registered since the eviction
            path = self._path(key[1], key[0])
            try:
                if ident is not None and _stat_identity(path) != ident:
                    continue   # fresh bytes staged by an in-flight write
            except OSError:
                continue       # already gone
            self._unlink([key])

    def _quota_check_locked(
        self, key: _Key, raw_bytes: int,
        pend_counts: Optional[Dict[str, int]] = None,
        pend_bytes: Optional[Dict[str, int]] = None,
        pend_raw: Optional[Dict[_Key, int]] = None,
    ) -> Tuple[str, Dict[_Key, Tuple[int, Optional[Tuple]]]]:
        """Decide what admitting ``key`` (``raw_bytes`` logical bytes)
        does to its tenant's quota. Returns ``(verdict, victims)``:
        verdict ``"ok"`` (victims already evicted from the index;
        caller passes the returned {key -> (eviction version, owned
        blob identity)} map to ``_unlink_evicted`` outside the lock)
        or ``"reject"``. Caller holds ``self._lock``.

        ``pend_*`` carry a ``write_batch``'s earlier items — admitted
        and staged but not yet registered — so intra-batch admissions
        can't over-fill the budget the registrations will consume."""
        tenant = key[0]
        q = self._quotas.get(tenant)
        if q is None:
            return "ok", {}
        p_counts = (pend_counts or {}).get(tenant, 0)
        p_bytes = (pend_bytes or {}).get(tenant, 0)
        p_raw = pend_raw or {}
        replacing = key in self._nbytes or key in p_raw
        prior_raw = (p_raw[key] if key in p_raw
                     else self._nbytes.get(key, 0)) if replacing else 0
        new_count = self._counts.get(tenant, 0) + p_counts \
            + (0 if replacing else 1)
        new_bytes = self._tenant_bytes.get(tenant, 0) + p_bytes \
            + raw_bytes - prior_raw
        over_count = q.max_updates is not None and new_count > q.max_updates
        over_bytes = q.max_bytes is not None and new_bytes > q.max_bytes
        if not over_count and not over_bytes:
            return "ok", {}
        if q.policy == "reject":
            return "reject", {}
        # evict policy: drop the tenant's oldest arrivals (never the
        # incoming key itself) until the newcomer fits
        order = sorted(
            (ts, k) for k, ts in self._arrivals.items()
            if k[0] == tenant and k != key
        )
        victims: List[_Key] = []
        for _, k in order:
            if (q.max_updates is None or new_count <= q.max_updates) and \
                    (q.max_bytes is None or new_bytes <= q.max_bytes):
                break
            new_count -= 1
            new_bytes -= self._nbytes.get(k, 0)
            victims.append(k)
        still_over = (
            (q.max_updates is not None and new_count > q.max_updates)
            or (q.max_bytes is not None and new_bytes > q.max_bytes)
        )
        if still_over:
            # the update alone busts the budget: nothing to evict for it
            return "reject", {}
        evicted: Dict[_Key, Tuple[int, Optional[Tuple]]] = {}
        for k in victims:
            ident = self._blob_mtime.get(k)   # before the drop pops it
            self._evict_locked(k)
            evicted[k] = (self._versions.get(k, 0), ident)
        return "ok", evicted

    def _account_write_locked(self, key: _Key, raw_bytes: int) -> None:
        """Byte accounting for a registered write. Caller holds
        ``self._lock`` and has already updated ``_counts``."""
        tenant = key[0]
        self._tenant_bytes[tenant] = (
            self._tenant_bytes.get(tenant, 0) + raw_bytes
            - self._nbytes.get(key, 0)
        )
        self._nbytes[key] = raw_bytes

    # -- client side --------------------------------------------------------
    def _normalize_update(
        self, update
    ) -> Tuple[Optional[CompressedUpdate], Optional[np.ndarray], int]:
        """``(cu, vec, raw_bytes)`` for one incoming update: exactly
        one of ``cu``/``vec`` is set; ``raw`` is the logical stored
        payload the quota/stats budget against."""
        if isinstance(update, CompressedUpdate):
            # quota/stats budget the REAL stored payload: codes + scales
            return update, None, update.nbytes
        vec = np.asarray(
            update if getattr(update, "ndim", None) == 1
            else tree_to_flat_vector(update)
        )
        if vec.dtype.kind in "biu":   # ints/bools promote; floats keep
            vec = vec.astype(np.float32)
        return None, vec, int(vec.nbytes)

    def write(
        self,
        client_id: str,
        update,
        weight: float = 1.0,
        tenant: str = DEFAULT_TENANT,
    ) -> float:
        """Store one update (pytree or flat vector) in ``tenant``'s
        partition. Returns the modeled write latency (bandwidth model,
        paper Fig. 12). Concurrent writes to the SAME (tenant,
        client_id) are last-writer-wins; the same client_id under two
        tenants are independent updates. With a :class:`TenantQuota`
        installed for ``tenant``, an over-budget write raises
        :class:`QuotaExceededError` (``reject``) or evicts the tenant's
        oldest resident updates to make room (``evict``)."""
        res = self.write_batch([(client_id, update, weight, tenant)])[0]
        if isinstance(res, BaseException):
            raise res
        return res

    def write_batch(
        self, items: Sequence[Tuple[str, object, float, str]]
    ) -> List[object]:
        """Land several updates with ONE registration-lock acquisition
        and ONE arrival notification — the ingest front-end's batched
        commit path (``repro.serving.IngestQueue`` coalesces concurrent
        uploads into these).

        ``items`` is a sequence of ``(client_id, update, weight,
        tenant)``. Returns one result per item, in order: the modeled
        write latency (float) on success, or the exception instance
        (``ValueError`` for an invalid tenant, ``QuotaExceededError``
        on a reject-policy refusal) — per-item failures never abort the
        rest of the batch, and a rejected item stages NO blob, exactly
        like a rejected ``write``.

        Semantics match N sequential ``write`` calls: per-item quota
        decisions see earlier batch items (the in-flight bytes/counts
        are carried into each check), duplicate keys are last-writer-
        wins, and stats count every item."""
        results: List[object] = [None] * len(items)
        # per-tenant deltas from earlier batch items admitted but not
        # yet registered — the quota check must see them or a batch
        # could over-admit past the budget
        pend_counts: Dict[str, int] = {}
        pend_bytes: Dict[str, int] = {}
        pend_raw: Dict[_Key, int] = {}
        staged = []
        for i, (client_id, update, weight, tenant) in enumerate(items):
            if not _valid_tenant(tenant):
                results[i] = ValueError(
                    f"invalid tenant name {tenant!r}: must be a "
                    "non-empty single path component (it names a "
                    "spool subdirectory)"
                )
                continue
            key = (tenant, client_id)
            cu, vec, raw = self._normalize_update(update)
            nbytes = raw * self.replication
            latency = nbytes / (self.datanode_bw * self.n_datanodes)
            # quota enforcement BEFORE any blob lands on disk: a
            # rejected write never leaves an orphan file, and evict-
            # policy victims free their budget before the newcomer
            # stages. The unlocked emptiness probe keeps the no-quota
            # ingest hot path at ONE lock acquisition per batch (a
            # quota installed concurrently can miss at most the writes
            # already in flight — the documented bound).
            verdict, victims = "ok", {}
            if self._quotas:  # lint: disable=guarded-access -- unlocked emptiness probe; one lock per batch on the no-quota hot path, staleness bound documented above
                with self._lock:
                    verdict, victims = self._quota_check_locked(
                        key, raw,
                        pend_counts=pend_counts, pend_bytes=pend_bytes,
                        pend_raw=pend_raw,
                    )
            self._unlink_evicted(victims)
            if verdict == "reject":
                results[i] = QuotaExceededError(
                    f"tenant {tenant!r}: update of {raw} B for "
                    f"{client_id!r} exceeds the tenant quota "
                    f"{self._quotas.get(tenant)}"  # lint: disable=guarded-access -- read-only repr for the error message; the verdict was computed under the lock
                )
                continue
            mtime = self._stage_disk(client_id, tenant, cu, vec, weight)
            if key in pend_raw:          # replaces an earlier batch item
                pend_bytes[tenant] = (
                    pend_bytes.get(tenant, 0) - pend_raw[key]
                )
            elif key in self._nbytes:    # lint: disable=guarded-access -- intra-batch pending accounting; staleness bounded by the one-lock-per-batch design documented above
                pend_bytes[tenant] = (
                    pend_bytes.get(tenant, 0)
                    - self._nbytes[key]  # lint: disable=guarded-access -- same intra-batch pending-accounting bound as the elif above
                )
            else:                        # a genuinely new key
                pend_counts[tenant] = pend_counts.get(tenant, 0) + 1
            pend_bytes[tenant] = pend_bytes.get(tenant, 0) + raw
            pend_raw[key] = raw
            staged.append((i, key, cu, vec, weight, mtime, raw,
                           nbytes, latency))
        if staged:
            with self._lock:
                for (i, key, cu, vec, weight, mtime, raw, nbytes,
                     latency) in staged:
                    self._register_locked(key, cu, vec, weight, mtime,
                                          raw, nbytes, latency)
                    results[i] = latency
                self._arrival_cv.notify_all()
        return results

    def _stage_disk(
        self,
        client_id: str,
        tenant: str,
        cu: Optional[CompressedUpdate],
        vec: Optional[np.ndarray],
        weight: float,
    ) -> Optional[Tuple[int, int, int]]:
        """Stage one update's blob + sidecars on the datanode (no
        lock). Returns the staged blob's identity triple (disk
        backend) or None (memory backend)."""
        if self.backend != "disk":
            return None
        # blob + sidecar land on the datanode OUTSIDE the lock.
        # np.save can't round-trip ml_dtypes (bf16 reloads as raw V2),
        # so extension floats spool as raw bytes + a dtype sidecar.
        # Compressed updates spool their int8 codes as the blob plus
        # a .scale sidecar (fp32 scale vector, npy format — written
        # through an open file so np.save can't append '.npy') and a
        # .dim sidecar (logical parameter count, text).
        path = self._path(client_id, tenant)
        if tenant != DEFAULT_TENANT and tenant not in self._made_dirs:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            self._made_dirs.add(tenant)
        dpath = path + ".dtype"
        if cu is not None:
            np.save(path, cu.codes)
            with open(path + ".scale", "wb") as f:
                np.save(f, cu.scales)
            with open(path + ".dim", "w") as f:
                f.write(str(int(cu.dim)))
            try:
                os.remove(dpath)   # stale sidecar from a dense write
            except FileNotFoundError:
                pass
        else:
            if vec.dtype.kind == "V":
                np.save(path, np.ascontiguousarray(vec).view(np.uint8))
                with open(dpath, "w") as f:
                    f.write(vec.dtype.name)
            else:
                np.save(path, vec)
                try:
                    os.remove(dpath)   # stale sidecar, prior dtype
                except FileNotFoundError:
                    pass
            for suffix in (".scale", ".dim"):
                try:   # stale sidecars from a prior compressed write
                    os.remove(path + suffix)
                except FileNotFoundError:
                    pass
        with open(path + ".w", "w") as f:
            f.write(repr(float(weight)))
        try:
            return _stat_identity(path)
        except OSError:
            return None

    def _register_locked(
        self,
        key: _Key,
        cu: Optional[CompressedUpdate],
        vec: Optional[np.ndarray],
        weight: float,
        mtime: Optional[Tuple[int, int, int]],
        raw: int,
        nbytes: int,
        latency: float,
    ) -> None:
        """Register one staged update in the index + stats. Caller
        holds ``self._lock`` and notifies ``_arrival_cv`` after the
        last registration it batches."""
        tenant = key[0]
        src = self._mem if self.backend == "memory" else self._weights
        if key not in src:
            self._counts[tenant] = self._counts.get(tenant, 0) + 1
        if self.backend == "memory":
            self._mem[key] = (cu if cu is not None else vec, weight)
        else:
            self._weights[key] = weight
            if mtime is not None:
                self._blob_mtime[key] = mtime
        self._versions[key] = self._versions.get(key, 0) + 1
        self._arrivals[key] = self.clock()
        self._account_write_locked(key, raw)
        self.stats.writes += 1
        self.stats.bytes_written += nbytes
        self.stats.sim_write_seconds += latency
        ts = self._tstats(tenant)
        ts.writes += 1
        ts.bytes_written += nbytes
        ts.sim_write_seconds += latency

    def _drop_index_entry(self, key: _Key) -> None:
        """Drop one key from every per-key index map and decrement its
        tenant's O(1) count. Caller holds ``self._lock``. ``_versions``
        is deliberately NOT dropped: the counter must never rewind
        while an old round's version snapshot is in flight."""
        if key in self._mem or key in self._weights:
            left = self._counts.get(key[0], 0) - 1
            if left > 0:
                self._counts[key[0]] = left
            else:
                self._counts.pop(key[0], None)
            freed = self._nbytes.get(key, 0)
            left_b = self._tenant_bytes.get(key[0], 0) - freed
            if left_b > 0:
                self._tenant_bytes[key[0]] = left_b
            else:
                self._tenant_bytes.pop(key[0], None)
        self._mem.pop(key, None)
        self._weights.pop(key, None)
        self._nbytes.pop(key, None)
        self._arrivals.pop(key, None)
        self._blob_mtime.pop(key, None)

    # -- aggregator side ----------------------------------------------------
    def _keys(self, tenant: Optional[str]) -> List[_Key]:
        """Sorted index keys of one tenant's partition, or of the whole
        spool (``tenant=None``). Callers must hold ``self._lock``."""
        src = self._mem if self.backend == "memory" else self._weights
        if tenant is None:
            return sorted(src.keys())
        return sorted(k for k in src.keys() if k[0] == tenant)

    def count(self, tenant: Optional[str] = None) -> int:
        """Updates present in ``tenant``'s partition (``None``: whole
        spool). O(1) either way — this is the monitor's per-wake
        poll, so a per-tenant counter is maintained instead of scanning
        the index."""
        with self._lock:
            src = self._mem if self.backend == "memory" else self._weights
            if tenant is None:
                return len(src)
            return self._counts.get(tenant, 0)

    def client_ids(self, tenant: Optional[str] = None) -> List[str]:
        """Sorted client ids in ``tenant``'s partition. With
        ``tenant=None`` (whole spool) an id shared by two tenants
        appears once per tenant."""
        with self._lock:
            return [cid for _, cid in self._keys(tenant)]

    def tenants(self) -> List[str]:
        """Sorted tenants that currently hold at least one update."""
        with self._lock:
            src = self._mem if self.backend == "memory" else self._weights
            return sorted({t for t, _ in src.keys()})

    def arrival_times(
        self, tenant: Optional[str] = None
    ) -> Dict[str, float]:
        """Snapshot of {client_id -> arrival timestamp} for ``tenant``'s
        partition (``None``: whole spool; last tenant wins on a shared
        id) on the store's ``clock`` timebase (``time.monotonic`` by
        default). This is the adaptive controller's training signal:
        the service subtracts the round's start time to get per-client
        arrival offsets."""
        with self._lock:
            return {
                cid: ts for (t, cid), ts in self._arrivals.items()
                if tenant is None or t == tenant
            }

    def wait_for_arrival(self, timeout: float, sleep=time.sleep) -> None:
        """Block until a new arrival is registered or ``timeout`` elapses.
        Event-driven (condition wait, woken by ``write`` /
        ``ingest_external``) under the real clock; with an INJECTED sleep
        (scripted test clocks) the caller's sleep drives time instead.
        The condition is spool-global: a waiter filtering on one tenant
        re-checks its partition on wake (spurious wakes are benign)."""
        if sleep is not time.sleep:
            sleep(timeout)
            return
        with self._arrival_cv:
            self._arrival_cv.wait(timeout)

    def read(
        self, client_id: str, tenant: str = DEFAULT_TENANT
    ) -> Tuple[np.ndarray, float]:
        u, w, _ = self._read_versioned((tenant, client_id))
        return u, w

    def _read_versioned(self, key: _Key) -> Tuple[np.ndarray, float, int]:
        """(update, weight, write-version). For the memory backend the
        array and version are captured under ONE lock, so version-checked
        removal is exact; the disk backend's blob read is lock-free as
        ever, so a racing overwrite can at worst cause a harmless re-fold
        next round (never a lost update).

        The disk path RE-CHECKS the version after the blob (and its
        dtype sidecar) are read: an entry evicted or superseded
        mid-read — quota eviction, external re-submission — bumped its
        version under the lock before any file was touched, so the
        re-check raises ``KeyError`` and the consumer skips the row
        instead of folding a half-unlinked blob (e.g. a bf16 payload
        whose ``.dtype`` sidecar vanished between the two reads)."""
        tenant, client_id = key
        if self.backend == "memory":
            with self._lock:
                arr, weight = self._mem[key]
                version = self._versions.get(key, 0)
            # hand out a read-only VIEW: the spool keeps the only mutable
            # reference, so a caller scribbling on a block cannot corrupt
            # what a concurrent (or later) round will read
            if isinstance(arr, CompressedUpdate):
                return self._readonly_cu(arr), weight, version
            view = arr.view()
            view.flags.writeable = False
            return view, weight, version
        with self._lock:
            weight = self._weights[key]
            version = self._versions.get(key, 0)
        path = self._path(client_id, tenant)
        blob = np.load(path)
        scales = self._sidecar_scales(path)
        if scales is not None:
            blob = CompressedUpdate(
                codes=blob, scales=scales,
                dim=self._sidecar_dim(path, default=int(blob.shape[0])),
            )
        else:
            dt = self._sidecar_dtype(path)
            if dt is not None:
                blob = blob.view(dt)
        with self._lock:
            if key not in self._weights or \
                    self._versions.get(key, 0) != version:
                raise KeyError(key)   # evicted/superseded mid-read
        return blob, weight, version

    @staticmethod
    def _readonly_cu(cu: CompressedUpdate) -> CompressedUpdate:
        codes, scales = cu.codes.view(), cu.scales.view()
        codes.flags.writeable = False
        scales.flags.writeable = False
        return CompressedUpdate(codes=codes, scales=scales, dim=cu.dim)

    @staticmethod
    def _sidecar_dtype(path: str) -> Optional[np.dtype]:
        try:
            with open(path + ".dtype") as f:
                return np.dtype(f.read().strip())
        except FileNotFoundError:
            return None

    @staticmethod
    def _sidecar_scales(path: str) -> Optional[np.ndarray]:
        """The ``.scale`` sidecar (fp32 per-block scale vector) marking
        a compressed blob, or None for a dense one."""
        try:
            with open(path + ".scale", "rb") as f:
                return np.load(f)
        except FileNotFoundError:
            return None

    @staticmethod
    def _sidecar_dim(path: str, default: int) -> int:
        """Logical parameter count of a compressed blob. External
        writers may omit it — the codes length (no padding) is assumed
        then."""
        try:
            with open(path + ".dim") as f:
                return int(f.read().strip())
        except (FileNotFoundError, ValueError):
            return default

    def meta(
        self, tenant: Optional[str] = None
    ) -> Tuple[int, int, np.dtype]:
        """(n_clients, update_dim, stored dtype) for ``tenant``'s
        partition (``None``: whole spool) without loading the set —
        what the planner needs BEFORE choosing an engine. A compressed
        first entry reports its LOGICAL dim and dtype int8 (the planner
        sizes chunks from ``compressed_bytes``, not ``dim * 1``)."""
        with self._lock:
            keys = self._keys(tenant)
        if not keys:
            raise LookupError(
                "empty store" if tenant is None
                else f"empty store partition for tenant {tenant!r}"
            )
        first = keys[0]
        if self.backend == "memory":
            with self._lock:
                vec, _ = self._mem[first]
            if isinstance(vec, CompressedUpdate):
                return len(keys), int(vec.dim), np.dtype(np.int8)
            return len(keys), int(vec.shape[0]), vec.dtype
        path = self._path(first[1], first[0])
        blob = np.load(path, mmap_mode="r")  # header only
        if os.path.exists(path + ".scale"):
            dim = self._sidecar_dim(path, default=int(blob.shape[0]))
            return len(keys), dim, np.dtype(np.int8)
        dt = self._sidecar_dtype(path)
        if dt is not None:
            return len(keys), int(blob.nbytes // dt.itemsize), dt
        return len(keys), int(blob.shape[0]), blob.dtype

    def iter_chunks(
        self,
        chunk_rows: int,
        prefetch: bool = True,
        tenant: Optional[str] = None,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield (updates, weights (c,) fp32) blocks from ``tenant``'s
        partition (``None``: whole spool) — updates is a dense (c, P)
        stored-dtype array, or a :class:`CompressedBlock` for int8
        block-quantized rows (no host-side dequantization). c ==
        chunk_rows except for ragged final blocks; in a MIXED
        dense/compressed partition each chunk splits into one
        homogeneous block per payload kind (see ``_load_block``).

        With ``prefetch`` a reader thread stages block k+1 while the
        engine consumes block k (double buffering): at most two blocks are
        resident, so peak host-side ingest memory is O(2 * chunk * P)
        regardless of n. The iterator works over a snapshot of the client
        index — updates written after the call don't shift the blocks.
        """
        with self._lock:
            keys = self._keys(tenant)
        chunk_rows = max(int(chunk_rows), 1)
        batches = [
            keys[i:i + chunk_rows] for i in range(0, len(keys), chunk_rows)
        ]
        load = self._load_block

        if not prefetch:
            for batch in batches:
                blks = load(batch)
                if blks is not None:  # None: whole batch raced a consume
                    for payload, w, _ in blks:
                        yield payload, w
            return

        q: "queue.Queue" = queue.Queue(maxsize=1)
        stop = threading.Event()   # set when the consumer abandons us

        def put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def reader():
            try:
                for batch in batches:
                    if stop.is_set():
                        return
                    blks = load(batch)
                    if blks is None:  # whole batch raced a consume
                        continue
                    for payload, w, _ in blks:
                        if not put(("block", (payload, w))):
                            return
                put(("done", None))
            except BaseException as exc:  # surface in the consumer
                put(("error", exc))

        t = threading.Thread(
            target=reader, name="updatestore-prefetch", daemon=True
        )
        t.start()
        try:
            while True:
                kind, payload = q.get()
                if kind == "done":
                    break
                if kind == "error":
                    raise payload
                yield payload
        finally:
            # consumer done or bailed early (exception / dropped
            # generator): release the reader so it never blocks holding
            # a staged block
            stop.set()
            t.join()

    def _load_block(
        self,
        batch: List[_Key],
        versions_out: Optional[Dict[str, int]] = None,
        keys_out: Optional[List[_Key]] = None,
    ) -> Optional[List[Tuple[object, np.ndarray, List[_Key]]]]:
        """Stack one batch of index keys into homogeneous sub-blocks
        ``[(payload, (c,) weights, loaded keys), ...]`` where payload is
        a dense (c, P) stored-dtype array or a :class:`CompressedBlock`
        — blob reads happen lock-free, stats update under the lock.

        Rows are GROUPED by payload kind (dense dtype+width, or
        compressed codes-width+block): an all-dense or all-compressed
        batch yields exactly one sub-block (the common case — grouping
        costs nothing), a mixed batch one per kind, in first-seen
        order, so the engines' fixed-shape step executables each see
        rectangular input. A key that vanished between the caller's
        snapshot and the read (consumed by a concurrent round's
        ``remove``, or evicted by the tailer's re-submission handling)
        is SKIPPED, honoring the read contract — a racing consume is at
        worst a smaller block, never a crashed round; ``None`` is
        returned when every key vanished. ``versions_out`` collects
        each id's write-version AS READ, for version-checked
        consumption (``remove``); it is keyed by client id, so it is
        only meaningful for single-tenant batches. ``keys_out``
        collects the keys actually loaded."""
        groups: Dict[tuple, Tuple[list, list, List[_Key]]] = {}
        n_loaded = 0
        for key in batch:
            try:
                u, w, v = self._read_versioned(key)
            except (KeyError, FileNotFoundError):
                continue   # consumed/evicted mid-flight: skip the row
            if versions_out is not None:
                versions_out[key[1]] = v
            if keys_out is not None:
                keys_out.append(key)
            if isinstance(u, CompressedUpdate):
                kind = ("q", u.codes.shape[0], u.scales.shape[0], u.dim)
            else:
                kind = ("d", u.dtype.str, u.shape[0])
            ups, ws, loaded = groups.setdefault(kind, ([], [], []))
            ups.append(u)
            ws.append(w)
            loaded.append(key)
            n_loaded += 1
        if not n_loaded:
            return None
        out: List[Tuple[object, np.ndarray, List[_Key]]] = []
        total_bytes = 0
        per_tenant: Dict[str, Tuple[int, int]] = {}
        for kind, (ups, ws, loaded) in groups.items():
            if kind[0] == "q":
                payload: object = CompressedBlock(
                    codes=np.stack([cu.codes for cu in ups]),
                    scales=np.stack([cu.scales for cu in ups]),
                    dim=kind[3],
                )
                nbytes = payload.nbytes
            else:
                payload = np.stack(ups)
                nbytes = payload.nbytes
            out.append((payload, np.asarray(ws, np.float32), loaded))
            total_bytes += nbytes
            row_bytes = nbytes // max(len(ups), 1)
            for t, _ in loaded:
                n_r, b_r = per_tenant.get(t, (0, 0))
                per_tenant[t] = (n_r + 1, b_r + row_bytes)
        with self._lock:
            self.stats.reads += n_loaded
            self.stats.bytes_read += total_bytes
            self.stats.peak_block_bytes = max(
                self.stats.peak_block_bytes, total_bytes
            )
            for t, (n_r, b_r) in per_tenant.items():
                ts = self._tstats(t)
                ts.reads += n_r
                ts.bytes_read += b_r
                ts.peak_block_bytes = max(ts.peak_block_bytes, b_r)
        return out

    def iter_arrivals(
        self,
        chunk_rows: int,
        should_close: Callable[[int, float], bool],
        poll_interval: float = 0.01,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        versions_out: Optional[Dict[str, int]] = None,
        stats_out: Optional[Dict[str, float]] = None,
        tenant: Optional[str] = None,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, List[str]]]:
        """Arrival-driven streaming read — the async-round substrate.

        Yields (block, (c,) weights, client_ids) — block a dense (c, P)
        array or a :class:`CompressedBlock` (mixed partitions split each
        chunk into homogeneous per-kind blocks) — as soon as
        ``chunk_rows`` NEW updates have landed in ``tenant``'s partition
        (``None``: whole spool), without snapshotting the index up
        front: updates written while the stream is live are picked up on
        the next poll, so an engine can fold partial sums while
        stragglers are still writing — and writes tagged for OTHER
        tenants never enter this stream, which is what makes interleaved
        open rounds safe on one shared store. ``should_close(count,
        waited)`` — the Monitor's threshold/timeout gate — is consulted
        every poll with the total number of matching updates observed so
        far and the seconds since the call; once it returns True the
        stream CLOSES: everything already landed is drained (full
        blocks, then one ragged remainder) and iteration stops. Only the
        final block can be ragged, which is the contract the engines'
        fixed-shape step executables rely on. Updates written after the
        close belong to the next round.

        NOTE the third tuple element is the block's client ids — the
        engines' ``fuse_stream`` block protocol instead expects an
        optional numeric per-row scale there, so adapt (as
        ``AggregationService._aggregate_async`` does) rather than feeding
        this iterator to an engine directly. ``versions_out`` collects
        write-versions as read (for version-checked ``remove``);
        ``stats_out["load_seconds"]`` accumulates actual block-staging
        I/O time, separate from the idle poll wait.
        """
        chunk_rows = max(int(chunk_rows), 1)
        seen: set = set()
        pending: List[_Key] = []
        start = clock()
        while True:
            with self._lock:
                keys = self._keys(tenant)
            fresh = [key for key in keys if key not in seen]
            seen.update(fresh)
            pending.extend(fresh)
            closed = should_close(len(seen), clock() - start)
            while len(pending) >= chunk_rows or (closed and pending):
                batch, pending = pending[:chunk_rows], pending[chunk_rows:]
                t0 = time.perf_counter()
                blks = self._load_block(batch, versions_out=versions_out)
                if stats_out is not None:
                    stats_out["load_seconds"] = (
                        stats_out.get("load_seconds", 0.0)
                        + time.perf_counter() - t0
                    )
                if blks is None:  # whole batch raced a consume/eviction
                    continue
                # ids of the rows ACTUALLY loaded — a key that raced a
                # concurrent consume is skipped, so the caller's folded
                # bookkeeping stays exact
                for payload, w, loaded in blks:
                    yield payload, w, [cid for _, cid in loaded]
            if closed:
                return
            # event-driven under the real clock: wake on the next write's
            # condition notify instead of burning the full poll interval
            self.wait_for_arrival(poll_interval, sleep)

    def read_stacked(
        self, tenant: Optional[str] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """All of ``tenant``'s updates as (n, P) + weights (n,) — the
        DENSE engine input. Order-statistic fusions still need this;
        reducible rounds should stream via ``iter_chunks`` instead.
        Compressed entries ARE dequantized here (host-side, fp32): the
        dense path exists precisely for fusions that need the full
        matrix."""
        ups, ws = [], []
        for block, w in self.iter_chunks(
            chunk_rows=1 << 62, prefetch=False, tenant=tenant
        ):
            if isinstance(block, CompressedBlock):
                block = block.dequantize()
            ups.append(block)
            ws.append(w)
        return np.concatenate(ups), np.concatenate(ws)

    def partition(
        self, n_parts: int, tenant: Optional[str] = None
    ) -> List[List[str]]:
        """Round-robin client placement over partitions (Spark-style),
        within ``tenant``'s partition (``None``: whole spool)."""
        ids = self.client_ids(tenant)
        return [ids[i::n_parts] for i in range(n_parts)]

    def remove(
        self,
        client_ids: Iterable[str],
        versions: Optional[Dict[str, int]] = None,
        tenant: str = DEFAULT_TENANT,
    ) -> None:
        """Consume updates from ``tenant``'s partition — async rounds
        treat the store as a queue and remove what they fold, so late
        stragglers are what remains for the next round, and a round can
        only ever consume its OWN tenant's updates. With ``versions``
        (id -> write-version as folded, from ``iter_arrivals``), an id
        whose version has since advanced is KEPT: a client that re-wrote
        mid-round keeps its newer update for the next round instead of
        losing it. Index entries drop under the lock; blob deletion,
        like all disk I/O, happens outside the critical section.

        The version guard is exact for the memory backend. On disk,
        ``write`` saves the blob before registering it, so a re-write
        racing the unlink batch is re-checked per id right before its
        files go; a write landing inside that last microsecond window can
        still lose its blob (lock-free spool limitation)."""
        keys = [(tenant, cid) for cid in client_ids]
        doomed = []
        with self._lock:
            for key in keys:
                if versions is not None and \
                        self._versions.get(key, 0) != \
                        versions.get(key[1], -1):
                    continue    # re-written since the fold: keep it
                self._drop_index_entry(key)
                doomed.append(key)
        if self.backend != "disk":
            return
        for key in doomed:
            if versions is not None:
                with self._lock:
                    if self._versions.get(key, 0) != \
                            versions.get(key[1], -1):
                        continue    # re-registered while we were unlinking
            self._unlink([key])

    def clear(self, tenant: Optional[str] = None) -> None:
        """Drop every update in ``tenant``'s partition — or the whole
        spool with ``tenant=None``, which also resets stats for a fresh
        round sequence. Keys are snapshotted under the lock; spool blobs
        are deleted outside it (the store's locking discipline: no disk
        I/O in the critical section)."""
        with self._lock:
            keys = self._keys(tenant)
            doomed = keys if self.backend == "disk" else []
            for key in keys:
                self._drop_index_entry(key)
            # grace timestamps purge by TENANT, not by index key —
            # grace-pending external blobs are in _ext_seen but not yet
            # in the index, and a stale first-seen time would skip the
            # grace window for the next blob with that id
            for key in [k for k in self._ext_seen
                        if tenant is None or k[0] == tenant]:
                self._ext_seen.pop(key, None)
            if tenant is None:
                self.stats = StoreStats()
                self._tenant_stats = {}
        self._unlink(doomed)

    def _unlink(self, keys: Iterable[_Key]) -> None:
        for tenant, cid in keys:
            base = self._path(cid, tenant)
            for path in (base, base + ".w", base + ".dtype",
                         base + ".scale", base + ".dim",
                         base + ".tenant"):
                try:
                    os.remove(path)
                except FileNotFoundError:
                    pass

    def _tenant_dir(self, tenant: str) -> str:
        """One tenant's disk partition: the spool root for the default
        tenant (restart-compatible with pre-tenant spools), a
        subdirectory for every other tenant."""
        if tenant == DEFAULT_TENANT:
            return self.spool_dir
        return os.path.join(self.spool_dir, tenant)

    def _path(self, client_id: str, tenant: str = DEFAULT_TENANT) -> str:
        return os.path.join(self._tenant_dir(tenant), f"{client_id}.npy")

    # -- external spool writers (tailing) ------------------------------------
    def _ext_register(
        self, cid: str, tenant: str, from_root: bool = False
    ) -> Optional[str]:
        """Try to register one externally written blob into ``tenant``'s
        partition. Returns the cid when newly registered, None when
        skipped (partial write, sidecar grace, already known)."""
        key = (tenant, cid)
        path = self._path(cid, tenant)
        try:
            blob = np.load(path, mmap_mode="r")
            nbytes = int(blob.nbytes)
            mtime = _stat_identity(path)
        except Exception:
            return None   # partial write: next pass gets it
        try:
            # a compressed external blob's .scale sidecar counts into
            # its quota/stats bytes — real on-disk size, like write()
            scales = np.load(path + ".scale", mmap_mode="r")
            nbytes += int(scales.nbytes)
        except Exception:
            pass   # dense blob (no sidecar) or sidecar mid-write
        try:
            with open(path + ".w") as f:
                weight = float(f.read())
        except (FileNotFoundError, ValueError):
            now = self.wall_clock()   # real elapsed, not self.clock
            with self._lock:
                first = self._ext_seen.setdefault(key, now)
            if now - first < self.sidecar_grace_seconds:
                return None   # sidecar may still be in flight
            weight = 1.0
        with self._lock:
            self._ext_seen.pop(key, None)
            if from_root:
                # a sidecar-routed ROOT blob was grace-tracked under
                # the DEFAULT key while its .tenant sidecar was in
                # flight — drop that too, or a later root re-submission
                # of this cid would read the stale first-seen time as
                # an already-expired grace window. (Subdir
                # registrations must NOT pop it: an unrelated root blob
                # with the same cid may be mid-grace.)
                self._ext_seen.pop((DEFAULT_TENANT, cid), None)
        victims: Dict[_Key, Tuple[int, Optional[Tuple]]] = {}
        try:
            with self._arrival_cv:
                if key in self._weights:
                    return None   # a concurrent write() beat us to it
                verdict, victims = self._quota_check_locked(key, nbytes)
                if verdict == "reject":
                    # over budget: the blob stays on disk unregistered
                    # (re-tried each pass) until capacity frees
                    return None
                self._weights[key] = weight
                self._counts[tenant] = self._counts.get(tenant, 0) + 1
                self._versions[key] = self._versions.get(key, 0) + 1
                self._arrivals[key] = self.clock()
                self._blob_mtime[key] = mtime
                self._account_write_locked(key, nbytes)
                self.stats.writes += 1
                self.stats.bytes_written += nbytes * self.replication
                ts = self._tstats(tenant)
                ts.writes += 1
                ts.bytes_written += nbytes * self.replication
                self._arrival_cv.notify_all()
        finally:
            self._unlink_evicted(victims)
        return cid

    def _ext_sidecar_tenant(self, cid: str) -> str:
        """Peek a ROOT-level external blob's ``.tenant`` sidecar — no
        side effects, so callers can consult the index BEFORE any files
        move. No sidecar (or one naming the default) -> the default
        tenant."""
        try:
            path = os.path.join(self.spool_dir, f"{cid}.npy.tenant")
            with open(path) as f:
                tenant = f.read().strip()
        except FileNotFoundError:
            return DEFAULT_TENANT
        return tenant or DEFAULT_TENANT

    def _ext_move_to_partition(
        self, cid: str, src_dir: str, tenant: str
    ) -> bool:
        """Move an external blob set (blob + sidecars) from ``src_dir``
        into ``tenant``'s partition directory, in place for
        registration. Returns False to defer: the ``.w`` weight sidecar
        may still be in flight behind the blob/``.tenant`` (the
        documented writer order blob -> .tenant -> .w) — moving before
        it lands would orphan the weight behind — so the move waits for
        ``.w`` or the sidecar grace window; an OSError (racing
        concurrent pass) also re-tries next tick."""
        src_base = os.path.join(src_dir, f"{cid}.npy")
        if not os.path.exists(src_base + ".w"):
            now = self.wall_clock()
            with self._lock:
                first = self._ext_seen.setdefault((tenant, cid), now)
            if now - first < self.sidecar_grace_seconds:
                return False   # defer until .w lands (or grace expires)
        dest_dir = self._tenant_dir(tenant)
        os.makedirs(dest_dir, exist_ok=True)
        try:
            # blob moves LAST, so a half-moved set never registers
            # half-done (the .scale/.dim sidecars of a compressed blob
            # are in place before the codes land)
            for suffix in (".w", ".dtype", ".scale", ".dim", ""):
                src = src_base + suffix
                if os.path.exists(src):
                    os.replace(src, self._path(cid, tenant) + suffix)
            try:
                os.remove(src_base + ".tenant")
            except FileNotFoundError:
                pass
        except OSError:
            return False
        return True

    def ingest_external(self) -> List[str]:
        """Register spool blobs written DIRECTLY into ``spool_dir`` by
        external processes (clients mounting the spool, not calling
        ``write``). Disk backend only; returns the newly registered
        client ids (across all tenants).

        Tenant routing: a blob inside ``spool_dir/<tenant>/`` registers
        in that tenant's partition; a root-level blob registers for the
        default tenant unless a ``<cid>.npy.tenant`` sidecar names one,
        in which case the files are moved into the named partition
        first. Writers using the sidecar route must emit it BEFORE the
        ``.w`` weight sidecar (blob -> .tenant -> .w): registration
        happens as soon as the weight is readable. COMPRESSED external
        blobs spool their int8 codes as the ``.npy`` plus ``.scale``
        (and optionally ``.dim``) sidecars, emitted before ``.w`` like
        ``.tenant`` — the registered bytes then count codes + scales,
        and reads yield the entry compressed.

        An unreadable blob (a write still in flight under the polling
        fallback) is skipped and picked up on a later pass — external
        writers should write-to-temp-then-rename so the inotify
        ``IN_MOVED_TO`` event always sees a complete file. Weight comes
        from the ``.w`` sidecar when present. A blob with NO sidecar yet
        is deferred for ``sidecar_grace_seconds`` (wall clock) before it
        registers at weight 1.0: writers emit blob-then-sidecar, so
        registering on first sight would race the sidecar and freeze the
        weight at the default — the sidecar's own close event (or the
        next poll tick) re-passes within the grace window.

        A re-submission that collides with a live default entry while
        the round folding that entry is CLOSING is safe: the eviction
        bumps the entry's write-version under the lock, so the close's
        version-checked ``remove`` skips its unlink batch (the
        re-submitted blob survives) and a streaming read that raced the
        eviction discards the stale bytes instead of folding them —
        see ``_evict_locked``."""
        if self.backend != "disk":
            return []
        with self._lock:
            known = set(self._weights)
        new: List[str] = []
        for name in sorted(os.listdir(self.spool_dir)):
            full = os.path.join(self.spool_dir, name)
            if os.path.isdir(full):
                for sub in sorted(os.listdir(full)):
                    if not sub.endswith(".npy"):
                        continue
                    cid = sub[: -len(".npy")]
                    if (name, cid) in known:
                        continue
                    if name == DEFAULT_TENANT:
                        # a literal 'default/' subdirectory: its files
                        # belong to the root partition — move them there
                        # (paths for the default tenant resolve to the
                        # root; registering in place would np.load a
                        # nonexistent root blob forever)
                        if not self._ext_move_to_partition(
                            cid, full, DEFAULT_TENANT
                        ):
                            continue
                    if self._ext_register(cid, name) is not None:
                        new.append(cid)
                continue
            if not name.endswith(".npy"):
                continue
            cid = name[: -len(".npy")]
            dkey = (DEFAULT_TENANT, cid)
            if dkey in known:
                if not os.path.exists(full + ".tenant"):
                    # common case — registered, no routing intent: one
                    # existence probe per pass, nothing else to do (a
                    # sidecar-less external re-write waits until the
                    # entry is consumed, like subdirectory re-writes)
                    continue
                # the root staging area is shared between default-
                # tenant clients and sidecar-routed external writers.
                # Ownership check: unchanged bytes (mtime as recorded
                # at registration) belong to the live entry — a stray
                # late .tenant sidecar must not move them out from
                # under the index; changed bytes are a NEW external
                # submission — evict the stale entry (its payload is
                # gone from disk) and re-ingest, honoring the sidecar.
                with self._lock:
                    recorded = self._blob_mtime.get(dkey)
                try:
                    current = _stat_identity(full)
                except OSError:
                    continue
                if recorded is None or current == recorded:
                    try:   # live entry owns the bytes: drop stray sidecar
                        os.remove(full + ".tenant")
                    except FileNotFoundError:
                        pass
                    continue
                with self._lock:
                    # eviction bumps the version, so a round CLOSING on
                    # the stale entry right now sees it as superseded:
                    # its version-checked remove skips the unlink (the
                    # re-submitted blob survives) and an in-flight
                    # _load_block read of the old bytes is discarded —
                    # the PR-4 evict-vs-closing-round race is closed
                    self._evict_locked(dkey)
                known.discard(dkey)
            # peek the tenant BEFORE moving anything: a blob registered
            # under the NAMED tenant must not have its files moved/
            # overwritten out from under that entry's version guard —
            # such a re-submission waits at the root until the
            # registered one is consumed, like subdirectory re-writes do
            tenant = self._ext_sidecar_tenant(cid)
            if not _valid_tenant(tenant):
                continue   # poisoned sidecar (path separators, ..): never route
            if (tenant, cid) in known:
                continue
            if tenant != DEFAULT_TENANT and not \
                    self._ext_move_to_partition(cid, self.spool_dir,
                                                tenant):
                continue
            if self._ext_register(cid, tenant, from_root=True) \
                    is not None:
                new.append(cid)
        return new

    def _recover(self) -> Dict[_Key, float]:
        """Rebuild the weight index from the spool after a restart —
        root blobs into the default tenant, one subdirectory per other
        tenant. Blobs still awaiting external ROUTING are left
        unregistered for ``ingest_external`` / the tailer: a root blob
        with a ``.tenant`` sidecar naming another tenant (registering
        it under default would steal it cross-tenant), and anything in
        a literal ``default/`` subdirectory (its files must move to the
        root before the default partition's paths resolve)."""

        def scan(directory: str, tenant: str) -> Dict[_Key, float]:
            weights: Dict[_Key, float] = {}
            for name in os.listdir(directory):
                if not name.endswith(".npy") or not \
                        os.path.isfile(os.path.join(directory, name)):
                    continue   # a subdirectory named *.npy is not a blob
                cid = name[: -len(".npy")]
                wpath = os.path.join(directory, name + ".w")
                try:
                    with open(wpath) as f:
                        weights[(tenant, cid)] = float(f.read())
                except (FileNotFoundError, ValueError):
                    weights[(tenant, cid)] = 1.0
            return weights

        recovered = scan(self.spool_dir, DEFAULT_TENANT)
        for cid in [c for _, c in recovered]:
            if self._ext_sidecar_tenant(cid) != DEFAULT_TENANT:
                recovered.pop((DEFAULT_TENANT, cid))   # pending routing
        for name in os.listdir(self.spool_dir):
            full = os.path.join(self.spool_dir, name)
            if os.path.isdir(full) and name != DEFAULT_TENANT:
                recovered.update(scan(full, name))
        return recovered


class _InotifyWatch:
    """Minimal ctypes inotify(7) binding: block until something lands in
    one of a set of directories. Raises ``OSError`` where inotify is
    unavailable (non-Linux, exhausted watch quota) — callers fall back
    to polling."""

    # no IN_CREATE: waking on creation would pass over files whose
    # contents (and sidecars) are still being written
    _IN_CLOSE_WRITE = 0x00000008
    _IN_MOVED_TO = 0x00000080

    def __init__(self, path: str):
        import ctypes
        import ctypes.util

        libc_name = ctypes.util.find_library("c") or "libc.so.6"
        self._libc = ctypes.CDLL(libc_name, use_errno=True)
        self._fd = self._libc.inotify_init()
        if self._fd < 0:
            raise OSError(ctypes.get_errno(), "inotify_init failed")
        self._watched: set = set()
        try:
            self.add(path)
        except OSError:
            os.close(self._fd)
            raise

    def add(self, path: str) -> None:
        """Watch one more directory (idempotent). Tenant subdirectories
        created after the tailer started are added this way."""
        import ctypes

        if path in self._watched:
            return
        mask = self._IN_CLOSE_WRITE | self._IN_MOVED_TO
        wd = self._libc.inotify_add_watch(
            self._fd, os.fsencode(path), mask
        )
        if wd < 0:
            raise OSError(
                ctypes.get_errno(), f"inotify_add_watch({path}) failed"
            )
        self._watched.add(path)

    def wait(self, timeout: float) -> bool:
        """True if at least one filesystem event fired within ``timeout``
        seconds (the event buffer is drained either way)."""
        import select

        ready, _, _ = select.select([self._fd], [], [], timeout)
        if not ready:
            return False
        try:
            os.read(self._fd, 65536)   # drain; content doesn't matter
        except OSError:
            return False
        return True

    def close(self) -> None:
        try:
            os.close(self._fd)
        except OSError:
            pass


class SpoolTailer:
    """Arrival-driven tailing of a DISK spool written by external
    processes: a daemon thread registers foreign blobs into the store
    index the moment they land, so ``iter_arrivals`` / the monitor see
    them like any ``write()``. Blobs are routed to their tenant
    partition by subdirectory (``spool_dir/<tenant>/``) or by a
    ``.tenant`` sidecar at the spool root (see
    ``UpdateStore.ingest_external``).

    Uses inotify (``IN_CLOSE_WRITE`` / ``IN_MOVED_TO``) when the
    platform provides it — arrivals wake the tailer immediately instead
    of on the next poll tick — and degrades to mtime-free directory
    polling at ``poll_interval`` elsewhere; ``event_driven`` reports
    which mode is live. Tenant subdirectories are discovered (and
    watched) as they appear, at poll cadence. Use as a context manager
    around a round::

        with SpoolTailer(store) as tailer:
            service.aggregate(from_store=True, async_round=True)
    """

    def __init__(self, store: UpdateStore, poll_interval: float = 0.25):
        if store.backend != "disk":
            raise ValueError("SpoolTailer tails DISK spools only")
        self.store = store
        self.poll_interval = poll_interval
        self.event_driven = False
        self._watch: Optional[_InotifyWatch] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _watch_tenant_dirs(self) -> None:
        """Add inotify watches for tenant subdirectories created since
        the last pass (no-op under the polling fallback)."""
        if self._watch is None:
            return
        for name in os.listdir(self.store.spool_dir):
            full = os.path.join(self.store.spool_dir, name)
            if os.path.isdir(full):
                try:
                    self._watch.add(full)
                except OSError:
                    pass   # quota/teardown race: polling still covers it

    def start(self) -> "SpoolTailer":
        try:
            self._watch = _InotifyWatch(self.store.spool_dir)
            self.event_driven = True
        except Exception:
            self._watch = None   # polling fallback
        self._watch_tenant_dirs()
        self.store.ingest_external()   # catch anything already spooled
        self._thread = threading.Thread(
            target=self._run, name="spool-tailer", daemon=True
        )
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.is_set():
            if self._watch is not None:
                self._watch.wait(self.poll_interval)
            else:
                self._stop.wait(self.poll_interval)
            if self._stop.is_set():
                return
            self._watch_tenant_dirs()
            self.store.ingest_external()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._watch is not None:
            self._watch.close()
            self._watch = None

    def __enter__(self) -> "SpoolTailer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
