"""UpdateStore — the HDFS analogue.

Clients write model updates here instead of pushing them over a single
server's NIC (the paper's webHDFS path, §III-D2). The store is the
communication substrate of the distributed engine: placement is sharded
(round-robin over simulated datanodes), capacity is cluster-level rather
than single-node, and reads hand the distributed engine per-shard slices.

Two backends:
  * memory — dict of flat fp32 vectors (fast; benchmarks).
  * disk   — one .npy per update under a spool dir (restart-safe; the
             end-to-end example and fault-tolerance tests use this).

Ingest-time accounting mirrors the paper's Fig. 12 'average write time':
bytes / per-datanode bandwidth with ``replication`` copies.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.utils.pytree import tree_to_flat_vector


@dataclasses.dataclass
class StoreStats:
    writes: int = 0
    bytes_written: int = 0
    sim_write_seconds: float = 0.0  # modeled (bandwidth-based), not wall


class UpdateStore:
    """Thread-safe spool of (client_id -> flat update, weight)."""

    def __init__(
        self,
        backend: str = "memory",
        spool_dir: Optional[str] = None,
        n_datanodes: int = 3,
        replication: int = 2,
        datanode_bw: float = 117e6,  # ~1 GbE in bytes/s, paper's testbed
    ):
        assert backend in ("memory", "disk")
        self.backend = backend
        self.spool_dir = spool_dir
        if backend == "disk":
            assert spool_dir, "disk backend needs spool_dir"
            os.makedirs(spool_dir, exist_ok=True)
        self.n_datanodes = n_datanodes
        self.replication = replication
        self.datanode_bw = datanode_bw
        self._mem: Dict[str, Tuple[np.ndarray, float]] = {}
        self._weights: Dict[str, float] = {}
        self._lock = threading.Lock()
        self.stats = StoreStats()
        if backend == "disk":
            # fault tolerance (the HDFS property the paper leans on):
            # recover updates spooled by a previous aggregator incarnation
            # — weights persist in a sidecar next to each blob
            self._weights.update(self._recover())

    # -- client side --------------------------------------------------------
    def write(self, client_id: str, update, weight: float = 1.0) -> float:
        """Store one update (pytree or flat vector). Returns the modeled
        write latency (bandwidth model, paper Fig. 12)."""
        vec = np.asarray(
            update if getattr(update, "ndim", None) == 1
            else tree_to_flat_vector(update)
        ).astype(np.float32)
        nbytes = vec.nbytes * self.replication
        latency = nbytes / (self.datanode_bw * self.n_datanodes)
        with self._lock:
            if self.backend == "memory":
                self._mem[client_id] = (vec, weight)
            else:
                np.save(self._path(client_id), vec)
                with open(self._path(client_id) + ".w", "w") as f:
                    f.write(repr(float(weight)))
                self._weights[client_id] = weight
            self.stats.writes += 1
            self.stats.bytes_written += nbytes
            self.stats.sim_write_seconds += latency
        return latency

    # -- aggregator side ----------------------------------------------------
    def count(self) -> int:
        with self._lock:
            if self.backend == "memory":
                return len(self._mem)
            return len(self._weights)

    def client_ids(self) -> List[str]:
        with self._lock:
            src = self._mem if self.backend == "memory" else self._weights
            return sorted(src.keys())

    def read(self, client_id: str) -> Tuple[np.ndarray, float]:
        if self.backend == "memory":
            return self._mem[client_id]
        return np.load(self._path(client_id)), self._weights[client_id]

    def read_stacked(self) -> Tuple[np.ndarray, np.ndarray]:
        """All updates as (n, P) + weights (n,) — the engine's input."""
        ids = self.client_ids()
        ups, ws = [], []
        for cid in ids:
            u, w = self.read(cid)
            ups.append(u)
            ws.append(w)
        return np.stack(ups), np.asarray(ws, np.float32)

    def partition(self, n_parts: int) -> List[List[str]]:
        """Round-robin client placement over partitions (Spark-style)."""
        ids = self.client_ids()
        return [ids[i::n_parts] for i in range(n_parts)]

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            if self.backend == "disk":
                for cid in list(self._weights):
                    for path in (self._path(cid), self._path(cid) + ".w"):
                        try:
                            os.remove(path)
                        except FileNotFoundError:
                            pass
                self._weights.clear()

    def _path(self, client_id: str) -> str:
        return os.path.join(self.spool_dir, f"{client_id}.npy")

    def _recover(self) -> Dict[str, float]:
        """Rebuild the weight index from the spool after a restart."""
        weights: Dict[str, float] = {}
        for name in os.listdir(self.spool_dir):
            if name.endswith(".npy"):
                cid = name[: -len(".npy")]
                wpath = os.path.join(self.spool_dir, name + ".w")
                try:
                    with open(wpath) as f:
                        weights[cid] = float(f.read())
                except (FileNotFoundError, ValueError):
                    weights[cid] = 1.0
        return weights
