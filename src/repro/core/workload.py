"""Workload classification — the paper's Algorithm 1 condition, adapted to
the TPU memory hierarchy.

Paper: ``S = w_s * n`` compared against single-node DRAM ``M``.
Here the single "node" is one TPU chip, so the classes are:

  VMEM_RESIDENT — one update tile fits the Pallas accumulator tiling, and
                  the whole batch streams through a single chip comfortably
                  (S < vmem_streaming_limit). The fused single-chip kernel
                  is fastest: one HBM pass, no collectives.
  HBM_LOCAL     — S fits one chip's HBM (with headroom for the fused
                  output and working set). Single-chip fusion, jnp or
                  Pallas engine.
  DISTRIBUTED   — S exceeds one chip: shard clients/coordinates across the
                  mesh (the paper's Spark/HDFS path).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.utils.mem import TPU_V5E, HardwareSpec


class WorkloadClass(enum.Enum):
    VMEM_RESIDENT = "vmem_resident"
    HBM_LOCAL = "hbm_local"
    DISTRIBUTED = "distributed"


@dataclasses.dataclass(frozen=True)
class Workload:
    """One aggregation round's load descriptor (the paper's (w_s, n))."""

    update_bytes: int          # w_s — REAL on-wire bytes per update
    n_clients: int             # n
    dtype_bytes: int = 4
    # explicit param count for payloads where update_bytes is not
    # params * dtype_bytes (int8 codes carry fp32 per-block scales)
    params: Optional[int] = None

    @property
    def total_bytes(self) -> int:  # S = w_s * n
        return self.update_bytes * self.n_clients

    @property
    def num_params(self) -> int:
        if self.params is not None:
            return self.params
        return self.update_bytes // self.dtype_bytes

    @classmethod
    def for_params(cls, num_params: int, n_clients: int,
                   compressed: bool = False,
                   block: Optional[int] = None) -> "Workload":
        """Build a load descriptor from a parameter count using the
        REAL transport payload size. With ``compressed=True`` the
        per-update bytes are the int8 codes + fp32 per-block scales
        (``repro.core.compress.compressed_bytes``), ~4x smaller than
        fp32 — classifying compressed rounds at fp32 size overstates S
        by the same factor and can push HBM_LOCAL work to the
        DISTRIBUTED path for no reason."""
        if compressed:
            # local import: compress pulls in jax; keep the classifier
            # importable without it
            from repro.core.compress import BLOCK, compressed_bytes
            return cls(
                update_bytes=compressed_bytes(num_params, block or BLOCK),
                n_clients=n_clients, dtype_bytes=1, params=num_params,
            )
        return cls(update_bytes=num_params * 4, n_clients=n_clients,
                   dtype_bytes=4, params=num_params)


# fraction of HBM usable for update storage (rest: program, output, fp32
# accumulators, XLA workspace)
HBM_HEADROOM = 0.75


def classify(load: Workload, hw: HardwareSpec = TPU_V5E) -> WorkloadClass:
    s = load.total_bytes
    if s <= hw.vmem_bytes * 4:
        # small enough that even a few streamed passes stay VMEM-friendly
        return WorkloadClass.VMEM_RESIDENT
    if s <= hw.hbm_bytes * HBM_HEADROOM:
        return WorkloadClass.HBM_LOCAL
    return WorkloadClass.DISTRIBUTED


def max_clients_single_node(update_bytes: int,
                            hw: HardwareSpec = TPU_V5E) -> int:
    """The paper's Fig. 1/2 quantity: max n for one node at given w_s."""
    return int(hw.hbm_bytes * HBM_HEADROOM // max(update_bytes, 1))
