"""The paper's primary contribution: a distributed, elastic, adaptive
aggregation service for federated learning — on a TPU mesh.

Layers:
  workload.py    S = w_s * n classification against the TPU memory hierarchy
  planner.py     roofline cost model + Algorithm-1 engine selection
  fusion/        fusion-algorithm library (FedAvg ... Krum/Zeno/GeoMedian)
  local.py       single-chip engine (jnp baseline | fused Pallas kernel)
  distributed.py shard_map map-reduce engine (+ hierarchical pod mode)
  store.py       UpdateStore (the HDFS analogue, tenant-partitioned)
                 + SpoolTailer (external-blob tailing with tenant routing)
  monitor.py     threshold/timeout straggler gate (pluggable policy,
                 per-tenant counts)
  adaptive.py    learned arrival curves -> per-tenant close policies
                 (+ cross-tenant prior, drift-widened deadlines,
                 drift-saturation re-warmup)
  secure.py      pairwise additive-mask secure aggregation
  service.py     AggregationService facade (seamless transition)
                 + RoundScheduler (concurrent per-tenant round workers)
                 + FairRoundScheduler (weighted-fair, capacity-aware
                 round admission for the serving layer)
"""
from repro.core.adaptive import AdaptiveController, ArrivalModel, ClosePolicy
from repro.core.distributed import DistributedEngine
from repro.core.fusion import REGISTRY, FusionAlgorithm, get_fusion
from repro.core.local import LocalEngine
from repro.core.monitor import Monitor, MonitorResult
from repro.core.planner import Plan, Planner
from repro.core.secure import SecureMasking
from repro.core.service import (
    AggregationService,
    FairRoundScheduler,
    RoundReport,
    RoundScheduler,
)
from repro.core.store import (
    DEFAULT_TENANT,
    QuotaExceededError,
    SpoolTailer,
    StoreStats,
    TenantQuota,
    UpdateStore,
)
from repro.core.workload import (
    Workload,
    WorkloadClass,
    classify,
    max_clients_single_node,
)

__all__ = [
    "AdaptiveController",
    "AggregationService",
    "ArrivalModel",
    "ClosePolicy",
    "DEFAULT_TENANT",
    "DistributedEngine",
    "FairRoundScheduler",
    "FusionAlgorithm",
    "LocalEngine",
    "Monitor",
    "MonitorResult",
    "Plan",
    "Planner",
    "QuotaExceededError",
    "REGISTRY",
    "RoundReport",
    "RoundScheduler",
    "SecureMasking",
    "SpoolTailer",
    "StoreStats",
    "TenantQuota",
    "UpdateStore",
    "Workload",
    "WorkloadClass",
    "classify",
    "get_fusion",
    "max_clients_single_node",
]
