"""Quantized update transport with error feedback (beyond-paper).

The paper attacks the aggregator's ingest bottleneck with a distributed
store; an orthogonal, composable lever is shrinking w_s itself. We
implement symmetric per-block int8 quantization with client-side error
feedback (EF-SGD, Karimireddy et al. 2019): each client keeps the
quantization residual and adds it to its next update, so the DC error
doesn't accumulate and FedAvg convergence is preserved in expectation.

~4x ingest reduction (fp32 -> int8 + one fp32 scale per block), applied
before ``UpdateStore.write``; the aggregator never dequantizes on the
host — the engines either fold the scales into the weighted sum
in-kernel (``repro.kernels.fused_fusion.weighted_sum_dequant_pallas``)
or dequantize on-device inside the cached step executable.

FP32-SCALES INVARIANT: whatever the input dtype (fp32, bf16, fp16 — an
edge client may train in half precision), ``quantize`` returns int8
codes and FP32 scales. Quantization math runs in fp32 internally; the
codes/scales contract never silently follows the input dtype, so spool
sidecars, kernels, and byte accounting all assume exactly
``int8 codes + fp32 scales``.

Wire containers:

  * :class:`CompressedUpdate` — ONE client's update as stored/spooled:
    block-padded int8 codes + fp32 per-block scales + the logical dim.
    ``UpdateStore.write`` accepts it directly (codes blob + ``.scale``
    / ``.dim`` sidecars on disk).
  * :class:`CompressedBlock` — a stacked (c, P_padded) batch of
    compressed rows, what ``UpdateStore.iter_chunks`` /
    ``iter_arrivals`` yield for compressed entries and what the
    engines' ``fuse_stream`` folds without host dequantization.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

BLOCK = 2048


def _quantize_np(vec: np.ndarray, block: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side quantization core: fp vec (P,) -> (zero-padded int8
    codes (B*block,), fp32 scales (B,)). Runs in fp32 regardless of the
    input dtype (the fp32-scales invariant); the pad region quantizes
    to exact zeros, so padded codes dequantize to zero contribution."""
    v = np.asarray(vec, np.float32)
    P = v.shape[0]
    pad = (-P) % block
    if pad:
        v = np.pad(v, (0, pad))
    v = v.reshape(-1, block)
    scale = np.maximum(np.abs(v).max(axis=1) / 127.0, 1e-12)
    scale = scale.astype(np.float32)
    q = np.clip(np.rint(v / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1), scale


def quantize(vec, block: int = BLOCK):
    """fp vec (P,) any float dtype -> (int8 codes (P,), fp32 scales
    (ceil(P/block),)). Accepts fp32/bf16/fp16 input; math runs in fp32
    and the scales are ALWAYS fp32 (the module's invariant) — the
    return contract never follows the input dtype."""
    P = vec.shape[0]
    v = jnp.pad(jnp.asarray(vec, jnp.float32), (0, (-P) % block))
    v = v.reshape(-1, block)
    scale = jnp.max(jnp.abs(v), axis=1).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(v / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:P], scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               block: int = BLOCK) -> jnp.ndarray:
    P = q.shape[0]
    pad = (-P) % block
    v = jnp.pad(q.astype(jnp.float32), (0, pad)).reshape(-1, block)
    return (v * scale[:, None].astype(jnp.float32)).reshape(-1)[:P]


@dataclasses.dataclass(frozen=True)
class CompressedUpdate:
    """One client's int8 block-quantized update, as spooled.

    ``codes`` is zero-padded to a whole number of blocks (codes past
    ``dim`` are exact zeros), so ``block == codes.size // scales.size``
    is recoverable from the shapes alone and stacked batches are
    rectangular without re-padding."""

    codes: np.ndarray    # (n_blocks * block,) int8, zero-padded past dim
    scales: np.ndarray   # (n_blocks,) fp32 — the fp32-scales invariant
    dim: int             # logical parameter count P

    @property
    def block(self) -> int:
        return self.codes.shape[0] // self.scales.shape[0]

    @property
    def nbytes(self) -> int:
        """Real transported/stored payload bytes: codes + scales."""
        return int(self.codes.nbytes + self.scales.nbytes)

    def dequantize(self) -> np.ndarray:
        """(dim,) fp32 — host-side reference path (tests / dense
        fallbacks); the hot path folds scales in-kernel instead."""
        v = self.codes.astype(np.float32).reshape(self.scales.shape[0], -1)
        return (v * self.scales[:, None]).reshape(-1)[: self.dim]


@dataclasses.dataclass(frozen=True)
class CompressedBlock:
    """A stacked batch of compressed rows — the streaming wire format
    ``UpdateStore.iter_chunks`` / ``iter_arrivals`` yield and the
    engines' ``fuse_stream`` fold without host-side dequantization."""

    codes: np.ndarray    # (rows, n_blocks * block) int8
    scales: np.ndarray   # (rows, n_blocks) fp32
    dim: int             # logical parameter count P

    @property
    def rows(self) -> int:
        return int(self.codes.shape[0])

    @property
    def block(self) -> int:
        return self.codes.shape[1] // self.scales.shape[1]

    @property
    def nbytes(self) -> int:
        return int(self.codes.nbytes + self.scales.nbytes)

    def dequantize(self) -> np.ndarray:
        """(rows, dim) fp32 — host-side fallback (``read_stacked``)."""
        c, B = self.scales.shape
        v = self.codes.astype(np.float32).reshape(c, B, -1)
        return (v * self.scales[:, :, None]).reshape(c, -1)[:, : self.dim]


def compress_update(vec, block: int = BLOCK) -> CompressedUpdate:
    """Quantize one flat update into its spool container (host-side
    numpy — this is the client write path, no jit dispatch)."""
    v = np.asarray(vec)
    codes, scales = _quantize_np(v, block)
    return CompressedUpdate(codes=codes, scales=scales, dim=int(v.shape[0]))


@dataclasses.dataclass
class ErrorFeedbackCompressor:
    """Per-client stateful compressor: quantizes (update + residual),
    carries the new residual forward."""

    block: int = BLOCK

    def __post_init__(self):
        self._residual: Dict = {}

    def compress(self, client_id, update: jnp.ndarray):
        u = jnp.asarray(update, jnp.float32)
        r = self._residual.get(client_id)
        if r is not None:
            u = u + r
        q, scale = quantize(u, self.block)
        self._residual[client_id] = u - dequantize(q, scale, self.block)
        return q, scale

    def compress_update(self, client_id, update) -> CompressedUpdate:
        """EF-compensated :class:`CompressedUpdate` for the store write
        path (host numpy; residual carried like ``compress``)."""
        u = np.asarray(update, np.float32)
        r = self._residual.get(client_id)
        if r is not None:
            u = u + np.asarray(r, np.float32)
        cu = compress_update(u, self.block)
        self._residual[client_id] = u - cu.dequantize()
        return cu

    def reset(self):
        self._residual.clear()


def compressed_bytes(n_params: int, block: int = BLOCK) -> int:
    """Stored payload bytes for one compressed update: block-PADDED
    int8 codes (the spool stores whole blocks) + the fp32 scale
    vector. Tiny text sidecars (weight/dim) are excluded, consistent
    with dense accounting excluding the ``.w`` sidecar."""
    n_blocks = -(-n_params // block)
    return n_blocks * block + 4 * n_blocks


def compression_ratio(n_params: int, block: int = BLOCK) -> float:
    return 4.0 * n_params / compressed_bytes(n_params, block)
