"""Quantized update transport with error feedback (beyond-paper).

The paper attacks the aggregator's ingest bottleneck with a distributed
store; an orthogonal, composable lever is shrinking w_s itself. We
implement symmetric per-block int8 quantization with client-side error
feedback (EF-SGD, Karimireddy et al. 2019): each client keeps the
quantization residual and adds it to its next update, so the DC error
doesn't accumulate and FedAvg convergence is preserved in expectation.

4x ingest reduction (fp32 -> int8 + one fp32 scale per block), applied
before `UpdateStore.write`; the aggregator dequantizes (or, for the
fused kernel path, folds the scales into the weighted sum).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 2048


def quantize(vec: jnp.ndarray, block: int = BLOCK):
    """fp vec (P,) -> (int8 codes (P,), fp32 scales (ceil(P/block),))."""
    P = vec.shape[0]
    pad = (-P) % block
    v = jnp.pad(vec.astype(jnp.float32), (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(v), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(v / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:P], scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               block: int = BLOCK) -> jnp.ndarray:
    P = q.shape[0]
    pad = (-P) % block
    v = jnp.pad(q.astype(jnp.float32), (0, pad)).reshape(-1, block)
    return (v * scale[:, None]).reshape(-1)[:P]


@dataclasses.dataclass
class ErrorFeedbackCompressor:
    """Per-client stateful compressor: quantizes (update + residual),
    carries the new residual forward."""

    block: int = BLOCK

    def __post_init__(self):
        self._residual: Dict[int, jnp.ndarray] = {}

    def compress(self, client_id: int, update: jnp.ndarray):
        u = update.astype(jnp.float32)
        r = self._residual.get(client_id)
        if r is not None:
            u = u + r
        q, scale = quantize(u, self.block)
        self._residual[client_id] = u - dequantize(q, scale, self.block)
        return q, scale

    def reset(self):
        self._residual.clear()


def compressed_bytes(n_params: int, block: int = BLOCK) -> int:
    n_blocks = -(-n_params // block)
    return n_params + 4 * n_blocks  # int8 codes + fp32 scales


def compression_ratio(n_params: int, block: int = BLOCK) -> float:
    return 4.0 * n_params / compressed_bytes(n_params, block)
