"""AggregationService — the paper's top-level contribution (Algorithm 1 +
§III-D): an adaptive, elastic aggregation facade that routes every round's
workload to the best engine and transitions seamlessly between them.

Round flow (mirrors Algorithm 1):
  1. S = w_s * n  -> classify + plan (planner.py's roofline cost model,
     plus a reuse term: engines holding a compiled executable for this
     round's shape bucket are costed below cold ones).
  2. small  -> single-chip engine (jnp baseline or fused Pallas path),
     updates land in memory exactly as IBMFL receives them over gRPC.
  3. large  -> clients were already redirected to the UpdateStore (the
     seamless-transition hook, §III-D3); monitor(T_h, timeout) gates the
     round; STREAMABLE fusions then STREAM (chunk, P) blocks off the
     store through one cached step executable — on the single-chip
     engine or per-shard over the mesh — so the dense (n, P) matrix
     never materializes on the host. Streamable = the reducible sum
     family (O(P) carry) plus the order-statistic reducers
     (TrimmedMean / CoordMedian) via the O(K*P) top-k carve, gated by
     ``robust_state_budget``; over-budget carve rounds and
     non-streamable fusions (Krum) fall back to the dense read /
     distributed engine with a ``RoundReport.notes`` entry.
  4. The fused flat vector is unflattened back into the model pytree.

ASYNC ROUNDS (``aggregate(from_store=True, async_round=True)``): instead
of idling in ``Monitor.wait()`` and only then ingesting, the round feeds
``UpdateStore.iter_arrivals`` into the engine's ``fuse_stream`` — partial
sums fold WHILE stragglers are still writing, and the monitor's
threshold/timeout gate decides when the in-flight stream closes. Folded
updates are consumed from the store (queue semantics); stragglers that
miss the close land in the next round. With ``staleness_discount=γ`` the
accumulator carries over between rounds (continuous / multi-tenant
aggregation): round r starts from γ × round r−1's partial sums and a
straggler that is a rounds late folds at weight γ^a. With the discount
disabled (None, the default) each async round is independent and — on a
fixed client set — bit-for-bit the same reduction as the synchronous
streamed path (tests/test_equivalence.py). ``async_round="auto"`` lets
the planner's overlap model choose (async wins once the expected monitor
wait dominates the close-drain residue).

ADAPTIVE ROUNDS (``AggregationService(adaptive=True, cost_bias=b)``):
the static threshold/timeout gate is replaced per round by the
``repro.core.adaptive`` controller's learned policy — an
exponentially-weighted empirical arrival curve per ``tenant`` (fed by
the store's write timestamps) is minimized against the planner's
cost-vs-staleness objective, so the gate closes exactly when the
marginal straggler stops being worth the wait. ``cost_bias`` is the
paper's user knob: 0 optimizes round wall-clock, 1 optimizes update
inclusion. A tenant without arrival history borrows the controller's
cross-tenant PRIOR curve (cold-start transfer), and a tenant whose
arrival behavior is drifting faster than the EW window gets a widened
deadline backstop. ``save_controller`` / ``load_controller`` persist
the learned state into ``repro/checkpoint`` alongside model state.

MULTI-TENANT ROUNDS: both the service-side cross-round state — carry
accumulator, straggler ages, learned curves — AND the UpdateStore
itself are keyed by ``tenant``: every write lands in one tenant's
store partition, and a round gates on, folds, and consumes ONLY its
own tenant's partition. Concurrent tenants interleave open rounds on
one shared store (and share the engines' warm compile caches) without
stealing each other's updates — see docs/MULTITENANCY.md.

CONCURRENT ROUND EXECUTION: ``aggregate`` is thread-safe — rounds for
DIFFERENT tenants run genuinely concurrently on one service (the
``RoundScheduler`` below owns one worker thread per tenant), while two
rounds for the SAME tenant serialize on a per-tenant lock (carry
accumulators, straggler ages, and the store's queue semantics assume
one open round per tenant). What concurrent rounds share is safe by
construction: the engines' compile caches are single-flight per shape
bucket (two tenants racing the same bucket compile once and share the
executable), engine accumulator state is per-call, compile-phase
accounting is per-thread, the adaptive controller serializes
internally, and DEVICE execution is bounded by the service's
``device_concurrency`` semaphore — concurrent tenants overlap their
monitor waits and host staging, while the hardware only runs the
configured number of folds at a time. One caveat: stateful fusions
(FedAvgM / FedAdam carry server-side velocity) share that state across
every tenant on the service — use a stateless fusion (fedavg family)
or one service per tenant when concurrent tenants train distinct
models.

Convergence guarantee (paper §IV-C): every engine computes the *same*
fusion formula — tests/test_equivalence.py asserts allclose across
engines, which is the system's core invariant.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaptiveController, ClosePolicy
from repro.core.compress import (
    BLOCK,
    CompressedUpdate,
    ErrorFeedbackCompressor,
    compressed_bytes,
)
from repro.core.distributed import DistributedEngine
from repro.core.fusion import FusionAlgorithm, get_fusion
from repro.core.local import LocalEngine
from repro.core.monitor import Monitor, MonitorResult
from repro.core.planner import Plan, Planner
from repro.core.store import DEFAULT_TENANT, StoreStats, UpdateStore
from repro.core.workload import Workload, WorkloadClass, classify
from repro.utils.mem import TPU_V5E, HardwareSpec
from repro.utils.pytree import flat_vector_to_tree, tree_to_flat_vector

PyTree = Any

# Monitor threshold sentinel: no client count can close the gate — the
# round is gated by the timeout alone (async rounds with no expected
# client count).
_TIMEOUT_GATED = 1 << 62


@dataclasses.dataclass
class RoundReport:
    plan: Plan
    n_clients: int
    update_bytes: int
    # wall time of the fusion computation; on async rounds this spans the
    # whole overlapped window (fusing AND waiting ran concurrently), so
    # compare phase_seconds across round modes, not fuse_seconds
    fuse_seconds: float
    monitor: Optional[MonitorResult] = None
    route_next_to_store: bool = False
    streamed: bool = False       # True: chunked store pipeline (no dense n,P)
    # ingest (store -> host blocks) / compile (executable build; 0.0 on
    # warm rounds) / compute (device time) — the paper's Fig. 12 phases
    phase_seconds: Dict[str, float] = dataclasses.field(default_factory=dict)
    # seconds of the monitor window during which fusion work proceeded
    # CONCURRENTLY with the straggler wait (0.0 on serialized rounds)
    overlap_seconds: float = 0.0
    async_round: bool = False    # arrival-driven overlapped round
    empty: bool = False          # monitor timed out with nothing to fuse
    tenant: str = DEFAULT_TENANT  # store partition / continuity key
    # the gate that closed this round — source == "learned" once the
    # adaptive controller has enough arrival history for the tenant
    close_policy: Optional[ClosePolicy] = None
    # snapshot of the TENANT's store accounting at round close (writes /
    # bytes / reads / evictions — per-partition, not spool-global)
    store_stats: Optional[StoreStats] = None
    # actual payload bytes the fusion ingested (pre-padding): int8 codes
    # + fp32 scales on compressed rounds, the dense matrix bytes
    # otherwise — the paper's transport-cost metric
    bytes_ingested: int = 0
    # operator-facing routing notes, e.g. why a robust round fell back
    # from the streamed carve to the dense path (state budget exceeded)
    notes: Tuple[str, ...] = ()


class AggregationService:
    """Adaptive aggregation service over a (possibly trivial) mesh."""

    def __init__(
        self,
        fusion: FusionAlgorithm | str = "fedavg",
        mesh=None,
        hw: HardwareSpec = TPU_V5E,
        local_strategy: str = "pallas",
        store: Optional[UpdateStore] = None,
        threshold_frac: float = 0.8,
        monitor_timeout: float = 30.0,
        memory_cap_bytes: Optional[int] = None,
        stream_chunk_bytes: int = 64 << 20,
        staleness_discount: Optional[float] = None,
        adaptive: bool = False,
        cost_bias: float = 0.5,
        compress: bool | int = False,
        device_concurrency: int = 1,
        secure=None,
        robust_state_budget: int = 64 << 20,
        clock=time.monotonic,
        sleep=time.sleep,
        poll_interval: float = 0.01,
    ):
        """Configure the adaptive aggregation facade.

        Args:
          fusion: fusion algorithm name (``repro.core.fusion.REGISTRY``)
            or instance; reducible ones (fedavg family) unlock streaming
            and async rounds.
          mesh: optional device mesh — enables the distributed (and,
            with a ``pod`` axis, hierarchical) engines.
          hw: hardware spec for the planner's roofline cost model.
          local_strategy: ``"jnp"`` (baseline) or ``"pallas"`` (fused
            kernel) for the single-chip engine.
          store: the UpdateStore clients write to (``from_store``
            rounds); a private memory-backed store by default.
          threshold_frac: the STATIC gate — close once this fraction of
            ``expected_clients`` has landed. The adaptive controller
            re-derives it per round when ``adaptive=True``.
          monitor_timeout: static gate deadline in seconds; also the cap
            no learned deadline may exceed.
          memory_cap_bytes: simulate a memory-limited aggregator node
            (forces chunked streaming below the cap).
          stream_chunk_bytes: target bytes per streamed (chunk, P) block
            when no memory cap is set.
          staleness_discount: γ in (0, 1] enables continuous rounds —
            the accumulator carries over between async rounds scaled by
            γ (per tenant), and a straggler folding ``a`` rounds late is
            discounted to γ^a of its weight. None (default): every
            round is independent and bit-equivalent to the synchronous
            streamed path.
          adaptive: learn per-tenant arrival curves and replace the
            static gate with the controller's learned threshold/deadline
            (``repro.core.adaptive``); state is inspectable at
            ``self.controller``.
          cost_bias: the paper's user knob in [0, 1] — 0 optimizes
            round wall-clock (cost), 1 optimizes update inclusion
            (efficiency); only meaningful with ``adaptive=True``.
          compress: quantized transport. ``True`` (block size
            ``repro.core.compress.BLOCK``) or an explicit block size
            enables ``compress_update`` — clients spool int8 codes +
            fp32 per-block scales (~4x fewer bytes) with per-tenant
            error feedback, and store rounds stream them through the
            engines' dequant-folding step without ever materializing
            the fp32 matrix. Mixed rounds are fine: a straggler that
            writes uncompressed fp32 folds into the same accumulator.
          device_concurrency: how many concurrent rounds may EXECUTE on
            the device at once (a bounded semaphore the engines acquire
            per fold step). Default 1 — on a small edge host the
            hardware serializes folds anyway, so concurrent tenants
            overlap only their monitor waits and host staging; raise it
            when the backend genuinely runs kernels in parallel.
          secure: an optional ``repro.core.secure.SecureMasking``
            instance declaring that clients write pairwise-masked
            updates. Mask cancellation needs the plain SUM over the
            close set, so this requires a sum-reducible fusion —
            rejected at construction otherwise. (Composing secure
            masking with ASYNC close sets is the ROADMAP follow-on:
            the mask basis must be renegotiated per inclusion
            decision.)
          robust_state_budget: byte cap on an order-statistic fusion's
            streamed carry state (the O(K*P) top-k carve buffers).
            Rounds whose projected state exceeds it route to the dense
            / distributed path with a ``RoundReport.notes`` entry
            instead of streaming.
          clock / sleep / poll_interval: time sources for the monitor
            and arrival streams, injectable for deterministic tests.
        """
        self.fusion = (
            get_fusion(fusion) if isinstance(fusion, str) else fusion
        )
        self.mesh = mesh
        self.hw = hw
        self.store = store or UpdateStore()
        self.threshold_frac = threshold_frac
        self.monitor_timeout = monitor_timeout
        self.stream_chunk_bytes = stream_chunk_bytes
        self.memory_cap_bytes = memory_cap_bytes
        # async-round continuity: None -> every async round is independent
        # (sync-equivalent); γ in (0, 1] -> the accumulator carries over
        # between rounds scaled by γ, and a straggler folding a rounds
        # late is discounted to γ^a of its weight (continuous aggregation)
        if staleness_discount is not None and not 0 < staleness_discount <= 1:
            raise ValueError("staleness_discount must be in (0, 1] or None")
        self.staleness_discount = staleness_discount
        self.clock = clock               # injectable for deterministic tests
        self.sleep = sleep
        self.poll_interval = poll_interval
        # per-TENANT round continuity (multi-tenant rounds interleave
        # through one service without cross-talk): tenant -> (wsum, tot)
        # pre-combine carry, and tenant -> {straggler id -> rounds late}
        self._carry: Dict[str, tuple] = {}  # guarded-by: _state_lock
        self._stale_ages: Dict[str, Dict[str, int]] = {}  # guarded-by: _state_lock
        # tenant -> last observed monitor wait (async_round="auto"'s
        # projection input; O(1) instead of scanning history per round)
        self._last_wait: Dict[str, float] = {}  # guarded-by: _state_lock
        # concurrency: rounds for the SAME tenant serialize on a
        # per-tenant lock (carry / ages / queue semantics assume one
        # open round per tenant); _state_lock guards the shared maps
        # and history; the device semaphore bounds concurrent device
        # execution across all tenants' folds
        if device_concurrency < 1:
            raise ValueError("device_concurrency must be >= 1")
        self.device_concurrency = device_concurrency
        self.device_sem = threading.BoundedSemaphore(device_concurrency)
        self._state_lock = threading.Lock()
        self._tenant_locks: Dict[str, threading.Lock] = {}  # guarded-by: _state_lock
        self.local = LocalEngine(
            strategy=local_strategy, memory_cap_bytes=memory_cap_bytes
        )
        self.distributed = (
            DistributedEngine(mesh=mesh) if mesh is not None else None
        )
        self.hierarchical = (
            DistributedEngine(mesh=mesh, hierarchical=True)
            if mesh is not None and "pod" in mesh.axis_names else None
        )
        n_dev = mesh.devices.size if mesh is not None else 1
        n_pods = mesh.shape.get("pod", 1) if mesh is not None else 1
        self.planner = Planner(hw=hw, n_devices=n_dev, n_pods=n_pods)
        if not 0 <= cost_bias <= 1:
            raise ValueError("cost_bias must be in [0, 1]")
        self.cost_bias = cost_bias
        # quantized transport: normalize compress to an Optional block
        # size; per-tenant EF compressors are created lazily (client
        # residuals must not leak across tenants)
        if compress is True:
            self.compress_block: Optional[int] = BLOCK
        elif compress:
            if int(compress) < 1:
                raise ValueError("compress block size must be >= 1")
            self.compress_block = int(compress)
        else:
            self.compress_block = None
        self._compressors: Dict[str, ErrorFeedbackCompressor] = {}  # guarded-by: _state_lock
        # unsupported-combo fail-fasts: a clear ValueError here beats an
        # opaque one deep in the round path
        if self.compress_block is not None and not self.fusion.streamable:
            raise ValueError(
                "compress=True requires a streamable fusion (the dequant "
                f"fold runs inside the streamed step); {self.fusion.name} "
                "is not streamable"
            )
        if secure is not None and not self.fusion.reducible:
            raise ValueError(
                "SecureMasking requires a sum-reducible fusion — pairwise "
                "masks only cancel under summation — and "
                f"{self.fusion.name} is not reducible"
            )
        self.secure = secure
        if staleness_discount is not None and not self.fusion.weighted:
            raise ValueError(
                "staleness_discount requires a weighted fusion; "
                f"{self.fusion.name} folds order statistics that cannot "
                "be discounted"
            )
        if int(robust_state_budget) < 1:
            raise ValueError("robust_state_budget must be >= 1 byte")
        self.robust_state_budget = int(robust_state_budget)
        # the adaptive layer: learns per-tenant arrival curves off the
        # store's timestamps and re-derives the gate every round
        self.controller: Optional[AdaptiveController] = (
            AdaptiveController(
                cost_bias=cost_bias,
                threshold_frac=threshold_frac,
                timeout=monitor_timeout,
                planner=self.planner,
            ) if adaptive else None
        )
        self.history: List[RoundReport] = []  # guarded-by: _state_lock

    # -- quantized transport --------------------------------------------------
    def compress_update(
        self, client_id: str, update, tenant: str = DEFAULT_TENANT,
    ) -> CompressedUpdate:
        """Quantize one client update for spooling: int8 codes + fp32
        per-block scales, with per-tenant ERROR FEEDBACK — the client's
        quantization residual is carried into its next round's update,
        so the multi-round fused mean converges to the uncompressed
        one. Pass the result straight to ``store.write``; requires
        ``AggregationService(compress=...)``."""
        if self.compress_block is None:
            raise ValueError(
                "compress_update needs a compressing service "
                "(AggregationService(compress=True) or =block_size)"
            )
        if getattr(update, "ndim", None) != 1:
            update = tree_to_flat_vector(update)
        with self._state_lock:
            comp = self._compressors.get(tenant)
            if comp is None:
                comp = self._compressors[tenant] = ErrorFeedbackCompressor(
                    block=self.compress_block
                )
        return comp.compress_update(client_id, update)

    # -- streaming knobs ------------------------------------------------------
    def _row_bytes(self, p: int, dtype) -> int:
        """Per-client payload bytes in the store: real compressed size
        (padded codes + fp32 scales) when the partition holds int8
        quantized updates, dense bytes otherwise."""
        if np.dtype(dtype) == np.int8:
            return compressed_bytes(p, self.compress_block or BLOCK)
        return p * np.dtype(dtype).itemsize

    def _chunk_rows(self, n: int, row_bytes: int) -> int:
        """Rows per streamed block: half the memory cap (two blocks are
        resident under double buffering), else the chunk-size default."""
        budget = (
            self.memory_cap_bytes // 2
            if self.memory_cap_bytes is not None
            else self.stream_chunk_bytes
        )
        return max(1, min(n, int(budget // max(row_bytes, 1))))

    def _stream_mode(
        self, fusion: FusionAlgorithm, p: int, n_hint: int,
    ) -> Tuple[bool, Optional[str]]:
        """THE stream-eligibility predicate (one place, not three):
        can this round stream, and if not, why not (operator note).

        Reducible fusions always stream (O(P) sum carry). Order-statistic
        fusions stream through the top-k carve iff their projected carry
        state — O(K*P) bytes, K from ``n_hint`` — fits the service's
        ``robust_state_budget``; over-budget rounds route dense with a
        ``RoundReport.notes`` entry rather than raising."""
        if not fusion.streamable:
            return False, None
        if fusion.reducible:
            return True, None
        need = fusion.state_nbytes(p, max(int(n_hint), 1))
        if need > self.robust_state_budget:
            return False, (
                f"robust stream fallback: {fusion.name} carve state needs "
                f"{need / (1 << 20):.1f} MiB for n={int(n_hint)}, P={p} "
                f"(budget {self.robust_state_budget / (1 << 20):.1f} MiB) "
                "— routed to the dense path"
            )
        return True, None

    def _warm_engines(self, n: int, p: int, dtype, chunk_rows=None,
                      fusion: Optional[FusionAlgorithm] = None,
                      n_hint: Optional[int] = None):
        """Engines holding a compiled executable for this round's shape —
        dense keys, or (with ``chunk_rows``) the streamed step keys."""
        fusion = fusion if fusion is not None else self.fusion
        warm = set()
        if chunk_rows is not None:
            blk = self.compress_block or BLOCK
            if self.local.is_warm_stream(
                    fusion, chunk_rows, p, dtype, block=blk,
                    n_hint=n_hint):
                warm.add("local")
            if self.distributed is not None and self.distributed \
                    .is_warm_stream(fusion, chunk_rows, p, dtype,
                                    block=blk, n_hint=n_hint):
                warm.add("distributed")
            if self.hierarchical is not None and self.hierarchical \
                    .is_warm_stream(fusion, chunk_rows, p, dtype,
                                    block=blk, n_hint=n_hint):
                warm.add("hierarchical")
            return warm
        if self.local.is_warm(fusion, n, p, dtype):
            warm.add("local")
        if self.distributed is not None and \
                self.distributed.is_warm(fusion, n, p, dtype):
            warm.add("distributed")
        if self.hierarchical is not None and \
                self.hierarchical.is_warm(fusion, n, p, dtype):
            warm.add("hierarchical")
        return warm

    def _stream_engine(self, name: str):
        if name == "hierarchical" and self.hierarchical is not None:
            return self.hierarchical
        if name == "distributed" and self.distributed is not None:
            return self.distributed
        return self.local

    def _round_lock(self, tenant: str) -> threading.Lock:
        """The tenant's round-serialization lock (created on first use)."""
        with self._state_lock:
            lock = self._tenant_locks.get(tenant)
            if lock is None:
                lock = self._tenant_locks[tenant] = threading.Lock()
            return lock

    # -- Algorithm 1 ----------------------------------------------------------
    def aggregate(
        self,
        updates: Optional[Sequence[PyTree]] = None,
        weights: Optional[Sequence[float]] = None,
        template: Optional[PyTree] = None,
        expected_clients: Optional[int] = None,
        from_store: bool = False,
        async_round: bool | str = False,
        tenant: str = DEFAULT_TENANT,
        val_grad=None,
    ) -> Tuple[PyTree, RoundReport]:
        """One aggregation round. Returns ``(fused, RoundReport)``.

        Thread-safe: rounds for different tenants run concurrently
        (see ``RoundScheduler``); two calls for the SAME tenant
        serialize on the tenant's round lock.

        Input modes:
          * ``updates`` (+ optional ``weights``) — in-memory, the small
            path's arrival mode (updates arrived over RPC, IBMFL-style).
          * ``from_store=True`` — clients wrote to the UpdateStore; the
            monitor gates the round on ``expected_clients`` (falling
            back to the current store count).

        ``async_round`` (store rounds, streamable fusions only) overlaps
        fusion with the straggler wait via arrival-driven streaming:
        ``True`` forces it, ``"auto"`` defers to the planner's overlap
        cost model (async wins once the expected monitor wait dominates
        the close-drain residue), ``False`` serializes (wait, then
        ingest). With ``staleness_discount=γ`` configured, async rounds
        carry the accumulator across rounds per ``tenant`` and discount
        a straggler that is ``a`` rounds late to ``γ^a`` of its weight.

        ``tenant`` keys the round end-to-end: the store partition the
        round gates on, folds, and consumes (writes tagged for other
        tenants are invisible to it), plus all service-side cross-round
        state — carry accumulator, straggler ages, and the adaptive
        controller's learned arrival curve. Concurrent tenants can
        interleave open rounds on ONE shared store without stealing
        each other's updates, while sharing the engines' warm compile
        caches (docs/MULTITENANCY.md). With ``adaptive=True`` on the
        service, the round's close gate is the controller's learned
        threshold/deadline for this tenant — borrowed from the
        cross-tenant prior while the tenant is cold (see
        ``report.close_policy``).

        ``val_grad`` threads a per-round validation gradient to fusions
        that score against one (Zeno): the round runs on a per-call
        CLONE (``fusion.with_val_grad``), so two concurrent tenants
        passing different validation gradients never race one shared
        fusion's state.

        An empty round (timeout, nothing landed) returns
        ``(None, report)`` with ``report.empty`` set instead of
        raising. ``template`` (a model pytree) unflattens the fused
        vector back into model structure."""
        with self._round_lock(tenant):
            return self._aggregate_impl(
                updates, weights, template, expected_clients,
                from_store, async_round, tenant, val_grad,
            )

    def _aggregate_impl(
        self,
        updates: Optional[Sequence[PyTree]],
        weights: Optional[Sequence[float]],
        template: Optional[PyTree],
        expected_clients: Optional[int],
        from_store: bool,
        async_round: bool | str,
        tenant: str,
        val_grad=None,
    ) -> Tuple[PyTree, RoundReport]:
        """``aggregate`` body; caller holds the tenant's round lock."""
        fusion = self.fusion
        if val_grad is not None:
            if not hasattr(fusion, "with_val_grad"):
                raise ValueError(
                    f"{fusion.name} does not score against a validation "
                    "gradient — val_grad only applies to Zeno-style "
                    "fusions"
                )
            fusion = fusion.with_val_grad(val_grad)
        monitor_result = None
        phase: Dict[str, float] = {}
        streamed = False
        policy = arrivals = t_round = t_round_store = None
        expected = expected_clients
        notes: Tuple[str, ...] = ()

        if from_store:
            expected = expected_clients or self.store.count(tenant)
            use_async = self._resolve_async(
                async_round, expected, tenant, fusion=fusion,
            )
            threshold = max(int(expected * self.threshold_frac), 1)
            timeout = self.monitor_timeout
            if self.controller is not None and expected > 0:
                # the adaptive gate: learned threshold/deadline for this
                # tenant (static until the arrival curve has history)
                policy = self.controller.policy(tenant, expected)
                threshold, timeout = policy.threshold, policy.deadline
            if use_async and expected == 0:
                # async rounds legitimately start BEFORE any arrival; with
                # no expected count, a threshold of 1 would close the gate
                # on the first client that lands — gate on the timeout
                # alone instead (such rounds report monitor.ready=False)
                threshold = _TIMEOUT_GATED
                policy = None
            monitor = Monitor(
                self.store,
                threshold=threshold,
                timeout=timeout,
                poll_interval=self.poll_interval,
                clock=self.clock, sleep=self.sleep,
                policy=policy,
                tenant=tenant,
            )
            t_round = self.clock()
            # arrival offsets are computed on the STORE's clock (the
            # timestamps' timebase), which may differ from the service
            # clock under injected test clocks
            t_round_store = self.store.clock()
            if use_async:
                return self._aggregate_async(
                    monitor, expected, template, tenant, t_round, policy,
                    t_round_store, fusion=fusion,
                )
            monitor_result = monitor.wait()
            # arrival snapshot AT CLOSE — the controller's training
            # signal; later stragglers belong to the next round's curve
            arrivals = self.store.arrival_times(tenant)
            if self.store.count(tenant) == 0:
                # timed-out round on an empty partition: structured empty
                # report, not a LookupError out of store.meta()
                return self._empty_round(
                    monitor_result, template, tenant=tenant,
                    t_round=t_round, expected=expected,
                )
            n, p, dtype = self.store.meta(tenant)
            row_bytes = self._row_bytes(p, dtype)
            chunk_rows = self._chunk_rows(n, row_bytes)
            load = Workload(
                update_bytes=row_bytes, n_clients=n,
                dtype_bytes=dtype.itemsize, params=p,
            )
            n_hint = max(n, expected or 0, 1)
            can_stream, stream_note = self._stream_mode(fusion, p, n_hint)
            notes = (stream_note,) if stream_note else ()
            plan = self.planner.plan(
                load, fusion,
                warm_engines=self._warm_engines(
                    n, p, dtype,
                    chunk_rows=chunk_rows if can_stream else None,
                    fusion=fusion,
                    n_hint=n_hint if can_stream else None,
                ),
            )
            if can_stream:
                # zero-materialization pipeline: (chunk, P) blocks flow
                # from the store through one cached step executable —
                # single-chip, or per-shard over the mesh (the dense
                # (n, P) matrix never stages on the host either way)
                engine = self._stream_engine(plan.engine)
                t0 = time.perf_counter()
                fused, srep = engine.fuse_stream(
                    fusion,
                    self.store.iter_chunks(chunk_rows, tenant=tenant),
                    chunk_rows=chunk_rows,
                    device_sem=self.device_sem,
                    n_hint=n_hint,
                )
                dt = time.perf_counter() - t0
                streamed = True
                phase = {
                    "ingest": srep.ingest_seconds,
                    "compile": srep.compile_seconds,
                    "compute": srep.compute_seconds,
                }
                return self._finish(
                    fused, template, plan, n, load, dt, monitor_result,
                    expected_clients, streamed, phase,
                    tenant=tenant, policy=policy, t_round=t_round_store,
                    expected=expected, arrivals=arrivals,
                    ingest_bytes=srep.ingest_bytes, fusion=fusion,
                    notes=notes,
                )
            t0 = time.perf_counter()
            stacked, w = self.store.read_stacked(tenant)
            phase["ingest"] = time.perf_counter() - t0
        else:
            assert updates is not None and len(updates) > 0
            t0 = time.perf_counter()
            flat = [
                np.asarray(
                    u if getattr(u, "ndim", None) == 1
                    else tree_to_flat_vector(u)
                )
                for u in updates
            ]
            stacked = np.stack(flat)
            phase["ingest"] = time.perf_counter() - t0
            w = (
                np.asarray(weights, np.float32)
                if weights is not None
                else np.ones((len(flat),), np.float32)
            )

        # dense path (in-memory round, or store round that can't stream):
        # one plan against the materialized matrix
        n, p = stacked.shape
        load = Workload(
            update_bytes=p * stacked.dtype.itemsize, n_clients=n,
            dtype_bytes=stacked.dtype.itemsize,
        )
        plan = self.planner.plan(
            load, fusion,
            warm_engines=self._warm_engines(
                n, p, stacked.dtype, fusion=fusion,
            ),
        )

        t0 = time.perf_counter()
        if plan.engine == "local":
            # the local engine scopes the semaphore itself: held around
            # executable invocation only, so a cold compile (outside it,
            # single-flight) never stalls other tenants' folds
            fused = self.local.fuse(
                fusion, stacked, w, device_sem=self.device_sem,
            )
            phase["compile"] = self.local.last_compile_seconds
            fused = jax.block_until_ready(fused)
        else:
            # mesh engines compile inside their fuse paths, so a cold
            # dense mesh round holds the semaphore through its compile
            # (known caveat — the mesh engines have no separate warm
            # step; the whole dispatch counts against the budget)
            with self.device_sem:
                if plan.engine == "hierarchical" \
                        and self.hierarchical is not None:
                    fused = self.hierarchical.fuse(fusion, stacked, w)
                    phase["compile"] = \
                        self.hierarchical.last_compile_seconds
                else:
                    assert self.distributed is not None, (
                        "planner chose the distributed engine but no "
                        "mesh was given"
                    )
                    fused = self.distributed.fuse(fusion, stacked, w)
                    phase["compile"] = \
                        self.distributed.last_compile_seconds
                fused = jax.block_until_ready(fused)  # lint: disable=sync-under-sem -- deliberate: the permit must cover device EXECUTION, not just dispatch, or device_concurrency would not bound real device work (PR 5)
        dt = time.perf_counter() - t0
        phase["compute"] = dt - phase.get("compile", 0.0)
        return self._finish(
            fused, template, plan, n, load, dt, monitor_result,
            expected_clients, streamed, phase,
            tenant=tenant, policy=policy, t_round=t_round_store,
            expected=expected, arrivals=arrivals,
            ingest_bytes=int(stacked.nbytes), fusion=fusion,
            notes=notes,
        )

    # -- async (monitor-overlapped) rounds ------------------------------------
    def _resolve_async(
        self, async_round: bool | str, expected: int,
        tenant: str = DEFAULT_TENANT,
        fusion: Optional[FusionAlgorithm] = None,
    ) -> bool:
        """Decide whether this store round overlaps fusion with the wait.
        Only streamable fusions can fold arrivals incrementally; "auto"
        asks the planner whether the expected monitor wait (the TENANT's
        last observed wait, else the timeout) dominates the drain
        residue. Projections are sized off ``tenant``'s store
        partition."""
        fusion = fusion if fusion is not None else self.fusion
        if not async_round or not fusion.streamable:
            return False
        if not fusion.reducible:
            # order-statistic streams must size + budget the carve state
            # up front: no known P yet, or over the state budget -> the
            # round runs synchronously (dense fallback with a note)
            try:
                _n_now, p, _dtype = self.store.meta(tenant)
            except LookupError:
                return False
            ok, _note = self._stream_mode(fusion, p, max(expected, 1))
            if not ok:
                return False
        if async_round != "auto":
            return True
        # the tenant's own history only: another tenant's wait says
        # nothing about this fleet's stragglers
        with self._state_lock:
            last_wait = self._last_wait.get(tenant)
        expected_wait = (
            last_wait if last_wait is not None else self.monitor_timeout
        )
        try:
            n, p, dtype = self.store.meta(tenant)
        except LookupError:
            # nothing has arrived yet — the wait is all there is, so
            # overlapping it is free
            return True
        n_proj = max(expected, n, 1)
        row_bytes = self._row_bytes(p, dtype)
        load = Workload(
            update_bytes=row_bytes, n_clients=n_proj,
            dtype_bytes=dtype.itemsize, params=p,
        )
        # cost against the same warmth the round itself will plan with —
        # a cached stream step must not be billed the cold compile term
        warm = self._warm_engines(
            n_proj, p, dtype,
            chunk_rows=self._chunk_rows(n_proj, row_bytes),
            fusion=fusion, n_hint=n_proj,
        )
        return self.planner.prefer_async(
            load, fusion, expected_wait, warm_engines=warm,
        )

    def _aggregate_async(
        self, monitor: Monitor, expected: int, template,
        tenant: str = DEFAULT_TENANT, t_round: Optional[float] = None,
        policy: Optional[ClosePolicy] = None,
        t_round_store: Optional[float] = None,
        fusion: Optional[FusionAlgorithm] = None,
    ) -> Tuple[PyTree, RoundReport]:
        """Arrival-driven round: fuse while stragglers write (Algorithm 1
        with the monitor folded INTO the ingest stream). The gate —
        static threshold/timeout or the controller's learned policy —
        closes the stream; folded updates are consumed from the
        tenant's store partition (other tenants' concurrent arrivals
        are invisible); stragglers missing the close age into the next
        round (per tenant)."""
        fusion = fusion if fusion is not None else self.fusion
        if t_round is None:
            t_round = monitor.clock()
        if t_round_store is None:
            t_round_store = self.store.clock()
        # learn (P, dtype) from the first arrival — or time out empty
        while True:
            count = self.store.count(tenant)
            waited = monitor.clock() - t_round
            if count > 0 or monitor.should_close(count, waited):
                break
            self.store.wait_for_arrival(monitor.poll_interval,
                                        monitor.sleep)
        if self.store.count(tenant) == 0:
            mr = monitor.result(0, monitor.clock() - t_round)
            return self._empty_round(
                mr, template, async_round=True, tenant=tenant,
                t_round=t_round, expected=expected,
            )
        n_now, p, dtype = self.store.meta(tenant)
        row_bytes = self._row_bytes(p, dtype)
        n_proj = max(expected, n_now, 1)
        chunk_rows = self._chunk_rows(n_proj, row_bytes)
        load = Workload(
            update_bytes=row_bytes, n_clients=n_proj,
            dtype_bytes=dtype.itemsize, params=p,
        )
        plan = self.planner.plan(
            load, fusion,
            warm_engines=self._warm_engines(
                n_proj, p, dtype, chunk_rows=chunk_rows,
                fusion=fusion, n_hint=n_proj,
            ),
        )
        engine = self._stream_engine(plan.engine)

        closed_at: Dict[str, float] = {}

        def should_close(count: int, _stream_waited: float) -> bool:
            # waited is measured from ROUND start: the pre-first-arrival
            # poll above is part of the same monitor window
            waited = monitor.clock() - t_round
            done = monitor.should_close(count, waited)
            if done and "waited" not in closed_at:
                closed_at["count"] = count
                closed_at["waited"] = waited
            return done

        gamma = self.staleness_discount
        # carry/ages are per-tenant entries, but the MAPS are shared
        # across tenant round threads — reads take the state lock (the
        # tenant round lock serializes same-tenant rounds, so the
        # snapshot stays valid for the whole round)
        with self._state_lock:
            ages = self._stale_ages.get(tenant, {})
            carry = self._carry.get(tenant)
        folded: List[str] = []
        folded_versions: Dict[str, int] = {}
        io_stats: Dict[str, float] = {}

        def blocks():
            for block, w, ids in self.store.iter_arrivals(
                chunk_rows, should_close,
                poll_interval=monitor.poll_interval,
                clock=monitor.clock, sleep=monitor.sleep,
                versions_out=folded_versions, stats_out=io_stats,
                tenant=tenant,
            ):
                folded.extend(ids)
                if gamma is not None and ages:
                    scale = np.asarray(
                        [gamma ** ages.get(cid, 0) for cid in ids],
                        np.float32,
                    )
                    yield block, w, scale
                else:
                    yield block, w

        init = None
        if gamma is not None and carry is not None:
            init = fusion.discount_state(carry, gamma)
        t0 = time.perf_counter()
        fused, srep = engine.fuse_stream(
            fusion, blocks(), init=init, chunk_rows=chunk_rows,
            device_sem=self.device_sem, n_hint=n_proj,
        )
        dt = time.perf_counter() - t0

        # arrival snapshot BEFORE the consume drops timestamps — the
        # adaptive controller's training signal for this tenant's curve
        arrivals = self.store.arrival_times(tenant)
        # queue semantics: what we folded is consumed from the tenant's
        # partition (version-checked — an update re-written mid-round
        # survives for the next round); what raced past the close stays,
        # one round staler
        self.store.remove(folded, versions=folded_versions, tenant=tenant)
        # compute the next-age map BEFORE taking the state lock:
        # client_ids() takes the STORE lock, and the declared order
        # (state inner-most) forbids acquiring it under _state_lock
        next_ages = {
            cid: ages.get(cid, 0) + 1
            for cid in self.store.client_ids(tenant)
        }
        with self._state_lock:
            if gamma is not None:
                self._carry[tenant] = srep.acc_state
            self._stale_ages[tenant] = next_ages

        overlap = closed_at.get("waited", 0.0)
        mr = monitor.result(
            int(closed_at.get("count", len(folded))), overlap,
        )
        # the engine's ingest clock times next(it), which for the arrival
        # stream is dominated by the IDLE poll wait; report actual block
        # staging I/O instead so phases stay comparable across round modes
        # (the wait itself is the `overlap` phase / overlap_seconds)
        phase = {
            "ingest": io_stats.get("load_seconds", 0.0),
            "compile": srep.compile_seconds,
            "compute": srep.compute_seconds,
            "overlap": overlap,
        }
        return self._finish(
            fused, template, plan, srep.n_rows, load, dt, mr,
            expected, True, phase,
            overlap_seconds=overlap, async_round=True,
            tenant=tenant, policy=policy, t_round=t_round_store,
            expected=expected, arrivals=arrivals,
            ingest_bytes=srep.ingest_bytes, fusion=fusion,
        )

    def _empty_round(
        self, monitor_result: MonitorResult, template, async_round=False,
        tenant: str = DEFAULT_TENANT, t_round: Optional[float] = None,
        expected: Optional[int] = None,
    ) -> Tuple[None, RoundReport]:
        """Timed-out round with nothing to fuse: a structured report (the
        caller keeps the previous model) instead of a LookupError."""
        if self.controller is not None and expected:
            # an empty window is evidence too: the tenant's attainable
            # fraction decays toward zero
            self.controller.observe_round(tenant, [], expected)
        plan = Plan(
            engine="local", workload_class=WorkloadClass.VMEM_RESIDENT,
            est_seconds=0.0, breakdown={}, n_devices=1, feasible=True,
            reason="empty round: monitor timed out with no arrivals",
        )
        report = RoundReport(
            plan=plan, n_clients=0, update_bytes=0, fuse_seconds=0.0,
            monitor=monitor_result, route_next_to_store=True,
            streamed=False, phase_seconds={}, async_round=async_round,
            empty=True, tenant=tenant,
            store_stats=self.store.stats_for(tenant),
        )
        with self._state_lock:
            self.history.append(report)
            if monitor_result is not None:
                self._last_wait[tenant] = monitor_result.waited
        return None, report

    # -- round epilogue -------------------------------------------------------
    def _finish(
        self, fused, template, plan, n, load, dt, monitor_result,
        expected_clients, streamed, phase,
        overlap_seconds: float = 0.0, async_round: bool = False,
        tenant: str = DEFAULT_TENANT, policy: Optional[ClosePolicy] = None,
        t_round: Optional[float] = None, expected: Optional[int] = None,
        arrivals: Optional[Dict[str, float]] = None,
        ingest_bytes: int = 0,
        fusion: Optional[FusionAlgorithm] = None,
        notes: Tuple[str, ...] = (),
    ):
        fusion = fusion if fusion is not None else self.fusion
        # §III-D3 seamless transition: if next round's projected load would
        # overflow a single chip (even the streamed local path then needs
        # the store as its backing set), tell clients to write to the store.
        # replace(), not a fresh Workload: the projected load must keep
        # the round's REAL payload dtype/size — rebuilding with the
        # default dtype_bytes=4 made int8 rounds project 4x the params
        # they actually carry
        next_load = dataclasses.replace(
            load, n_clients=max(n, expected_clients or n),
        )
        route_next = (
            classify(next_load, self.hw) is WorkloadClass.DISTRIBUTED
            or self.planner.plan(next_load, fusion).engine != "local"
        )

        # feed the round's observed arrival offsets back into the
        # tenant's learned curve (store-gated rounds only)
        if self.controller is not None and arrivals is not None \
                and t_round is not None:
            offsets = [max(t - t_round, 0.0) for t in arrivals.values()]
            self.controller.observe_round(
                tenant, offsets, expected or n, est_seconds=dt,
            )

        report = RoundReport(
            plan=plan,
            n_clients=n,
            update_bytes=load.update_bytes,
            fuse_seconds=dt,
            monitor=monitor_result,
            route_next_to_store=route_next,
            streamed=streamed,
            phase_seconds=phase,
            overlap_seconds=overlap_seconds,
            async_round=async_round,
            tenant=tenant,
            close_policy=policy,
            store_stats=self.store.stats_for(tenant),
            bytes_ingested=ingest_bytes,
            notes=notes,
        )
        with self._state_lock:
            self.history.append(report)
            if monitor_result is not None:
                self._last_wait[tenant] = monitor_result.waited

        if template is not None:
            return flat_vector_to_tree(jnp.asarray(fused), template), report
        return fused, report

    # -- controller persistence (restart continuity) --------------------------
    def save_controller(self, path: str) -> str:
        """Persist the adaptive controller's learned state (per-tenant
        arrival curves + cross-tenant prior) as JSON at
        ``<path>.controller.json`` — pass the same ``path`` as the
        model checkpoint (``repro.checkpoint.save_pytree``) so the
        learned gates travel with the model. Returns the written path.
        Raises ``ValueError`` on a non-adaptive service."""
        from repro.checkpoint import save_controller_state

        if self.controller is None:
            raise ValueError(
                "save_controller needs an adaptive service "
                "(AggregationService(adaptive=True))"
            )
        return save_controller_state(path, self.controller)

    def load_controller(self, path: str) -> None:
        """Restore controller state saved by ``save_controller`` — a
        restarted service resumes with its learned curves instead of
        re-learning from static-timeout rounds. Raises ``ValueError``
        on a non-adaptive service."""
        from repro.checkpoint import load_controller_state

        if self.controller is None:
            raise ValueError(
                "load_controller needs an adaptive service "
                "(AggregationService(adaptive=True))"
            )
        load_controller_state(path, self.controller)


class RoundScheduler:
    """Concurrent round execution for N tenants on ONE service — the
    paper's multi-application edge aggregator without the one-service-
    per-tenant workaround.

    The scheduler owns one daemon WORKER THREAD per tenant (created on
    first ``submit``; same-tenant rounds queue FIFO behind it, so the
    service's per-tenant round lock never blocks a worker — ordering is
    by construction). Rounds for different tenants genuinely overlap:
    each worker's monitor wait, host staging, and controller access run
    concurrently, while device execution is bounded by the service's
    ``device_concurrency`` semaphore (default 1 — on a small edge host
    the only thing worth overlapping is the waiting, which is exactly
    what the paper's concurrency claim needs).

    Starvation control is the UpdateStore's per-tenant quota
    (``store.set_quota(tenant, max_updates=..., max_bytes=...,
    policy="reject"|"evict")``): a noisy tenant saturates its own
    budget and its own worker, never another tenant's monitor or
    partition. Scheduling itself is fair in the trivial sense — every
    tenant has its own worker, so there is no shared run queue to
    starve; the shared resources (device semaphore, compile cache) are
    FIFO under lock contention.

    Use as a context manager::

        with RoundScheduler(service) as sched:
            futs = [sched.submit(t, from_store=True, async_round=True,
                                 expected_clients=48)
                    for t in ("appA", "appB", "appC")]
            results = [f.result() for f in futs]   # (fused, report)

    or one fan-out-and-wait cycle with ``run_round([...])``. Futures
    carry an ``aggregate`` failure as their exception; a scheduler
    shutdown drains queued work before the workers exit."""

    def __init__(self, service: AggregationService):
        self.service = service
        self._queues: Dict[str, "queue.Queue"] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self._lock = threading.Lock()
        self._closed = False

    def submit(
        self, tenant: str = DEFAULT_TENANT, **aggregate_kwargs
    ) -> "Future":
        """Enqueue one ``service.aggregate(tenant=..., **kwargs)`` round
        on the tenant's worker; returns a ``concurrent.futures.Future``
        resolving to ``(fused, RoundReport)``."""
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("RoundScheduler is shut down")
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = queue.Queue()
                t = threading.Thread(
                    target=self._worker, args=(q,),
                    name=f"round-scheduler:{tenant}", daemon=True,
                )
                self._threads[tenant] = t
                t.start()
            # enqueue under the lock: a put after shutdown()'s None
            # sentinel would land on a queue no worker reads and the
            # future would never resolve
            q.put((fut, tenant, aggregate_kwargs))
        return fut

    def run_round(
        self, tenants: Sequence[str], **aggregate_kwargs
    ) -> Dict[str, Tuple[PyTree, RoundReport]]:
        """One concurrent fan-out: submit a round for every tenant, wait
        for all, return ``{tenant: (fused, report)}``."""
        futs = {t: self.submit(t, **aggregate_kwargs) for t in tenants}
        return {t: f.result() for t, f in futs.items()}

    def _worker(self, q: "queue.Queue") -> None:
        while True:
            item = q.get()
            if item is None:
                return
            fut, tenant, kwargs = item
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                fut.set_result(
                    self.service.aggregate(tenant=tenant, **kwargs)
                )
            except BaseException as exc:
                fut.set_exception(exc)

    def tenants(self) -> List[str]:
        """Tenants with a live worker."""
        with self._lock:
            return sorted(self._threads)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting rounds; each worker drains its queue and
        exits. With ``wait`` (default) blocks until they have."""
        with self._lock:
            if self._closed:
                threads = list(self._threads.values())
            else:
                self._closed = True
                for q in self._queues.values():
                    q.put(None)
                threads = list(self._threads.values())
        if wait:
            for t in threads:
                t.join()

    def __enter__(self) -> "RoundScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


class FairRoundScheduler:
    """Waiting/running round admission for N tenants on ONE service —
    the sarathi-serve shape: submitted rounds join a per-tenant WAITING
    queue, a single admission loop moves them to RUNNING under a
    concurrency cap, picking the next tenant by weighted-fair virtual
    time with a capacity gate.

    Versus :class:`RoundScheduler` (one always-on worker per tenant,
    all submitted rounds run at once), this scheduler makes admission a
    DECISION:

      * ``max_running`` bounds rounds in flight — on an edge host the
        real bound is host staging memory and device time, not thread
        count;
      * tenant selection is weighted fair queuing: each tenant carries
        a virtual time advanced by ``1 / weight`` per admitted round,
        and the admission loop picks the eligible tenant with the
        smallest vtime (ties by name) — a tenant with weight 2 gets
        twice the round admissions of a weight-1 tenant under
        contention, and an idle tenant's first round is never starved
        behind a busy tenant's backlog (its vtime is clamped forward to
        the current minimum on arrival, the classic WFQ no-credit
        rule);
      * capacity awareness: a round whose projected host-staging
        footprint (2x streamed chunk, from the store partition's live
        ``meta`` — double-buffered blocks) does not fit
        ``capacity_bytes`` alongside the running rounds' footprints
        waits, EXCEPT when nothing is running (a too-big round must
        run alone rather than deadlock);
      * one round per tenant in flight: same-tenant submissions queue
        FIFO (the service's per-tenant round lock would serialize them
        anyway — keeping them waiting keeps their slot available for
        OTHER tenants: no head-of-line blocking).

    Use exactly like ``RoundScheduler``::

        with FairRoundScheduler(svc, max_running=2,
                                weights={"appA": 2.0}) as sched:
            futs = [sched.submit(t, from_store=True,
                                 expected_clients=48)
                    for t in tenants]
            results = [f.result() for f in futs]
    """

    def __init__(
        self,
        service: AggregationService,
        max_running: int = 2,
        weights: Optional[Dict[str, float]] = None,
        capacity_bytes: Optional[int] = None,
    ):
        if max_running < 1:
            raise ValueError("max_running must be >= 1")
        self.service = service
        self.max_running = int(max_running)
        self.capacity_bytes = capacity_bytes
        self._weights = dict(weights or {})
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._waiting: Dict[str, "queue.SimpleQueue"] = {}
        self._waiting_count: Dict[str, int] = {}
        self._running: Dict[str, int] = {}      # tenant -> footprint
        self._vtime: Dict[str, float] = {}
        self._closed = False
        self._drained = False
        self._admitted = 0
        self._admission_order: List[str] = []
        self._workers: List[threading.Thread] = []
        self._loop = threading.Thread(
            target=self._admission_loop, name="fair-scheduler",
            daemon=True,
        )
        self._loop.start()

    # -- submission ----------------------------------------------------------
    def submit(
        self, tenant: str = DEFAULT_TENANT, **aggregate_kwargs
    ) -> "Future":
        """Queue one round; returns a Future resolving to
        ``(fused, RoundReport)`` once the round is admitted AND run."""
        fut: Future = Future()
        with self._wake:
            if self._closed:
                raise RuntimeError("FairRoundScheduler is shut down")
            q = self._waiting.get(tenant)
            if q is None:
                q = self._waiting[tenant] = queue.SimpleQueue()
            q.put((fut, aggregate_kwargs))
            self._waiting_count[tenant] = (
                self._waiting_count.get(tenant, 0) + 1
            )
            self._wake.notify_all()
        return fut

    def run_round(
        self, tenants: Sequence[str], **aggregate_kwargs
    ) -> Dict[str, Tuple[PyTree, RoundReport]]:
        """One fair fan-out: submit a round per tenant, wait for all."""
        futs = {t: self.submit(t, **aggregate_kwargs) for t in tenants}
        return {t: f.result() for t, f in futs.items()}

    # -- admission -----------------------------------------------------------
    def _footprint(self, tenant: str) -> int:
        """Projected host-staging bytes for the tenant's next round:
        two streamed chunks (double buffering), sized from the LIVE
        store partition. An empty partition projects 0 — the round
        will gate on its monitor, not on staging memory."""
        store = getattr(self.service, "store", None)
        if store is None:
            return 0
        try:
            n, p, dtype = store.meta(tenant)
        except LookupError:
            return 0
        row = self.service._row_bytes(p, dtype)
        rows = self.service._chunk_rows(n, row)
        return 2 * rows * row

    def _eligible_locked(self) -> Optional[str]:
        """The weighted-fair pick among tenants with waiting rounds,
        honoring the running cap, one-in-flight-per-tenant, and the
        capacity gate. Caller holds ``self._lock``."""
        if len(self._running) >= self.max_running:
            return None
        used = sum(self._running.values())
        best: Optional[Tuple[float, str]] = None
        for tenant, count in self._waiting_count.items():
            if count <= 0 or tenant in self._running:
                continue
            vt = self._vtime.get(tenant, 0.0)
            if best is None or (vt, tenant) < best:
                # capacity gate: the footprint probe touches the store
                # index (cheap), so only probe the current best
                fp = self._footprint(tenant)
                if self.capacity_bytes is not None and self._running \
                        and used + fp > self.capacity_bytes:
                    continue
                best = (vt, tenant)
        return best[1] if best else None

    def _admission_loop(self) -> None:
        while True:
            with self._wake:
                tenant = self._eligible_locked()
                while tenant is None:
                    if self._closed and not any(
                        c > 0 for c in self._waiting_count.values()
                    ) and not self._running:
                        self._drained = True
                        self._wake.notify_all()
                        return
                    self._wake.wait(timeout=0.5)
                    tenant = self._eligible_locked()
                fut, kwargs = self._waiting[tenant].get_nowait()
                self._waiting_count[tenant] -= 1
                fp = self._footprint(tenant)
                self._running[tenant] = fp
                # WFQ no-credit rule: an idle tenant resumes at the
                # current virtual time, not at zero — it gets its fair
                # share from NOW, not a starvation-inducing backlog of
                # credit
                floor = min(
                    (self._vtime[t] for t in self._running
                     if t in self._vtime), default=0.0,
                )
                vt = max(self._vtime.get(tenant, 0.0), floor)
                weight = max(self._weights.get(tenant, 1.0), 1e-9)
                self._vtime[tenant] = vt + 1.0 / weight
                self._admitted += 1
                self._admission_order.append(tenant)
            worker = threading.Thread(
                target=self._run_one, args=(tenant, fut, kwargs),
                name=f"fair-round:{tenant}", daemon=True,
            )
            # track round workers so shutdown() can join them — a
            # drained queue only means each worker popped its tenant
            # from _running, not that the thread has exited
            with self._wake:
                self._workers = [
                    w for w in self._workers if w.is_alive()
                ]
                self._workers.append(worker)
            worker.start()

    def _run_one(self, tenant: str, fut: "Future", kwargs: dict) -> None:
        if not fut.set_running_or_notify_cancel():
            with self._wake:
                self._running.pop(tenant, None)
                self._wake.notify_all()
            return
        try:
            fut.set_result(
                self.service.aggregate(tenant=tenant, **kwargs)
            )
        except BaseException as exc:
            fut.set_exception(exc)
        finally:
            with self._wake:
                self._running.pop(tenant, None)
                self._wake.notify_all()

    # -- introspection / shutdown --------------------------------------------
    def running(self) -> List[str]:
        """Tenants with an admitted round in flight."""
        with self._lock:
            return sorted(self._running)

    def waiting(self) -> Dict[str, int]:
        """Waiting round count per tenant."""
        with self._lock:
            return {t: c for t, c in self._waiting_count.items() if c}

    def admission_order(self) -> List[str]:
        """Tenants in admission order (the fairness audit trail)."""
        with self._lock:
            return list(self._admission_order)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting submissions; drain waiting rounds, then stop
        the admission loop. ``wait`` blocks until drained."""
        with self._wake:
            self._closed = True
            self._wake.notify_all()
        if wait:
            with self._wake:
                while not self._drained:
                    self._wake.wait(timeout=0.5)
            self._loop.join(timeout=10.0)
            with self._wake:
                workers = list(self._workers)
                self._workers = []
            for worker in workers:
                worker.join(timeout=10.0)

    def __enter__(self) -> "FairRoundScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
