"""AggregationService — the paper's top-level contribution (Algorithm 1 +
§III-D): an adaptive, elastic aggregation facade that routes every round's
workload to the best engine and transitions seamlessly between them.

Round flow (mirrors Algorithm 1):
  1. S = w_s * n  -> classify + plan (planner.py's roofline cost model,
     plus a reuse term: engines holding a compiled executable for this
     round's shape bucket are costed below cold ones).
  2. small  -> single-chip engine (jnp baseline or fused Pallas path),
     updates land in memory exactly as IBMFL receives them over gRPC.
  3. large  -> clients were already redirected to the UpdateStore (the
     seamless-transition hook, §III-D3); monitor(T_h, timeout) waits for
     the straggler threshold; reducible fusions then STREAM (chunk, P)
     blocks off the store through one cached step executable — the dense
     (n, P) matrix never materializes on the host — while order-statistic
     fusions fall back to the dense read / distributed engine.
  4. The fused flat vector is unflattened back into the model pytree.

Convergence guarantee (paper §IV-C): every engine computes the *same*
fusion formula — tests/test_equivalence.py asserts allclose across
engines, which is the system's core invariant.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import DistributedEngine
from repro.core.fusion import FusionAlgorithm, get_fusion
from repro.core.local import LocalEngine
from repro.core.monitor import Monitor, MonitorResult
from repro.core.planner import Plan, Planner
from repro.core.store import UpdateStore
from repro.core.workload import Workload, WorkloadClass, classify
from repro.utils.mem import TPU_V5E, HardwareSpec
from repro.utils.pytree import flat_vector_to_tree, tree_to_flat_vector

PyTree = Any


@dataclasses.dataclass
class RoundReport:
    plan: Plan
    n_clients: int
    update_bytes: int
    fuse_seconds: float          # wall time of the fusion computation
    monitor: Optional[MonitorResult] = None
    route_next_to_store: bool = False
    streamed: bool = False       # True: chunked store pipeline (no dense n,P)
    # ingest (store -> host blocks) / compile (executable build; 0.0 on
    # warm rounds) / compute (device time) — the paper's Fig. 12 phases
    phase_seconds: Dict[str, float] = dataclasses.field(default_factory=dict)


class AggregationService:
    """Adaptive aggregation service over a (possibly trivial) mesh."""

    def __init__(
        self,
        fusion: FusionAlgorithm | str = "fedavg",
        mesh=None,
        hw: HardwareSpec = TPU_V5E,
        local_strategy: str = "pallas",
        store: Optional[UpdateStore] = None,
        threshold_frac: float = 0.8,
        monitor_timeout: float = 30.0,
        memory_cap_bytes: Optional[int] = None,
        stream_chunk_bytes: int = 64 << 20,
    ):
        self.fusion = (
            get_fusion(fusion) if isinstance(fusion, str) else fusion
        )
        self.mesh = mesh
        self.hw = hw
        self.store = store or UpdateStore()
        self.threshold_frac = threshold_frac
        self.monitor_timeout = monitor_timeout
        self.stream_chunk_bytes = stream_chunk_bytes
        self.memory_cap_bytes = memory_cap_bytes
        self.local = LocalEngine(
            strategy=local_strategy, memory_cap_bytes=memory_cap_bytes
        )
        self.distributed = (
            DistributedEngine(mesh=mesh) if mesh is not None else None
        )
        self.hierarchical = (
            DistributedEngine(mesh=mesh, hierarchical=True)
            if mesh is not None and "pod" in mesh.axis_names else None
        )
        n_dev = mesh.devices.size if mesh is not None else 1
        n_pods = mesh.shape.get("pod", 1) if mesh is not None else 1
        self.planner = Planner(hw=hw, n_devices=n_dev, n_pods=n_pods)
        self.history: List[RoundReport] = []

    # -- streaming knobs ------------------------------------------------------
    def _chunk_rows(self, n: int, row_bytes: int) -> int:
        """Rows per streamed block: half the memory cap (two blocks are
        resident under double buffering), else the chunk-size default."""
        budget = (
            self.memory_cap_bytes // 2
            if self.memory_cap_bytes is not None
            else self.stream_chunk_bytes
        )
        return max(1, min(n, int(budget // max(row_bytes, 1))))

    def _warm_engines(self, n: int, p: int, dtype, chunk_rows=None):
        warm = set()
        if chunk_rows is not None:
            if self.local.is_warm_stream(self.fusion, chunk_rows, p, dtype):
                warm.add("local")
        elif self.local.is_warm(self.fusion, n, p, dtype):
            warm.add("local")
        if self.distributed is not None and \
                self.distributed.is_warm(self.fusion, n, p, dtype):
            warm.add("distributed")
        if self.hierarchical is not None and \
                self.hierarchical.is_warm(self.fusion, n, p, dtype):
            warm.add("hierarchical")
        return warm

    # -- Algorithm 1 ----------------------------------------------------------
    def aggregate(
        self,
        updates: Optional[Sequence[PyTree]] = None,
        weights: Optional[Sequence[float]] = None,
        template: Optional[PyTree] = None,
        expected_clients: Optional[int] = None,
        from_store: bool = False,
    ) -> Tuple[PyTree, RoundReport]:
        """One aggregation round. Either ``updates`` (in-memory, the small
        path's arrival mode) or ``from_store=True`` (clients wrote to the
        UpdateStore; the monitor gates the round)."""
        monitor_result = None
        phase: Dict[str, float] = {}
        streamed = False

        if from_store:
            expected = expected_clients or self.store.count()
            monitor = Monitor(
                self.store,
                threshold=max(int(expected * self.threshold_frac), 1),
                timeout=self.monitor_timeout,
            )
            monitor_result = monitor.wait()
            n, p, dtype = self.store.meta()
            row_bytes = p * dtype.itemsize
            chunk_rows = self._chunk_rows(n, row_bytes)
            load = Workload(
                update_bytes=row_bytes, n_clients=n,
                dtype_bytes=dtype.itemsize,
            )
            can_stream = self.fusion.reducible
            plan = self.planner.plan(
                load, self.fusion,
                warm_engines=self._warm_engines(
                    n, p, dtype,
                    chunk_rows=chunk_rows if can_stream else None,
                ),
            )
            if plan.engine == "local" and can_stream:
                # zero-materialization pipeline: (chunk, P) blocks flow
                # from the store through one cached step executable
                t0 = time.perf_counter()
                fused, srep = self.local.fuse_stream(
                    self.fusion, self.store.iter_chunks(chunk_rows)
                )
                dt = time.perf_counter() - t0
                streamed = True
                phase = {
                    "ingest": srep.ingest_seconds,
                    "compile": srep.compile_seconds,
                    "compute": srep.compute_seconds,
                }
                return self._finish(
                    fused, template, plan, n, load, dt, monitor_result,
                    expected_clients, streamed, phase,
                )
            t0 = time.perf_counter()
            stacked, w = self.store.read_stacked()
            phase["ingest"] = time.perf_counter() - t0
        else:
            assert updates is not None and len(updates) > 0
            t0 = time.perf_counter()
            flat = [
                np.asarray(
                    u if getattr(u, "ndim", None) == 1
                    else tree_to_flat_vector(u)
                )
                for u in updates
            ]
            stacked = np.stack(flat)
            phase["ingest"] = time.perf_counter() - t0
            w = (
                np.asarray(weights, np.float32)
                if weights is not None
                else np.ones((len(flat),), np.float32)
            )

        # dense path (in-memory round, or store round that can't stream):
        # one plan against the materialized matrix
        n, p = stacked.shape
        load = Workload(
            update_bytes=p * stacked.dtype.itemsize, n_clients=n,
            dtype_bytes=stacked.dtype.itemsize,
        )
        plan = self.planner.plan(
            load, self.fusion,
            warm_engines=self._warm_engines(n, p, stacked.dtype),
        )

        t0 = time.perf_counter()
        if plan.engine == "local":
            fused = self.local.fuse(self.fusion, stacked, w)
            phase["compile"] = self.local.last_compile_seconds
        elif plan.engine == "hierarchical" and self.hierarchical is not None:
            fused = self.hierarchical.fuse(self.fusion, stacked, w)
        else:
            assert self.distributed is not None, (
                "planner chose the distributed engine but no mesh was given"
            )
            fused = self.distributed.fuse(self.fusion, stacked, w)
        fused = jax.block_until_ready(fused)
        dt = time.perf_counter() - t0
        phase["compute"] = dt - phase.get("compile", 0.0)
        return self._finish(
            fused, template, plan, n, load, dt, monitor_result,
            expected_clients, streamed, phase,
        )

    # -- round epilogue -------------------------------------------------------
    def _finish(
        self, fused, template, plan, n, load, dt, monitor_result,
        expected_clients, streamed, phase,
    ):
        # §III-D3 seamless transition: if next round's projected load would
        # overflow a single chip (even the streamed local path then needs
        # the store as its backing set), tell clients to write to the store.
        next_load = Workload(
            update_bytes=load.update_bytes,
            n_clients=max(n, expected_clients or n),
        )
        route_next = (
            classify(next_load, self.hw) is WorkloadClass.DISTRIBUTED
            or self.planner.plan(next_load, self.fusion).engine != "local"
        )

        report = RoundReport(
            plan=plan,
            n_clients=n,
            update_bytes=load.update_bytes,
            fuse_seconds=dt,
            monitor=monitor_result,
            route_next_to_store=route_next,
            streamed=streamed,
            phase_seconds=phase,
        )
        self.history.append(report)

        if template is not None:
            return flat_vector_to_tree(jnp.asarray(fused), template), report
        return fused, report
