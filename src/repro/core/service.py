"""AggregationService — the paper's top-level contribution (Algorithm 1 +
§III-D): an adaptive, elastic aggregation facade that routes every round's
workload to the best engine and transitions seamlessly between them.

Round flow (mirrors Algorithm 1):
  1. S = w_s * n  -> classify + plan (planner.py's roofline cost model).
  2. small  -> single-chip engine (jnp baseline or fused Pallas path),
     updates land in memory exactly as IBMFL receives them over gRPC.
  3. large  -> clients were already redirected to the UpdateStore (the
     seamless-transition hook, §III-D3); monitor(T_h, timeout) waits for
     the straggler threshold; the distributed engine map-reduces the
     store's shards over the mesh.
  4. The fused flat vector is unflattened back into the model pytree.

Convergence guarantee (paper §IV-C): every engine computes the *same*
fusion formula — tests/test_equivalence.py asserts allclose across
engines, which is the system's core invariant.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import DistributedEngine
from repro.core.fusion import FusionAlgorithm, get_fusion
from repro.core.local import LocalEngine
from repro.core.monitor import Monitor, MonitorResult
from repro.core.planner import Plan, Planner
from repro.core.store import UpdateStore
from repro.core.workload import Workload, WorkloadClass
from repro.utils.mem import TPU_V5E, HardwareSpec
from repro.utils.pytree import flat_vector_to_tree, tree_to_flat_vector

PyTree = Any


@dataclasses.dataclass
class RoundReport:
    plan: Plan
    n_clients: int
    update_bytes: int
    fuse_seconds: float          # wall time of the fusion computation
    monitor: Optional[MonitorResult] = None
    route_next_to_store: bool = False


class AggregationService:
    """Adaptive aggregation service over a (possibly trivial) mesh."""

    def __init__(
        self,
        fusion: FusionAlgorithm | str = "fedavg",
        mesh=None,
        hw: HardwareSpec = TPU_V5E,
        local_strategy: str = "pallas",
        store: Optional[UpdateStore] = None,
        threshold_frac: float = 0.8,
        monitor_timeout: float = 30.0,
        memory_cap_bytes: Optional[int] = None,
    ):
        self.fusion = (
            get_fusion(fusion) if isinstance(fusion, str) else fusion
        )
        self.mesh = mesh
        self.hw = hw
        self.store = store or UpdateStore()
        self.threshold_frac = threshold_frac
        self.monitor_timeout = monitor_timeout
        self.local = LocalEngine(
            strategy=local_strategy, memory_cap_bytes=memory_cap_bytes
        )
        self.distributed = (
            DistributedEngine(mesh=mesh) if mesh is not None else None
        )
        self.hierarchical = (
            DistributedEngine(mesh=mesh, hierarchical=True)
            if mesh is not None and "pod" in mesh.axis_names else None
        )
        n_dev = mesh.devices.size if mesh is not None else 1
        n_pods = mesh.shape.get("pod", 1) if mesh is not None else 1
        self.planner = Planner(hw=hw, n_devices=n_dev, n_pods=n_pods)
        self.history: List[RoundReport] = []

    # -- Algorithm 1 ----------------------------------------------------------
    def aggregate(
        self,
        updates: Optional[Sequence[PyTree]] = None,
        weights: Optional[Sequence[float]] = None,
        template: Optional[PyTree] = None,
        expected_clients: Optional[int] = None,
        from_store: bool = False,
    ) -> Tuple[PyTree, RoundReport]:
        """One aggregation round. Either ``updates`` (in-memory, the small
        path's arrival mode) or ``from_store=True`` (clients wrote to the
        UpdateStore; the monitor gates the round)."""
        monitor_result = None
        if from_store:
            expected = expected_clients or self.store.count()
            monitor = Monitor(
                self.store,
                threshold=max(int(expected * self.threshold_frac), 1),
                timeout=self.monitor_timeout,
            )
            monitor_result = monitor.wait()
            stacked, w = self.store.read_stacked()
        else:
            assert updates is not None and len(updates) > 0
            flat = [
                np.asarray(
                    u if getattr(u, "ndim", None) == 1
                    else tree_to_flat_vector(u)
                )
                for u in updates
            ]
            stacked = np.stack(flat)
            w = (
                np.asarray(weights, np.float32)
                if weights is not None
                else np.ones((len(flat),), np.float32)
            )

        n, p = stacked.shape
        load = Workload(
            update_bytes=p * stacked.dtype.itemsize, n_clients=n,
            dtype_bytes=stacked.dtype.itemsize,
        )
        plan = self.planner.plan(load, self.fusion)

        t0 = time.perf_counter()
        if plan.engine == "local":
            fused = self.local.fuse(self.fusion, stacked, w)
        elif plan.engine == "hierarchical" and self.hierarchical is not None:
            fused = self.hierarchical.fuse(self.fusion, stacked, w)
        else:
            assert self.distributed is not None, (
                "planner chose the distributed engine but no mesh was given"
            )
            fused = self.distributed.fuse(self.fusion, stacked, w)
        fused = jax.block_until_ready(fused)
        dt = time.perf_counter() - t0

        # §III-D3 seamless transition: if next round's projected load would
        # overflow a single chip (even the streamed local path then needs
        # the store as its backing set), tell clients to write to the store.
        next_load = Workload(
            update_bytes=load.update_bytes,
            n_clients=max(n, expected_clients or n),
        )
        from repro.core.workload import classify

        route_next = (
            classify(next_load, self.hw) is WorkloadClass.DISTRIBUTED
            or self.planner.plan(next_load, self.fusion).engine != "local"
        )

        report = RoundReport(
            plan=plan,
            n_clients=n,
            update_bytes=load.update_bytes,
            fuse_seconds=dt,
            monitor=monitor_result,
            route_next_to_store=route_next,
        )
        self.history.append(report)

        if template is not None:
            return flat_vector_to_tree(jnp.asarray(fused), template), report
        return fused, report
