"""Adaptive aggregation controller — the paper's headline claim made
real: "the first adaptive FL aggregator at the Edge, enabling users to
manage the cost and efficiency trade-off" (arXiv:2204.07767, §V).

The static gate (PR 2) closes a round at a fixed ``threshold_frac`` of
expected clients or a fixed timeout. That wastes wall-clock whenever the
observed arrival behavior diverges from the deadline: a fleet whose
stragglers reliably land at 1.2 s idles out a 30 s timeout the first
time two clients drop; a bursty fleet that fully arrives at 0.3 s still
pays the threshold poll cadence. This module LEARNS the arrival curve
and re-derives the gate every round:

  ``ArrivalModel``       per-tenant exponentially-weighted empirical
                         quantile curve of arrival offsets (seconds from
                         round start to each client's store write), with
                         censoring: fractions that did not arrive within
                         a round's window stay unknown rather than
                         polluting the curve, an EW *attainable
                         fraction* tracks client drop-out, and an EW
                         *drift* score tracks how fast the curve itself
                         is moving round-over-round.
  ``AdaptiveController`` owns one model per tenant PLUS a cross-tenant
                         prior (the pooled curve cold-start tenants
                         borrow until they have their own mass), turns
                         the selected curve into a ``ClosePolicy`` by
                         minimizing the planner's cost-vs-staleness
                         objective (``Planner.round_objective``) over a
                         fraction grid — widening the learned deadline
                         while the tenant's drift score says arrival
                         behavior is shifting faster than the EW window
                         tracks — and persists across rounds (and — via
                         ``state_dict`` — across aggregator restarts;
                         ``repro.checkpoint.save_controller_state``
                         writes it next to model checkpoints).
  ``ClosePolicy``        the pluggable gate predicate ``Monitor``
                         accepts: close at a learned threshold count OR
                         a learned deadline, whichever first.

The user knob is ``cost_bias`` in [0, 1]: 0 optimizes round wall-clock
alone (cost — close as soon as the marginal straggler is not worth the
wait), 1 optimizes update inclusion alone (efficiency — wait for every
client the curve says will come). 0.5 balances them. The controller
never waits past the static timeout: the learned deadline is capped, so
a fleet whose behavior shifts degrades to the static gate, not worse.
A shift the EW window cannot catch at all — drift saturated for
``rewarm_patience`` consecutive rounds — triggers RE-WARMUP: one forced
static round (``ClosePolicy.source == "rewarm"``) with the tenant's
curve reset, so the gate re-learns the new regime instead of widening a
stale deadline forever.

The controller is THREAD-SAFE: one instance serves every tenant's
concurrent rounds (the RoundScheduler's workers call ``policy`` /
``observe_round`` from per-tenant threads), so all public entry points
serialize on an internal lock — model blends and policy derivation are
numpy state mutations that must not interleave.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.planner import Planner


@dataclasses.dataclass
class ClosePolicy:
    """A concrete round-close gate: close once ``threshold`` updates
    have landed OR ``deadline`` seconds have elapsed. Callable with the
    ``(count, waited)`` signature ``Monitor`` and
    ``UpdateStore.iter_arrivals`` expect, so it plugs into either."""

    threshold: int          # arrival count that closes the gate
    deadline: float         # seconds after which the gate closes anyway
    threshold_frac: float   # threshold / expected (for reporting)
    expected_wait: float    # learned t(threshold_frac); deadline basis
    # "static" — the configured threshold_frac/timeout gate;
    # "learned" — derived from this tenant's own arrival curve;
    # "prior"  — derived from the cross-tenant prior curve (cold-start
    #            tenant borrowing pooled mass until it has its own);
    # "rewarm" — the static gate FORCED for one round after the
    #            tenant's drift stayed saturated (the curve was reset
    #            and re-learns from this round's arrivals)
    source: str = "static"

    def __call__(self, count: int, waited: float) -> bool:
        return count >= self.threshold or waited >= self.deadline


class ArrivalModel:
    """Exponentially-weighted empirical quantile curve of one tenant's
    arrival offsets.

    ``observe(offsets, expected)`` folds one round's arrival times
    (seconds since round start, one per client that landed) into the
    curve: quantile k is the offset by which fraction ``fracs[k]`` of
    the EXPECTED fleet had arrived. Fractions the round never reached
    (stragglers that missed the window, dropped clients) are censored —
    the stored quantile keeps its previous estimate and the EW
    ``attainable`` fraction decays instead, so the policy stops aiming
    at fractions the fleet no longer delivers.

    ``drift`` is an EW score of how much the freshly observed quantiles
    disagree with the stored curve (relative error over the fractions
    both reached, capped at 1.0): ~0 for a fleet in steady state, large
    while arrival behavior is shifting faster than the EW window has
    caught up. The controller widens the learned deadline while drift
    is high, so a regime change degrades toward the static timeout
    instead of closing rounds against a stale curve.

    ``ema`` is the weight of the NEWEST round (0.5 adapts within ~2
    rounds; lower is smoother).
    """

    # relative-error floor (seconds): offsets below this are all jitter
    _DRIFT_DENOM_FLOOR = 1e-2

    def __init__(self, n_quantiles: int = 20, ema: float = 0.5):
        if not 0 < ema <= 1:
            raise ValueError("ema must be in (0, 1]")
        self.fracs = np.arange(1, n_quantiles + 1) / n_quantiles
        self.quantiles = np.full(n_quantiles, np.nan)
        self.attainable: Optional[float] = None
        # the exact attainable tail — EW of the LAST arrival's offset —
        # so the policy can aim at "everyone who actually comes" even
        # when that fraction falls between grid points
        self.tail_wait: Optional[float] = None
        # EW round-over-round curve disagreement (None until two rounds
        # have reached at least one common fraction)
        self.drift: Optional[float] = None
        self.ema = ema
        self.rounds = 0

    def observe(self, offsets: Sequence[float], expected: int) -> None:
        arr = np.sort(np.asarray(list(offsets), np.float64))
        expected = max(int(expected), len(arr), 1)
        fresh = np.full_like(self.quantiles, np.nan)
        for k, f in enumerate(self.fracs):
            need = max(int(math.ceil(f * expected)), 1)
            if need <= len(arr):
                fresh[k] = max(arr[need - 1], 0.0)
        a = self.ema
        # drift BEFORE blending: how far did this round land from the
        # curve we believed? Only fractions observed on both sides count
        # (censored tails are the attainable fraction's business, not
        # drift's — permanent drop-out must not read as endless drift).
        both = ~np.isnan(fresh) & ~np.isnan(self.quantiles)
        if both.any():
            rel = np.abs(fresh[both] - self.quantiles[both]) / np.maximum(
                np.abs(self.quantiles[both]), self._DRIFT_DENOM_FLOOR
            )
            shift = float(np.minimum(rel, 1.0).mean())
            self.drift = (
                shift if self.drift is None
                else (1 - a) * self.drift + a * shift
            )
        keep = np.isnan(fresh)
        seed = np.isnan(self.quantiles)
        blended = (1 - a) * self.quantiles + a * fresh
        self.quantiles = np.where(
            keep, self.quantiles, np.where(seed, fresh, blended)
        )
        arrived_frac = len(arr) / expected
        self.attainable = (
            arrived_frac if self.attainable is None
            else (1 - a) * self.attainable + a * arrived_frac
        )
        if len(arr):
            tail = max(float(arr[-1]), 0.0)
            self.tail_wait = (
                tail if self.tail_wait is None
                else (1 - a) * self.tail_wait + a * tail
            )
        self.rounds += 1

    def wait_for(self, frac: float) -> float:
        """Learned seconds from round start until ``frac`` of the fleet
        has arrived; ``inf`` for fractions the curve has never seen."""
        finite = ~np.isnan(self.quantiles)
        if not finite.any() or frac > self.fracs[finite].max():
            return math.inf
        return float(
            np.interp(frac, self.fracs[finite], self.quantiles[finite])
        )

    # -- restart persistence -------------------------------------------------
    def state_dict(self) -> Dict:
        return {
            "fracs": self.fracs.tolist(),
            "quantiles": [
                None if np.isnan(q) else float(q) for q in self.quantiles
            ],
            "attainable": self.attainable,
            "tail_wait": self.tail_wait,
            "drift": self.drift,
            "ema": self.ema,
            "rounds": self.rounds,
        }

    @classmethod
    def from_state_dict(cls, state: Dict) -> "ArrivalModel":
        m = cls(n_quantiles=len(state["fracs"]), ema=state["ema"])
        m.fracs = np.asarray(state["fracs"], np.float64)
        m.quantiles = np.asarray(
            [np.nan if q is None else q for q in state["quantiles"]],
            np.float64,
        )
        m.attainable = state["attainable"]
        m.tail_wait = state.get("tail_wait")
        m.drift = state.get("drift")
        m.rounds = int(state["rounds"])
        return m


class AdaptiveController:
    """Per-tenant round-close policy learner (Algorithm 1, made
    adaptive).

    Lifecycle per round, per tenant::

        pol = controller.policy(tenant, expected)   # before the monitor
        ... run the round with pol as the gate ...
        controller.observe_round(tenant, offsets, expected,
                                 est_seconds=report.fuse_seconds)

    ``policy`` selects the curve to derive the gate from:

      * the tenant's OWN model once it has ``warmup_rounds``
        observations (``source="learned"``);
      * else the cross-tenant PRIOR — every observed round of every
        tenant also folds into one pooled curve, so a cold-start tenant
        borrows the fleet-wide arrival behavior instead of burning
        static timeouts while its own curve warms up
        (``source="prior"``);
      * else the STATIC gate (``threshold_frac`` / ``timeout``, exactly
        PR 2's behavior; also the fallback whenever a curve yields no
        finite candidate).

    The selected curve is minimized against
    ``Planner.round_objective(wait, inclusion, cost_bias)`` over its
    fraction grid and emitted as a learned threshold/deadline. The
    deadline is ``deadline_slack * t(f*) * widen + deadline_margin``
    capped at the static ``timeout`` — the controller can only ever
    close EARLIER than the static gate's worst case, never later —
    where ``widen >= 1`` grows with the model's drift score
    (``1 + drift_gain * max(drift - drift_tolerance, 0)``): while
    arrival behavior is shifting faster than the EW window tracks, the
    deadline backstop loosens toward the static timeout instead of
    cutting off a fleet the stale curve mispredicts.

    ``est_seconds`` (the tenant's observed fuse wall) enters the
    objective through ``max(wait, est)``: waiting for stragglers is free
    while the engine is still folding the updates already present.
    """

    def __init__(
        self,
        cost_bias: float = 0.5,
        threshold_frac: float = 0.8,
        timeout: float = 30.0,
        planner: Optional[Planner] = None,
        ema: float = 0.5,
        n_quantiles: int = 20,
        warmup_rounds: int = 1,
        deadline_slack: float = 1.25,
        deadline_margin: float = 0.25,
        drift_tolerance: float = 0.25,
        drift_gain: float = 4.0,
        rewarm_drift: float = 0.75,
        rewarm_patience: int = 3,
    ):
        if not 0 <= cost_bias <= 1:
            raise ValueError("cost_bias must be in [0, 1]")
        self.cost_bias = cost_bias
        self.threshold_frac = threshold_frac
        self.timeout = timeout
        self.planner = planner or Planner()
        self.ema = ema
        self.n_quantiles = n_quantiles
        self.warmup_rounds = warmup_rounds
        self.deadline_slack = deadline_slack
        self.deadline_margin = deadline_margin
        # drift below the tolerance is steady-state jitter; above it the
        # deadline widens by drift_gain per unit of excess drift
        self.drift_tolerance = drift_tolerance
        self.drift_gain = drift_gain
        # re-warmup: drift at or above rewarm_drift for rewarm_patience
        # CONSECUTIVE rounds means the EW curve is chasing a regime it
        # cannot catch — widening the deadline forever is strictly worse
        # than re-learning, so the next policy() forces ONE static-gated
        # round (source="rewarm") and resets the tenant's curve
        self.rewarm_drift = rewarm_drift
        self.rewarm_patience = max(int(rewarm_patience), 1)
        self._models: Dict[str, ArrivalModel] = {}  # guarded-by: _lock
        self._est_seconds: Dict[str, float] = {}  # guarded-by: _lock
        self._drift_sat: Dict[str, int] = {}  # guarded-by: _lock -- consecutive saturated rounds
        self._rewarm_pending: set = set()  # guarded-by: _lock
        # tenants re-learning after a rewarm reset: they skip the prior
        # borrow (it may carry the stale regime they just abandoned)
        # until their fresh curve reaches warmup
        self._rewarmed: set = set()  # guarded-by: _lock
        # the cross-tenant prior: every tenant's rounds pool here, and
        # tenants without their own mass borrow it (cold-start transfer)
        self._prior = ArrivalModel(n_quantiles=n_quantiles, ema=ema)  # guarded-by: _lock
        self._prior_est: Optional[float] = None  # guarded-by: _lock
        # one controller serves every tenant's concurrent rounds: model
        # mutation (numpy EW blends) and policy derivation are not
        # atomic, so all public entry points serialize here. RLock —
        # policy() consults state_dict-free internals re-entrantly.
        self._lock = threading.RLock()

    # -- learning ------------------------------------------------------------
    def observe_round(
        self,
        tenant: str,
        offsets: Sequence[float],
        expected: int,
        est_seconds: Optional[float] = None,
    ) -> None:
        """Fold one closed round's arrival offsets (seconds from round
        start per landed client) into the tenant's curve AND the
        cross-tenant prior (the pooled curve cold-start tenants
        borrow). An EMPTY round is evidence for the tenant's own curve
        (its attainable fraction decays) but is kept OUT of the prior:
        one dead tenant's fleet must not drag every cold-start tenant's
        borrowed threshold toward zero."""
        offsets = list(offsets)
        with self._lock:
            model = self._models.get(tenant)
            if model is None:
                model = self._models[tenant] = ArrivalModel(
                    n_quantiles=self.n_quantiles, ema=self.ema
                )
            model.observe(offsets, expected)
            # drift-saturation bookkeeping for the re-warmup trigger
            if model.drift is not None and \
                    model.drift >= self.rewarm_drift:
                sat = self._drift_sat.get(tenant, 0) + 1
                self._drift_sat[tenant] = sat
                if sat >= self.rewarm_patience:
                    self._rewarm_pending.add(tenant)
                    self._drift_sat[tenant] = 0
            else:
                self._drift_sat[tenant] = 0
            if offsets:
                self._prior.observe(offsets, expected)
            if est_seconds is not None:
                prev = self._est_seconds.get(tenant)
                self._est_seconds[tenant] = (
                    est_seconds if prev is None
                    else (1 - self.ema) * prev + self.ema * est_seconds
                )
                self._prior_est = (
                    est_seconds if self._prior_est is None
                    else (1 - self.ema) * self._prior_est
                    + self.ema * est_seconds
                )

    def model(self, tenant: str) -> Optional[ArrivalModel]:
        """The tenant's own arrival curve (None before its first
        observed round)."""
        with self._lock:
            return self._models.get(tenant)

    def prior_model(self) -> ArrivalModel:
        """The cross-tenant prior curve (pooled over every tenant's
        observed rounds)."""
        with self._lock:
            return self._prior

    # -- policy --------------------------------------------------------------
    def static_policy(self, expected: int) -> ClosePolicy:
        """The configured static gate for an ``expected``-client round —
        what ``policy`` falls back to before any curve has mass."""
        return ClosePolicy(
            threshold=max(int(expected * self.threshold_frac), 1),
            deadline=self.timeout,
            threshold_frac=self.threshold_frac,
            expected_wait=self.timeout,
            source="static",
        )

    def policy(self, tenant: str, expected: int) -> ClosePolicy:
        """The gate for the tenant's next round: its own learned curve
        once warmed up, the cross-tenant prior while cold, the static
        gate before anything has mass — and, after the tenant's drift
        stayed saturated for ``rewarm_patience`` consecutive rounds,
        ONE forced static round (``source="rewarm"``) with the EW curve
        reset, so the tenant re-learns the new regime instead of
        widening a stale deadline forever."""
        if expected <= 0:
            return self.static_policy(1)
        with self._lock:
            if tenant in self._rewarm_pending:
                self._rewarm_pending.discard(tenant)
                # reset the EW curve: the saturated drift said it no
                # longer describes the fleet. The static round observed
                # next seeds the fresh model (cold-start borrows are
                # skipped on purpose — the prior may carry the same
                # stale regime this tenant just abandoned).
                self._models[tenant] = ArrivalModel(
                    n_quantiles=self.n_quantiles, ema=self.ema
                )
                self._drift_sat[tenant] = 0
                self._rewarmed.add(tenant)
                pol = self.static_policy(expected)
                return dataclasses.replace(pol, source="rewarm")
            model = self._models.get(tenant)
            if model is not None and model.rounds >= self.warmup_rounds:
                self._rewarmed.discard(tenant)
                return self._derive(
                    model, expected, self._est_seconds.get(tenant, 0.0),
                    source="learned",
                )
            if self._prior.rounds >= self.warmup_rounds and \
                    tenant not in self._rewarmed:
                return self._derive(
                    self._prior, expected,
                    self._est_seconds.get(tenant, self._prior_est or 0.0),
                    source="prior",
                )
            return self.static_policy(expected)

    def _derive(
        self, model: ArrivalModel, expected: int, est: float, source: str
    ) -> ClosePolicy:
        """Minimize the planner objective over ``model``'s curve and
        emit the close gate (threshold count + drift-widened deadline
        backstop, capped at the static timeout)."""
        attainable = model.attainable if model.attainable is not None \
            else 1.0
        candidates = []
        for f in model.fracs:
            # a small margin keeps a fraction reachable through EW noise
            if f > min(attainable * 1.02, 1.0):
                break
            wait = model.wait_for(float(f))
            if not math.isfinite(wait):
                break
            candidates.append((float(f), wait))
        if model.tail_wait is not None:
            # the exact attainable fleet ("everyone who actually comes")
            # — the grid rounds this fraction away, so offer it directly
            candidates.append(
                (min(attainable, 1.0), float(model.tail_wait))
            )
        # ascending f, so the <= tie-break below resolves toward the
        # HIGHER-inclusion candidate (the tail candidate can fall
        # between grid points)
        candidates.sort()
        best_f, best_wait, best_j = None, None, math.inf
        for f, wait in candidates:
            j = self.planner.round_objective(
                expected_wait=wait,
                inclusion=f,
                cost_bias=self.cost_bias,
                horizon=self.timeout,
                est_seconds=est,
            )
            # <= so ties resolve toward higher inclusion
            if j <= best_j:
                best_f, best_wait, best_j = f, wait, j
        if best_f is None:
            return self.static_policy(expected)
        # slack + a fixed margin: the threshold closes the common path,
        # the deadline is a jitter-tolerant backstop — widened while the
        # curve is drifting, never past the static timeout
        widen = 1.0 + self.drift_gain * max(
            (model.drift or 0.0) - self.drift_tolerance, 0.0
        )
        deadline = min(
            self.deadline_slack * best_wait * widen + self.deadline_margin,
            self.timeout,
        )
        return ClosePolicy(
            threshold=max(int(math.ceil(best_f * expected)), 1),
            deadline=deadline,
            threshold_frac=best_f,
            expected_wait=best_wait,
            source=source,
        )

    # -- restart persistence -------------------------------------------------
    def state_dict(self) -> Dict:
        """JSON-able controller state (per-tenant curves, the
        cross-tenant prior, and fuse-wall estimates) so an aggregator
        restart resumes learned, not cold.
        ``repro.checkpoint.save_controller_state`` persists this next to
        model checkpoints; ``AggregationService.save_controller`` /
        ``load_controller`` are the service-level hooks."""
        with self._lock:
            return {
                "models": {
                    t: m.state_dict() for t, m in self._models.items()
                },
                "est_seconds": dict(self._est_seconds),
                "prior": self._prior.state_dict(),
                "prior_est": self._prior_est,
                "drift_sat": dict(self._drift_sat),
                "rewarm_pending": sorted(self._rewarm_pending),
                "rewarmed": sorted(self._rewarmed),
            }

    def load_state_dict(self, state: Dict) -> None:
        """Restore ``state_dict`` output (older checkpoints without a
        prior or re-warmup section restore those parts fresh)."""
        with self._lock:
            self._models = {
                t: ArrivalModel.from_state_dict(s)
                for t, s in state.get("models", {}).items()
            }
            self._est_seconds = dict(state.get("est_seconds", {}))
            prior = state.get("prior")
            self._prior = (
                ArrivalModel.from_state_dict(prior) if prior
                else ArrivalModel(
                    n_quantiles=self.n_quantiles, ema=self.ema
                )
            )
            self._prior_est = state.get("prior_est")
            self._drift_sat = dict(state.get("drift_sat", {}))
            self._rewarm_pending = set(state.get("rewarm_pending", []))
            self._rewarmed = set(state.get("rewarmed", []))

    def tenants(self) -> List[str]:
        """Tenants with at least one observed round."""
        with self._lock:
            return sorted(self._models)

    def snapshot(self, tenant: str) -> Dict:
        """One consistent trajectory row (soak benches, monitoring):
        the tenant's curve state under a single lock hold — reading
        ``model(t).drift`` / rewarm flags piecemeal can interleave
        with a concurrent ``observe_round``."""
        with self._lock:
            m = self._models.get(tenant)
            return {
                "tenant": tenant,
                "rounds": 0 if m is None else m.rounds,
                "drift": None if m is None else m.drift,
                "attainable": None if m is None else m.attainable,
                "tail_wait": None if m is None else m.tail_wait,
                "est_seconds": self._est_seconds.get(tenant),
                "drift_saturated": self._drift_sat.get(tenant, 0),
                "rewarm_pending": tenant in self._rewarm_pending,
                "rewarmed": tenant in self._rewarmed,
                "prior_rounds": self._prior.rounds,
            }
