"""Adaptive engine selection (paper Algorithm 1 + §III-D3 seamless
transition), upgraded from the paper's single threshold test to a
roofline-based cost model over the TPU memory hierarchy.

For a workload (w_s, n) and fusion algorithm, the planner estimates for
each candidate engine:

  ingest   — bytes into the aggregation substrate (store -> HBM or NIC ->
             HBM), divided by the available ingest bandwidth,
  compute  — fusion FLOPs / peak (negligible for averaging: ~2 flops/B,
             far below the HBM knee, so HBM time dominates — the same
             observation that makes the paper's NumPy single-core path
             memory-bound),
  memory   — one streaming pass over S = w_s * n at HBM bandwidth,
  collective — reduce/shuffle bytes over ICI links (distributed only).

and picks the cheapest FEASIBLE plan (single-chip plans are infeasible
once S exceeds HBM headroom — the paper's memory wall).

Beyond engine choice, the planner owns the round-TIMING economics:
``overlap_estimate`` / ``prefer_async`` cost the monitor-overlapped
round against the serialized one (``async_round="auto"``), and
``round_objective`` is the cost-vs-staleness trade-off the adaptive
controller minimizes when learning a round-close policy (the paper's
user-managed knob).
"""
from __future__ import annotations

import dataclasses
from typing import Collection, Dict, List, Tuple


from repro.core.fusion.base import FusionAlgorithm
from repro.core.workload import HBM_HEADROOM, Workload, WorkloadClass, classify
from repro.utils.mem import TPU_V5E, HardwareSpec


@dataclasses.dataclass(frozen=True)
class Plan:
    engine: str               # "local" | "distributed" | "hierarchical"
    workload_class: WorkloadClass
    est_seconds: float
    breakdown: Dict[str, float]
    n_devices: int
    feasible: bool
    reason: str = ""


@dataclasses.dataclass
class Planner:
    hw: HardwareSpec = TPU_V5E
    n_devices: int = 1
    n_pods: int = 1
    store_bw: float = 819e9   # store->HBM modeled at HBM class bandwidth
    # fixed cost of going distributed: dispatch/schedule + collective launch
    # latencies (the Spark-context analogue of the paper's §III-D3; the one-
    # time ~30 s spin-up is amortized across rounds and excluded)
    dispatch_overhead: float = 5e-3
    # reuse term: an engine without a cached executable for this round's
    # shape bucket pays a trace+compile before any byte moves. Elastic
    # rounds make this recurrent, not one-time, so warm engines are
    # costed below cold ones (ties between a warm single-chip plan and a
    # marginally-faster cold distributed plan resolve to the warm one).
    compile_overhead: float = 50e-3
    # async-round residue: what CANNOT hide under the monitor wait — the
    # close-time drain of the last partial chunk plus the final combine
    # (one poll interval + a block fold, in practice a few milliseconds)
    overlap_drain_seconds: float = 5e-3

    def candidate_plans(self, load: Workload, fusion: FusionAlgorithm,
                        warm_engines: Collection[str] = ()) -> List[Plan]:
        s = float(load.total_bytes)
        p_bytes = float(load.update_bytes)
        wl = classify(load, self.hw)
        plans: List[Plan] = []

        # -- single chip ----------------------------------------------------
        hbm_cap = self.hw.hbm_bytes * HBM_HEADROOM
        feasible_local = s <= hbm_cap or fusion.streamable  # streaming path
        mem_t = s / self.hw.hbm_bw
        passes = 1.0 if fusion.reducible else 2.0  # sort-based ops re-read
        local_compile = (
            0.0 if "local" in warm_engines else self.compile_overhead
        )
        plans.append(Plan(
            engine="local",
            workload_class=wl,
            est_seconds=s / self.store_bw + passes * mem_t + local_compile,
            breakdown={
                "ingest": s / self.store_bw,
                "memory": passes * mem_t,
                "compute": 2 * load.num_params * load.n_clients
                / self.hw.peak_flops_bf16,
                "collective": 0.0,
                "compile": local_compile,
            },
            n_devices=1,
            feasible=feasible_local,
            reason="streams client chunks" if s > hbm_cap else "fits HBM",
        ))

        # -- distributed mesh -------------------------------------------------
        if self.n_devices > 1:
            d = self.n_devices
            per_dev = s / d
            # streamable fusions stream store partitions through each chip
            # (the Spark model: the dataset lives in the store, not HBM),
            # so feasibility only requires the WORKING SET to fit
            working_set = (
                p_bytes / d if fusion.streamable else per_dev
            )
            ici = self.hw.ici_bw_per_link * self.hw.ici_links
            if fusion.reducible:
                # psum of the (param-sharded) partial: ring all-reduce of
                # P/d_model bytes over the data axis
                coll = 2.0 * p_bytes / max(d, 1) / ici * 4  # fp32 partials
            elif fusion.coordinatewise:
                coll = per_dev / ici  # all_to_all moves ~1/d of local shard
            else:
                coll = p_bytes / ici  # gram/score psums + row broadcast
            dist_name = "hierarchical" if self.n_pods > 1 else "distributed"
            dist_compile = (
                0.0 if dist_name in warm_engines else self.compile_overhead
            )
            plans.append(Plan(
                engine=dist_name,
                workload_class=wl,
                est_seconds=per_dev / self.store_bw + per_dev / self.hw.hbm_bw
                + coll + self.dispatch_overhead + dist_compile,
                breakdown={
                    "ingest": per_dev / self.store_bw,
                    "memory": per_dev / self.hw.hbm_bw,
                    "compute": 2 * load.num_params * load.n_clients
                    / (d * self.hw.peak_flops_bf16),
                    "collective": coll,
                    "compile": dist_compile,
                },
                n_devices=d,
                feasible=working_set <= hbm_cap,
                reason=f"shards S over {d} chips"
                + (" (streamed from store)" if per_dev > hbm_cap else ""),
            ))
        return plans

    # -- async overlap costing (Algorithm 1, straggler wait) -----------------
    def overlap_estimate(
        self, plan: Plan, expected_wait: float
    ) -> Tuple[float, float]:
        """(serialized_seconds, overlapped_seconds) for a store round whose
        monitor is expected to wait ``expected_wait`` for stragglers.

        Serialized (the PR-1 loop): the aggregator idles for the whole
        wait, THEN ingests and fuses — wait + est. Overlapped (async
        rounds): ingest/memory/compile stream under the wait as arrivals
        land, so the round costs max(wait, est) plus the close-time drain
        residue. The gap — min(wait, est) − drain — is exactly the
        straggler latency Algorithm 1 is meant to hide."""
        serialized = expected_wait + plan.est_seconds
        overlapped = (
            max(expected_wait, plan.est_seconds) + self.overlap_drain_seconds
        )
        return serialized, overlapped

    def round_objective(
        self,
        expected_wait: float,
        inclusion: float,
        cost_bias: float,
        horizon: float,
        est_seconds: float = 0.0,
    ) -> float:
        """The cost-vs-efficiency trade-off the adaptive controller
        minimizes (the paper's user-managed knob, §V): a convex blend of

          cost       — the overlapped round wall-clock for closing after
                       ``expected_wait`` seconds: fusing proceeds under
                       the wait (``max(wait, est_seconds)``) plus the
                       close-drain residue, normalized by ``horizon``
                       (the static timeout — the worst case a static
                       gate would pay), and
          staleness  — ``1 - inclusion``: the fraction of the expected
                       fleet whose update misses this round and folds a
                       round stale (or not at all).

        ``cost_bias`` in [0, 1] weights them: 0 optimizes wall-clock
        alone, 1 optimizes inclusion alone. Lower is better."""
        overlapped = (
            max(expected_wait, est_seconds) + self.overlap_drain_seconds
        )
        t_norm = min(overlapped, horizon) / max(horizon, 1e-9)
        return (1.0 - cost_bias) * t_norm + cost_bias * (1.0 - inclusion)

    def prefer_async(
        self,
        load: Workload,
        fusion: FusionAlgorithm,
        expected_wait: float,
        warm_engines: Collection[str] = (),
    ) -> bool:
        """True when the overlapped round model beats the serialized one —
        i.e. when the monitor wait dominates the drain residue. Only
        streamable fusions can fold while stragglers write."""
        if not fusion.streamable:
            return False
        plan = self.plan(load, fusion, warm_engines)
        serialized, overlapped = self.overlap_estimate(plan, expected_wait)
        return overlapped < serialized

    def plan(self, load: Workload, fusion: FusionAlgorithm,
             warm_engines: Collection[str] = ()) -> Plan:
        plans = [
            p for p in self.candidate_plans(load, fusion, warm_engines)
            if p.feasible
        ]
        if not plans:
            raise MemoryError(
                f"no feasible engine for S={load.total_bytes} bytes "
                f"({load.n_clients} x {load.update_bytes})"
            )
        return min(plans, key=lambda p: p.est_seconds)
