"""Distributed aggregation engine — the paper's Spark-MapReduce path,
re-thought as ``shard_map`` over the TPU mesh (§III-D2, DESIGN.md §2).

Layouts (mesh axes: optional "pod", "data", "model"):
  * reducible fusions:     updates (n, P) sharded P(client_axes, "model").
        map    = local partial weighted-sum over the client shard,
        reduce = psum over the client axes (paper's MapReduce reduce).
        Result: (P,) sharded over "model".
  * coordinate-wise:       all_to_all re-shards clients -> coordinates, so
        each device holds ALL n client values for a slice of coordinates
        (what Spark's shuffle does before a per-key reduce), then applies
        the op locally. Result sharded over ("model", client_axes).
  * Krum / Zeno / GeoMedian: updates sharded P(None, all axes) — full
        client rows never materialize on one device; pairwise Gram blocks
        / score terms are computed per coordinate shard and psum'd.

Compiled paths are PERSISTENT across rounds: the ``shard_map`` closures
(which the seed rebuilt and re-``jax.jit``'d on every ``fuse()`` call)
live in a per-engine CompiledCache keyed by (fusion, padded shape, dtype,
path), AOT-compiled against concrete sharded example inputs so compile
time is measured per key — cold vs warm rounds are distinguishable via
``last_compile_seconds`` exactly like the local engine. Reducible rounds
additionally bucket the client count to the next power of two
(zero-weight padded rows), so elastic rounds with varying ``n`` reuse ONE
executable instead of re-tracing.

Reducible rounds can also STREAM: ``fuse_stream`` folds (chunk, P)
blocks (off ``UpdateStore.iter_chunks``, or the service-adapted arrival
stream) through one cached shard_map step executable whose (P,)-sharded
accumulator lives on the mesh — host staging is O(chunk * P) per block,
never the dense (n, P) matrix.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compress import BLOCK, CompressedBlock
from repro.core.fusion.base import FusionAlgorithm
from repro.core.fusion.robust import GeometricMedian, Krum, Zeno
from repro.core.local import StreamReport, _check_scale
from repro.utils.compat import shard_map
from repro.utils.jitcache import CompiledCache, bucket_rows, fusion_cache_key


def _device_put(mesh: Mesh, x, spec: P):
    return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))


@dataclasses.dataclass
class DistributedEngine:
    """Map-reduce fusion over a device mesh."""

    mesh: Mesh
    client_axes: Tuple[str, ...] = ("data",)
    param_axis: str = "model"
    hierarchical: bool = False   # reduce within pod first, then across pods

    name: str = "distributed"

    def __post_init__(self):
        names = self.mesh.axis_names
        self.client_axes = tuple(a for a in self.client_axes if a in names)
        if "pod" in names and "pod" not in self.client_axes:
            # pods shard clients too (each pod's edge aggregates its region)
            self.client_axes = ("pod",) + self.client_axes
        self._n_client_shards = int(
            np.prod([self.mesh.shape[a] for a in self.client_axes])
        )
        self._n_param_shards = self.mesh.shape.get(self.param_axis, 1)
        self.cache = CompiledCache(name=f"distributed:{id(self.mesh)}")
        # per-THREAD compile accounting — concurrent rounds sharing this
        # engine each see their own fuse call's compile phase
        self._tls = threading.local()

    @property
    def last_compile_seconds(self) -> float:
        """Compile seconds paid by the CURRENT thread's last fuse call
        (0.0 on warm rounds); thread-local under concurrent rounds."""
        return getattr(self._tls, "compile_seconds", 0.0)

    @last_compile_seconds.setter
    def last_compile_seconds(self, value: float) -> None:
        self._tls.compile_seconds = value

    # -- shape bucketing -----------------------------------------------------
    def _padded_rows(self, n: int, reducible: bool) -> int:
        """Reducible rounds bucket n to a power of two (executable reuse);
        order-statistic paths pad only to the shard multiple — they slice
        padding by the REAL n inside the kernel, so their executables are
        n-specific anyway."""
        if reducible:
            b = bucket_rows(n)
            return b + ((-b) % self._n_client_shards)
        return n + ((-n) % self._n_client_shards)

    def is_warm(self, fusion, n: int, P_: int, dtype) -> bool:
        """Would this round hit an already-compiled executable?"""
        key = self._fuse_key(fusion, n, P_, dtype)
        return key in self.cache

    def _fuse_key(self, fusion, n: int, P_: int, dtype):
        pn = self._padded_rows(n, fusion.reducible)
        pad_p = (-P_) % (self._n_param_shards * self._n_client_shards)
        n_real = None if fusion.reducible else n
        return (
            fusion_cache_key(fusion), pn, P_ + pad_p, np.dtype(dtype).str,
            n_real, self.hierarchical,
        )

    # -- public -------------------------------------------------------------
    def fuse(self, fusion: FusionAlgorithm, updates, weights) -> jax.Array:
        """updates (n, P), weights (n,). Returns fused (P,) (sharded)."""
        self.last_compile_seconds = 0.0
        n, P_ = np.shape(updates)
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)
        weights = fusion.effective_weights(jnp.asarray(weights, jnp.float32))
        pad_n = self._padded_rows(n, fusion.reducible) - n
        pad_p = (-P_) % (self._n_param_shards * self._n_client_shards)
        if pad_n or pad_p:
            updates = jnp.pad(jnp.asarray(updates), ((0, pad_n), (0, pad_p)))
            # zero weight => padded rows contribute nothing to reducible
            # fusions; robust paths mask them explicitly
            weights = jnp.pad(jnp.asarray(weights), (0, pad_n))
        out = self._dispatch(fusion, updates, weights, n)
        return out[:P_]

    # -- dispatch -----------------------------------------------------------
    def _dispatch(self, fusion, updates, weights, n_real: int):
        if fusion.reducible:
            return self._fuse_reducible(fusion, updates, weights, n_real)
        if fusion.coordinatewise:
            return self._fuse_coordinatewise(fusion, updates, weights, n_real)
        if isinstance(fusion, Krum):
            return self._fuse_krum(fusion, updates, weights, n_real)
        if isinstance(fusion, Zeno):
            return self._fuse_zeno(fusion, updates, weights, n_real)
        if isinstance(fusion, GeometricMedian):
            return self._fuse_geomedian(fusion, updates, weights, n_real)
        raise NotImplementedError(
            f"no distributed strategy for fusion {fusion.name!r}"
        )

    def _cspec(self):
        return tuple(self.client_axes) if len(self.client_axes) > 1 else (
            self.client_axes[0] if self.client_axes else None
        )

    # -- reducible: map-reduce ------------------------------------------------
    def _partials(self, fusion, u, w):
        """The local 'map' stage over one client/param shard (full-row
        norms are psum'd over param shards first when the fusion needs
        them), followed by the client-axis reduce."""
        if fusion.needs_row_norms:
            sq = jnp.sum(u.astype(jnp.float32) ** 2, axis=1)
            if self._n_param_shards > 1:
                sq = jax.lax.psum(sq, self.param_axis)
            wsum, tot = fusion.partial_with_norms(u, w, jnp.sqrt(sq))
        else:
            wsum, tot = fusion.partial(u, w)
        if self.hierarchical:
            # edge stage: reduce within the pod's client shards first,
            # then the (smaller) cross-pod reduce — the paper's
            # client-edge-cloud hierarchy on the pod axis.
            for ax in reversed(self.client_axes):
                wsum = jax.lax.psum(wsum, ax)
                tot = jax.lax.psum(tot, ax)
        else:
            wsum = jax.lax.psum(wsum, self.client_axes)
            tot = jax.lax.psum(tot, self.client_axes)
        return wsum, tot

    def _fuse_reducible(self, fusion, updates, weights, n_real):
        mesh = self.mesh
        in_u = P(self._cspec(), self.param_axis)
        in_w = P(self._cspec())
        out = P(self.param_axis)

        def build():
            def mapper(u, w):
                return self._partials(fusion, u, w)

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u, in_w),
                out_specs=(out, P()), check_vma=False,
            )

        u = _device_put(mesh, updates, in_u)
        w = _device_put(mesh, jnp.asarray(weights, jnp.float32), in_w)
        fn = self._key_get(fusion, updates, None, build, u, w)
        wsum, tot = fn(u, w)
        # combine stays OUTSIDE the compiled closure: FedAvgM/FedAdam keep
        # python-side server state that must update every round, not once
        # at trace time.
        return fusion.combine(wsum, tot)

    # -- coordinate-wise: shuffle (all_to_all) then local --------------------
    def _fuse_coordinatewise(self, fusion, updates, weights, n_real):
        mesh = self.mesh
        in_u = P(self._cspec(), self.param_axis)
        out = P((self.param_axis,) + tuple(self.client_axes))

        def build():
            def mapper(u):
                for ax in self.client_axes:
                    u = jax.lax.all_to_all(
                        u, ax, split_axis=1, concat_axis=0, tiled=True
                    )
                # u now holds ALL padded client rows for a coordinate
                # slice; drop padding rows so order statistics are exact.
                u = u[:n_real]
                return fusion.fuse(u, None)

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u,), out_specs=out,
                check_vma=False,
            )

        u = _device_put(mesh, updates, in_u)
        fn = self._key_get(fusion, updates, n_real, build, u)
        return fn(u)

    # -- Krum: psum'd Gram matrix --------------------------------------------
    def _fuse_krum(self, fusion: Krum, updates, weights, n_real):
        mesh = self.mesh
        all_axes = tuple(self.client_axes) + (self.param_axis,)
        in_u = P(None, all_axes)
        out = P(all_axes)

        def build():
            def mapper(u):
                uf = u.astype(jnp.float32)
                gram = jax.lax.psum(uf @ uf.T, all_axes)
                gram = gram[:n_real, :n_real]
                idx = fusion.select_from_gram(gram)
                return jnp.mean(uf[:n_real][idx], axis=0)

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u,), out_specs=out,
                check_vma=False,
            )

        u = _device_put(mesh, updates, in_u)
        fn = self._key_get(fusion, updates, n_real, build, u)
        return fn(u)

    # -- Zeno: psum'd scores ---------------------------------------------------
    def _fuse_zeno(self, fusion: Zeno, updates, weights, n_real):
        mesh = self.mesh
        all_axes = tuple(self.client_axes) + (self.param_axis,)
        in_u = P(None, all_axes)
        out = P(all_axes)
        g_val = fusion._g_val

        def build():
            def mapper(u, g):
                uf = u.astype(jnp.float32)
                inner = jax.lax.psum(uf @ g, all_axes)[:n_real]
                sq = jax.lax.psum(jnp.sum(uf * uf, axis=1), all_axes)[:n_real]
                s = fusion.scores(inner, sq)
                keep = max(n_real - fusion.n_suspect, 1)
                _, idx = jax.lax.top_k(s, keep)
                return jnp.mean(uf[:n_real][idx], axis=0)

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u, P(all_axes)),
                out_specs=out, check_vma=False,
            )

        u = _device_put(mesh, updates, in_u)
        if g_val is None:
            g_val = jnp.mean(jnp.asarray(updates, jnp.float32), axis=0)
        g = _device_put(mesh, jnp.asarray(g_val, jnp.float32), P(all_axes))
        fn = self._key_get(fusion, updates, n_real, build, u, g)
        return fn(u, g)

    # -- Geometric median: distributed Weiszfeld -------------------------------
    def _fuse_geomedian(self, fusion: GeometricMedian, updates, weights,
                        n_real):
        mesh = self.mesh
        all_axes = tuple(self.client_axes) + (self.param_axis,)
        in_u = P(None, all_axes)
        out = P(all_axes)

        def build():
            def mapper(u, w):
                uf = u.astype(jnp.float32)[:n_real]
                wf = w.astype(jnp.float32)[:n_real]
                wf = wf / jnp.sum(wf)
                z = jnp.einsum("np,n->p", uf, wf)

                def step(z, _):
                    d2 = jax.lax.psum(
                        jnp.sum((uf - z[None, :]) ** 2, axis=1), all_axes
                    )
                    d = jnp.sqrt(d2)
                    beta = wf / jnp.maximum(d, fusion.smooth)
                    beta = beta / jnp.sum(beta)
                    return jnp.einsum("np,n->p", uf, beta), None

                z, _ = jax.lax.scan(step, z, None, length=fusion.iters)
                return z

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u, P(None)), out_specs=out,
                check_vma=False,
            )

        u = _device_put(mesh, updates, in_u)
        w = _device_put(mesh, jnp.asarray(weights, jnp.float32), P(None))
        fn = self._key_get(fusion, updates, n_real, build, u, w)
        return fn(u, w)

    # -- streaming: per-shard chunked ingest ----------------------------------
    def _stream_key(self, fusion, chunk: int, P_: int, dtype, sig):
        pc = chunk + (-chunk) % self._n_client_shards
        pad_p = (-P_) % (self._n_param_shards * self._n_client_shards)
        return ("stream", fusion_cache_key(fusion), pc, P_ + pad_p,
                np.dtype(dtype).str, self.hierarchical, sig)

    def _dequant_key(self, chunk: int, P_: int, blk: int, weighted: bool):
        pc = chunk + (-chunk) % self._n_client_shards
        Pq = -(-P_ // blk) * blk
        pad_p = (-P_) % (self._n_param_shards * self._n_client_shards)
        return ("dequant", pc, Pq, blk, P_, P_ + pad_p, weighted)

    def is_warm_stream(self, fusion, chunk: int, P_: int, dtype,
                       block: Optional[int] = None,
                       n_hint: Optional[int] = None) -> bool:
        """Warm-path probe. ``dtype`` int8 probes the COMPRESSED route:
        the on-device dequant executable (at quantization block
        ``block``, default ``compress.BLOCK``) AND the fp32 fold step it
        feeds — a compressed round is only warm with both. ``n_hint``
        sizes order-statistic carve state (its executables are keyed by
        the carve capacity)."""
        if not fusion.streamable:
            return False
        try:
            sig = fusion.state_signature(P_, n_hint)
        except ValueError:   # carve fusion with no n_hint: can't stream
            return False
        if np.dtype(dtype) == np.int8:
            blk = int(block) if block else BLOCK
            return (
                self._dequant_key(chunk, P_, blk, fusion.weighted)
                in self.cache
                and self._stream_key(fusion, chunk, P_, np.float32, sig)
                in self.cache
            )
        return self._stream_key(fusion, chunk, P_, dtype, sig) in self.cache

    def _dequant_fn(self, pc, Pq, blk, dim, pdim, u_spec, weighted,
                    q_ex, s_ex):
        """Cached on-device dequant executable for streamed compressed
        blocks: (codes (pc, Pq) int8, scales (pc, Pq//blk) fp32) ->
        (pc, pdim) fp32, output sharding-constrained to the step
        executable's update layout (``u_spec`` — client-sharded for the
        sum path, client-replicated for the carve path) — so the fp32
        block exists only as a device-side transient between two
        compiled artifacts, never on the host, and mixed fp32/int8
        rounds share ONE fold step and ONE on-mesh accumulator."""
        mesh = self.mesh
        key = ("dequant", pc, Pq, blk, dim, pdim, weighted)

        def build():
            def deq(q, s):
                u = (
                    q.astype(jnp.float32).reshape(pc, Pq // blk, blk)
                    * s[:, :, None]
                ).reshape(pc, Pq)[:, :dim]
                if pdim != dim:
                    u = jnp.pad(u, ((0, 0), (0, pdim - dim)))
                return jax.lax.with_sharding_constraint(
                    u, NamedSharding(mesh, u_spec)
                )

            return deq

        return self.cache.get(key, build, q_ex, s_ex)

    def _leaf_spec(self, shape, pdim) -> P:
        """Mesh placement for one reducer-state leaf by shape rule:
        trailing param axis sharded over ``param_axis`` ((pdim,) and
        (K, pdim) leaves), scalars replicated."""
        if len(shape) == 0 or shape[-1] != pdim:
            return P()
        if len(shape) == 1:
            return P(self.param_axis)
        return P(*([None] * (len(shape) - 1) + [self.param_axis]))

    def fuse_stream(
        self,
        fusion: FusionAlgorithm,
        blocks: Iterable[Tuple[np.ndarray, ...]],
        init: Optional[tuple] = None,
        chunk_rows: Optional[int] = None,
        device_sem=None,
        n_hint: Optional[int] = None,
    ) -> Tuple[jax.Array, StreamReport]:
        """Per-shard streaming ingest: fold (chunk, P) blocks (e.g. from
        ``UpdateStore.iter_chunks``) through ONE cached shard_map step
        executable. Each block is staged host-side at O(chunk * P),
        device_put sharded over (client_axes, param_axis), and psum'd
        into a (P,)-sharded on-mesh accumulator — the dense (n, P)
        matrix never exists on the host. A block may be a
        :class:`repro.core.compress.CompressedBlock` (int8 codes + fp32
        per-block scales): it stages host-side at its COMPRESSED size,
        dequantizes on-device through a cached executable, and feeds
        the same fp32 fold step dense fp32 blocks use — mixed
        dense/compressed rounds (stragglers may be uncompressed) share
        one step and one on-mesh accumulator, and the fp32 matrix never
        exists on the host. Block / ``init`` / ``chunk_rows`` /
        ``n_hint`` semantics match ``LocalEngine.fuse_stream`` (numeric
        per-block staleness scale; carried reducer state in/out via the
        StreamReport; pass the configured ``chunk_rows`` so variable
        final blocks reuse one executable — ``iter_arrivals`` yields
        client ids, adapt it before streaming here; ``device_sem``
        bounds concurrent device execution across rounds sharing this
        engine, and all carry state is per-call local so concurrent
        folds never cross).

        Layouts per reducer family: the SUM path shards blocks
        P(client_axes, param_axis) and psums partials (the historical
        map-reduce); the order-statistic CARVE path shards blocks
        P(None, param_axis) — every device along the client axes holds
        all chunk rows for its coordinate slice and carves them locally,
        no collective needed — with the (K, P) extreme buffers sharded
        over the param axis, so per-device carry stays O(K * P/shards)."""
        if not fusion.streamable:
            raise ValueError(
                f"{fusion.name} is not streamable — streamed aggregation "
                "needs a reducer decomposition (weighted sum or "
                "order-statistic carve)"
            )
        weighted = fusion.weighted
        mesh = self.mesh
        self.last_compile_seconds = 0.0
        if weighted:
            in_u = P(self._cspec(), self.param_axis)
            in_w = P(self._cspec())
        else:
            # carve path: replicate rows across client axes, shard coords
            in_u = P(None, self.param_axis)
            in_w = P(None)
        rep = StreamReport()
        sem = device_sem if device_sem is not None \
            else contextlib.nullcontext()
        it = iter(blocks)
        steps: dict = {}   # payload dtype -> cached fold step
        deqs: dict = {}    # (Pq, blk) -> cached dequant executable
        state = sig = None
        leaf_specs = None
        chunk = dim = None
        pc = pdim = 0
        compile_total = 0.0
        while True:
            t0 = time.perf_counter()
            try:
                item = next(it)
            except StopIteration:
                break
            rep.ingest_seconds += time.perf_counter() - t0
            block, w = item[0], item[1]
            scale = _check_scale(item[2]) if len(item) > 2 else None
            if scale is not None and not weighted:
                raise ValueError(
                    f"{fusion.name}: per-row staleness scales are "
                    "unsupported — order statistics cannot discount rows"
                )
            compressed = isinstance(block, CompressedBlock)
            rows = block.rows if compressed else block.shape[0]
            bdim = block.dim if compressed else block.shape[1]
            if chunk is None:
                dim = bdim
                chunk = int(chunk_rows) if chunk_rows else rows
                rep.chunk_rows = chunk
                pc = chunk + (-chunk) % self._n_client_shards
                pdim = dim + (
                    (-dim) % (self._n_param_shards * self._n_client_shards)
                )
                sig = fusion.state_signature(dim, n_hint)
            elif bdim != dim:
                raise ValueError(
                    f"fuse_stream: block dim {bdim} != stream dim {dim}"
                )
            if rows > chunk:
                raise ValueError(
                    f"fuse_stream: block of {rows} rows exceeds "
                    f"chunk_rows={chunk}"
                )
            rep.ingest_bytes += int(block.nbytes)   # pre-padding payload
            if weighted:
                wpad = np.zeros((pc,), np.float32)
                wpad[:rows] = w
                w_eff = np.array(
                    fusion.effective_weights(jnp.asarray(wpad, jnp.float32))
                )
                if scale is not None:
                    w_eff[:rows] *= np.asarray(scale, np.float32)[:rows]
                w_eff[rows:] = 0.0         # effective_weights may remap pads
            else:
                # order-statistic fold: weights carry only row VALIDITY
                w_eff = np.zeros((pc,), np.float32)
                w_eff[:rows] = 1.0
            t0 = time.perf_counter()
            if compressed:
                # host staging at the COMPRESSED size; the fp32 block
                # exists only on device, between the dequant executable
                # and the fold step
                Pq, blk = block.codes.shape[1], block.block
                if rows < pc:
                    qpad = np.zeros((pc, Pq), np.int8)
                    qpad[:rows] = block.codes
                    spad = np.zeros((pc, Pq // blk), np.float32)
                    spad[:rows] = block.scales
                else:
                    qpad, spad = block.codes, block.scales
                cspec2 = P(self._cspec(), None) if weighted else P(None, None)
                q_dev = _device_put(mesh, qpad, cspec2)
                s_dev = _device_put(mesh, spad, cspec2)
                deq = deqs.get((Pq, blk))
                if deq is None:
                    deq, c_s = self._dequant_fn(
                        pc, Pq, blk, dim, pdim, in_u, weighted, q_dev,
                        s_dev,
                    )
                    deqs[(Pq, blk)] = deq
                    compile_total += c_s
                u_dev = deq(q_dev, s_dev)
                dtype = np.dtype(np.float32)
            else:
                if rows < pc or pdim != dim:  # shard-multiple/ragged pad
                    padded = np.zeros((pc, pdim), block.dtype)
                    padded[:rows, :dim] = block
                    block = padded
                u_dev = _device_put(mesh, block, in_u)
                dtype = np.dtype(block.dtype)
            w_dev = _device_put(mesh, jnp.asarray(w_eff, jnp.float32), in_w)
            rep.ingest_seconds += time.perf_counter() - t0
            if state is None:
                host_state = self._stream_state_host(fusion, dim, pdim,
                                                     n_hint, init)
                leaf_specs = tuple(
                    self._leaf_spec(np.shape(x), pdim) for x in host_state
                )
                state = tuple(
                    _device_put(mesh, x, s)
                    for x, s in zip(host_state, leaf_specs)
                )
            step = steps.get(dtype.str)
            if step is None:
                def build():
                    def step_fn(u, wv, *leaves):
                        st = tuple(leaves)
                        if fusion.reducible:
                            partial = lambda uu, ww: self._partials(
                                fusion, uu, ww)
                            new = fusion.fold_block(st, u, wv,
                                                    partial=partial)
                        else:
                            # local carve per coordinate shard — rows are
                            # replicated across client axes, no collective
                            new = fusion.fold_block(st, u, wv)
                        return tuple(new)

                    return shard_map(
                        step_fn, mesh=mesh,
                        in_specs=(in_u, in_w) + leaf_specs,
                        out_specs=leaf_specs, check_vma=False,
                    )

                step, compile_s = self.cache.get(
                    self._stream_key(fusion, chunk, dim, dtype, sig),
                    build, u_dev, w_dev, *state,
                )
                steps[dtype.str] = step
                # mixed rounds accumulate one compile per payload kind
                compile_total += compile_s
            rep.compile_seconds = compile_total
            self.last_compile_seconds = compile_total
            t0 = time.perf_counter()
            with sem:
                state = step(u_dev, w_dev, *state)
                if device_sem is not None:
                    # async dispatch must not escape the execution bound
                    jax.block_until_ready(state)  # lint: disable=sync-under-sem -- deliberate: the permit must cover device EXECUTION, not just dispatch (PR 5's device_concurrency contract)
            rep.compute_seconds += time.perf_counter() - t0
            rep.n_rows += rows
            rep.n_blocks += 1
        if rep.n_blocks == 0:
            if init is None:
                raise ValueError("fuse_stream: empty block iterator")
            # carry-only round: nothing arrived, finalize the carried state
            dim = int(np.shape(init[0])[-1])
            state = tuple(jnp.asarray(x, jnp.float32) for x in init)
            pdim = dim
        t0 = time.perf_counter()
        # slice param-padded leaves back to the real dim BEFORE finalize:
        # padded coordinates carry garbage (inf sentinels on the carve
        # path) that must never reach the finalize arithmetic
        host_leaves = tuple(np.asarray(x) for x in state)
        sliced = tuple(
            x[..., :dim] if x.ndim and x.shape[-1] == pdim else x
            for x in host_leaves
        )
        rep.acc_state = sliced
        if fusion.reducible:
            rep.acc_wsum = sliced[0]
            rep.acc_tot = float(sliced[1])
        with sem:
            fused = jax.block_until_ready(fusion.finalize(sliced))  # lint: disable=sync-under-sem -- deliberate: the permit must cover device EXECUTION, not just dispatch (PR 5's device_concurrency contract)
        rep.compute_seconds += time.perf_counter() - t0
        return fused, rep

    def _stream_state_host(self, fusion, dim, pdim, n_hint, init):
        """Initial reducer state as host arrays, zero-padded on the
        param axis to the shard multiple so carried state re-shards
        cleanly (padded coords are sliced off before finalize)."""
        proto = tuple(fusion.init_state(dim, n_hint))
        if init is not None:
            if len(init) != len(proto):
                raise ValueError(
                    f"fuse_stream: carried state has {len(init)} leaves, "
                    f"{fusion.name} expects {len(proto)}"
                )
            for x, p in zip(init, proto):
                if np.shape(x) != np.shape(p):
                    raise ValueError(
                        f"fuse_stream: carried accumulator has shape "
                        f"{np.shape(x)}, stream blocks have dim {dim}"
                    )
            proto = tuple(np.asarray(x, np.float32) for x in init)
        out = []
        for leaf in proto:
            leaf = np.asarray(leaf, np.float32)
            if leaf.ndim and leaf.shape[-1] == dim and pdim != dim:
                pad = [(0, 0)] * (leaf.ndim - 1) + [(0, pdim - dim)]
                leaf = np.pad(leaf, pad)
            out.append(leaf)
        return tuple(out)

    # -- cache plumbing -------------------------------------------------------
    def _key_get(self, fusion, padded_updates, n_real, build, *concrete):
        """Fetch (or AOT-compile against the concrete sharded example
        inputs) the executable for this round's padded shape, accumulating
        measured compile seconds into ``last_compile_seconds``."""
        pn, pp = np.shape(padded_updates)
        key = (
            fusion_cache_key(fusion), pn, pp,
            np.dtype(padded_updates.dtype).str, n_real, self.hierarchical,
        )
        fn, compile_s = self.cache.get(key, build, *concrete)
        self.last_compile_seconds += compile_s
        return fn
