"""Distributed aggregation engine — the paper's Spark-MapReduce path,
re-thought as ``shard_map`` over the TPU mesh (§III-D2, DESIGN.md §2).

Layouts (mesh axes: optional "pod", "data", "model"):
  * reducible fusions:     updates (n, P) sharded P(client_axes, "model").
        map    = local partial weighted-sum over the client shard,
        reduce = psum over the client axes (paper's MapReduce reduce).
        Result: (P,) sharded over "model".
  * coordinate-wise:       all_to_all re-shards clients -> coordinates, so
        each device holds ALL n client values for a slice of coordinates
        (what Spark's shuffle does before a per-key reduce), then applies
        the op locally. Result sharded over ("model", client_axes).
  * Krum / Zeno / GeoMedian: updates sharded P(None, all axes) — full
        client rows never materialize on one device; pairwise Gram blocks
        / score terms are computed per coordinate shard and psum'd.

Compiled paths are PERSISTENT across rounds: the ``shard_map`` closures
(which the seed rebuilt and re-``jax.jit``'d on every ``fuse()`` call)
live in a per-engine CompiledCache keyed by (fusion, padded shape, dtype,
path). Reducible rounds additionally bucket the client count to the next
power of two (zero-weight padded rows), so elastic rounds with varying
``n`` reuse ONE executable instead of re-tracing.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fusion.base import FusionAlgorithm
from repro.core.fusion.robust import GeometricMedian, Krum, TrimmedMean, Zeno
from repro.utils.compat import shard_map
from repro.utils.jitcache import CompiledCache, bucket_rows, fusion_cache_key


def _device_put(mesh: Mesh, x, spec: P):
    return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))


@dataclasses.dataclass
class DistributedEngine:
    """Map-reduce fusion over a device mesh."""

    mesh: Mesh
    client_axes: Tuple[str, ...] = ("data",)
    param_axis: str = "model"
    hierarchical: bool = False   # reduce within pod first, then across pods

    name: str = "distributed"

    def __post_init__(self):
        names = self.mesh.axis_names
        self.client_axes = tuple(a for a in self.client_axes if a in names)
        if "pod" in names and "pod" not in self.client_axes:
            # pods shard clients too (each pod's edge aggregates its region)
            self.client_axes = ("pod",) + self.client_axes
        self._n_client_shards = int(
            np.prod([self.mesh.shape[a] for a in self.client_axes])
        )
        self._n_param_shards = self.mesh.shape.get(self.param_axis, 1)
        self.cache = CompiledCache(name=f"distributed:{id(self.mesh)}")

    # -- shape bucketing -----------------------------------------------------
    def _padded_rows(self, n: int, reducible: bool) -> int:
        """Reducible rounds bucket n to a power of two (executable reuse);
        order-statistic paths pad only to the shard multiple — they slice
        padding by the REAL n inside the kernel, so their executables are
        n-specific anyway."""
        if reducible:
            b = bucket_rows(n)
            return b + ((-b) % self._n_client_shards)
        return n + ((-n) % self._n_client_shards)

    def is_warm(self, fusion, n: int, P_: int, dtype) -> bool:
        """Would this round hit an already-compiled executable?"""
        key = self._fuse_key(fusion, n, P_, dtype)
        return key in self.cache

    def _fuse_key(self, fusion, n: int, P_: int, dtype):
        pn = self._padded_rows(n, fusion.reducible)
        pad_p = (-P_) % (self._n_param_shards * self._n_client_shards)
        n_real = None if fusion.reducible else n
        return (
            fusion_cache_key(fusion), pn, P_ + pad_p, np.dtype(dtype).str,
            n_real, self.hierarchical,
        )

    # -- public -------------------------------------------------------------
    def fuse(self, fusion: FusionAlgorithm, updates, weights) -> jax.Array:
        """updates (n, P), weights (n,). Returns fused (P,) (sharded)."""
        n, P_ = np.shape(updates)
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)
        weights = fusion.effective_weights(jnp.asarray(weights, jnp.float32))
        pad_n = self._padded_rows(n, fusion.reducible) - n
        pad_p = (-P_) % (self._n_param_shards * self._n_client_shards)
        if pad_n or pad_p:
            updates = jnp.pad(jnp.asarray(updates), ((0, pad_n), (0, pad_p)))
            # zero weight => padded rows contribute nothing to reducible
            # fusions; robust paths mask them explicitly
            weights = jnp.pad(jnp.asarray(weights), (0, pad_n))
        out = self._dispatch(fusion, updates, weights, n)
        return out[:P_]

    # -- dispatch -----------------------------------------------------------
    def _dispatch(self, fusion, updates, weights, n_real: int):
        if fusion.reducible:
            return self._fuse_reducible(fusion, updates, weights, n_real)
        if fusion.coordinatewise:
            return self._fuse_coordinatewise(fusion, updates, weights, n_real)
        if isinstance(fusion, Krum):
            return self._fuse_krum(fusion, updates, weights, n_real)
        if isinstance(fusion, Zeno):
            return self._fuse_zeno(fusion, updates, weights, n_real)
        if isinstance(fusion, GeometricMedian):
            return self._fuse_geomedian(fusion, updates, weights, n_real)
        raise NotImplementedError(
            f"no distributed strategy for fusion {fusion.name!r}"
        )

    def _cspec(self):
        return tuple(self.client_axes) if len(self.client_axes) > 1 else (
            self.client_axes[0] if self.client_axes else None
        )

    # -- reducible: map-reduce ------------------------------------------------
    def _fuse_reducible(self, fusion, updates, weights, n_real):
        mesh = self.mesh
        in_u = P(self._cspec(), self.param_axis)
        in_w = P(self._cspec())
        out = P(self.param_axis)

        def build():
            def mapper(u, w):
                if fusion.needs_row_norms:
                    sq = jnp.sum(u.astype(jnp.float32) ** 2, axis=1)
                    if self._n_param_shards > 1:
                        sq = jax.lax.psum(sq, self.param_axis)
                    wsum, tot = fusion.partial_with_norms(u, w, jnp.sqrt(sq))
                else:
                    wsum, tot = fusion.partial(u, w)
                if self.hierarchical:
                    # edge stage: reduce within the pod's client shards
                    # first, then the (smaller) cross-pod reduce — the
                    # paper's client-edge-cloud hierarchy on the pod axis.
                    for ax in reversed(self.client_axes):
                        wsum = jax.lax.psum(wsum, ax)
                        tot = jax.lax.psum(tot, ax)
                else:
                    wsum = jax.lax.psum(wsum, self.client_axes)
                    tot = jax.lax.psum(tot, self.client_axes)
                return wsum, tot

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u, in_w),
                out_specs=(out, P()), check_vma=False,
            )

        fn = self._key_get(fusion, updates, None, build)
        u = _device_put(mesh, updates, in_u)
        w = _device_put(mesh, jnp.asarray(weights, jnp.float32), in_w)
        wsum, tot = fn(u, w)
        # combine stays OUTSIDE the compiled closure: FedAvgM/FedAdam keep
        # python-side server state that must update every round, not once
        # at trace time.
        return fusion.combine(wsum, tot)

    # -- coordinate-wise: shuffle (all_to_all) then local --------------------
    def _fuse_coordinatewise(self, fusion, updates, weights, n_real):
        mesh = self.mesh
        in_u = P(self._cspec(), self.param_axis)
        out = P((self.param_axis,) + tuple(self.client_axes))

        def build():
            def mapper(u):
                for ax in self.client_axes:
                    u = jax.lax.all_to_all(
                        u, ax, split_axis=1, concat_axis=0, tiled=True
                    )
                # u now holds ALL padded client rows for a coordinate
                # slice; drop padding rows so order statistics are exact.
                u = u[:n_real]
                return fusion.fuse(u, None)

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u,), out_specs=out,
                check_vma=False,
            )

        fn = self._key_get(fusion, updates, n_real, build)
        u = _device_put(mesh, updates, in_u)
        return fn(u)

    # -- Krum: psum'd Gram matrix --------------------------------------------
    def _fuse_krum(self, fusion: Krum, updates, weights, n_real):
        mesh = self.mesh
        all_axes = tuple(self.client_axes) + (self.param_axis,)
        in_u = P(None, all_axes)
        out = P(all_axes)

        def build():
            def mapper(u):
                uf = u.astype(jnp.float32)
                gram = jax.lax.psum(uf @ uf.T, all_axes)
                gram = gram[:n_real, :n_real]
                idx = fusion.select_from_gram(gram)
                return jnp.mean(uf[:n_real][idx], axis=0)

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u,), out_specs=out,
                check_vma=False,
            )

        fn = self._key_get(fusion, updates, n_real, build)
        u = _device_put(mesh, updates, in_u)
        return fn(u)

    # -- Zeno: psum'd scores ---------------------------------------------------
    def _fuse_zeno(self, fusion: Zeno, updates, weights, n_real):
        mesh = self.mesh
        all_axes = tuple(self.client_axes) + (self.param_axis,)
        in_u = P(None, all_axes)
        out = P(all_axes)
        g_val = fusion._g_val

        def build():
            def mapper(u, g):
                uf = u.astype(jnp.float32)
                inner = jax.lax.psum(uf @ g, all_axes)[:n_real]
                sq = jax.lax.psum(jnp.sum(uf * uf, axis=1), all_axes)[:n_real]
                s = fusion.scores(inner, sq)
                keep = max(n_real - fusion.n_suspect, 1)
                _, idx = jax.lax.top_k(s, keep)
                return jnp.mean(uf[:n_real][idx], axis=0)

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u, P(all_axes)),
                out_specs=out, check_vma=False,
            )

        fn = self._key_get(fusion, updates, n_real, build)
        u = _device_put(mesh, updates, in_u)
        if g_val is None:
            g_val = jnp.mean(jnp.asarray(updates, jnp.float32), axis=0)
        g = _device_put(mesh, jnp.asarray(g_val, jnp.float32), P(all_axes))
        return fn(u, g)

    # -- Geometric median: distributed Weiszfeld -------------------------------
    def _fuse_geomedian(self, fusion: GeometricMedian, updates, weights,
                        n_real):
        mesh = self.mesh
        all_axes = tuple(self.client_axes) + (self.param_axis,)
        in_u = P(None, all_axes)
        out = P(all_axes)

        def build():
            def mapper(u, w):
                uf = u.astype(jnp.float32)[:n_real]
                wf = w.astype(jnp.float32)[:n_real]
                wf = wf / jnp.sum(wf)
                z = jnp.einsum("np,n->p", uf, wf)

                def step(z, _):
                    d2 = jax.lax.psum(
                        jnp.sum((uf - z[None, :]) ** 2, axis=1), all_axes
                    )
                    d = jnp.sqrt(d2)
                    beta = wf / jnp.maximum(d, fusion.smooth)
                    beta = beta / jnp.sum(beta)
                    return jnp.einsum("np,n->p", uf, beta), None

                z, _ = jax.lax.scan(step, z, None, length=fusion.iters)
                return z

            return shard_map(
                mapper, mesh=mesh, in_specs=(in_u, P(None)), out_specs=out,
                check_vma=False,
            )

        fn = self._key_get(fusion, updates, n_real, build)
        u = _device_put(mesh, updates, in_u)
        w = _device_put(mesh, jnp.asarray(weights, jnp.float32), P(None))
        return fn(u, w)

    # -- cache plumbing -------------------------------------------------------
    def _key_get(self, fusion, padded_updates, n_real, build):
        pn, pp = np.shape(padded_updates)
        key = (
            fusion_cache_key(fusion), pn, pp,
            np.dtype(padded_updates.dtype).str, n_real, self.hierarchical,
        )
        return self.cache.get_jitted(key, build)
