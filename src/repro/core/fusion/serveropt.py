"""Server-side optimizer fusions: FedAvgM (server momentum) and FedAdam
(Reddi et al., Adaptive Federated Optimization). These wrap a reducible
inner fusion (GradAvg) and keep server state across rounds."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.fusion.averaging import GradAvg
from repro.core.fusion.base import FusionAlgorithm


@dataclasses.dataclass
class FedAvgM(FusionAlgorithm):
    """Server momentum over the fused pseudo-gradient."""

    lr: float = 1.0
    momentum: float = 0.9
    name = "fedavgm"
    reducible = True

    def __post_init__(self):
        self._inner = GradAvg()
        self._velocity: Optional[jnp.ndarray] = None

    def reset(self):
        self._velocity = None

    def partial(self, updates, weights):
        return self._inner.partial(updates, weights)

    def combine(self, weighted_sum, weight_sum):
        g = self._inner.combine(weighted_sum, weight_sum)
        v = g if self._velocity is None else (
            self.momentum * self._velocity + g
        )
        self._velocity = v
        return self.lr * v

    def fuse(self, updates, weights):
        return self.combine(*self.partial(updates, weights))


@dataclasses.dataclass
class FedAdam(FusionAlgorithm):
    """FedAdam server optimizer over the fused pseudo-gradient."""

    lr: float = 1e-2
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-3
    name = "fedadam"
    reducible = True

    def __post_init__(self):
        self._inner = GradAvg()
        self._m: Optional[jnp.ndarray] = None
        self._v: Optional[jnp.ndarray] = None
        self._t = 0

    def reset(self):
        self._m, self._v, self._t = None, None, 0

    def partial(self, updates, weights):
        return self._inner.partial(updates, weights)

    def combine(self, weighted_sum, weight_sum):
        g = self._inner.combine(weighted_sum, weight_sum)
        if self._m is None:
            self._m = jnp.zeros_like(g)
            self._v = jnp.zeros_like(g)
        self._t += 1
        self._m = self.b1 * self._m + (1 - self.b1) * g
        self._v = self.b2 * self._v + (1 - self.b2) * g * g
        mhat = self._m / (1 - self.b1 ** self._t)
        vhat = self._v / (1 - self.b2 ** self._t)
        return self.lr * mhat / (jnp.sqrt(vhat) + self.eps)

    def fuse(self, updates, weights):
        return self.combine(*self.partial(updates, weights))
