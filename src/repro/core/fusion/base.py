"""Fusion algorithm interface.

A fusion algorithm consumes ``n`` client model updates and produces one
fused update. All algorithms operate on the canonical flat-vector layout
(``utils.pytree.tree_to_flat_vector``): updates are a (n, P) matrix and
per-client weights (sample counts) a (n,) vector.

Two capability flags drive engine selection (paper §III-D):

* ``reducible`` — the algorithm is a weighted sum over clients, so the
  distributed engine can fuse with a pure map-reduce (local partial sums +
  ``psum``), exactly like the paper's Spark MapReduce path. FedAvg,
  IterAvg, GradAvg, ClippedAvg are reducible.
* ``coordinatewise`` — the algorithm acts independently per coordinate
  given ALL client values for that coordinate (median, trimmed mean).
  The distributed engine re-shards clients->coordinates (all-to-all) and
  applies the op locally.

Algorithms that are neither (Krum, Zeno, geometric median) expose
``pairwise_stats``/``score``-style hooks used by the distributed engine to
compute partial statistics locally and combine with ``psum``.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


class FusionAlgorithm(abc.ABC):
    """Base class. Subclasses are stateless and jit-friendly."""

    name: str = "base"
    reducible: bool = False
    coordinatewise: bool = False

    # set when per-client full-row norms are needed before the weighted sum
    # (e.g. ClippedAvg) — the distributed engine psums squared norms across
    # parameter shards and calls partial_with_norms instead of partial.
    needs_row_norms: bool = False

    @abc.abstractmethod
    def fuse(self, updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
        """updates: (n, P); weights: (n,) fp32. Returns (P,)."""

    # -- hooks for the reducible (map-reduce) path -------------------------
    def effective_weights(self, weights: jnp.ndarray) -> jnp.ndarray:
        """Normalize the weight semantics BEFORE any padding, so padded
        rows (weight 0) never contribute. IterAvg overrides to ones."""
        return weights

    def partial(self, updates: jnp.ndarray, weights: jnp.ndarray):
        """Local 'map' stage: returns (weighted_sum (P,), weight_sum ())."""
        raise NotImplementedError(f"{self.name} is not reducible")

    def partial_with_norms(self, updates, weights, row_norms):
        """Like partial() but given exact full-row L2 norms (n,)."""
        raise NotImplementedError(f"{self.name} does not use row norms")

    def combine(self, weighted_sum: jnp.ndarray, weight_sum: jnp.ndarray):
        """Final 'reduce' stage after summing partials across shards."""
        raise NotImplementedError(f"{self.name} is not reducible")

    def __repr__(self) -> str:
        return f"<fusion:{self.name}>"


EPS = 1e-6  # the paper's epsilon in Eq. (1)
