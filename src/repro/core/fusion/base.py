"""Fusion algorithm interface.

A fusion algorithm consumes ``n`` client model updates and produces one
fused update. All algorithms operate on the canonical flat-vector layout
(``utils.pytree.tree_to_flat_vector``): updates are a (n, P) matrix and
per-client weights (sample counts) a (n,) vector.

Two capability flags drive engine selection (paper §III-D):

* ``reducible`` — the algorithm is a weighted sum over clients, so the
  distributed engine can fuse with a pure map-reduce (local partial sums +
  ``psum``), exactly like the paper's Spark MapReduce path. FedAvg,
  IterAvg, GradAvg, ClippedAvg are reducible.
* ``coordinatewise`` — the algorithm acts independently per coordinate
  given ALL client values for that coordinate (median, trimmed mean).
  The distributed engine re-shards clients->coordinates (all-to-all) and
  applies the op locally.

Algorithms that are neither (Krum, Zeno, geometric median) expose
``pairwise_stats``/``score``-style hooks used by the distributed engine to
compute partial statistics locally and combine with ``psum``.

Streaming reducer protocol
--------------------------

Engines stream rounds by folding (chunk, P) blocks into a fusion-owned
carry state instead of materializing the (n, P) matrix. The contract:

* ``streamable``  — capability flag: the fusion can fold blocks into a
  bounded carry state (defaults to ``reducible``).
* ``weighted``    — the fold consumes real client weights / staleness
  scales. Order-statistic reducers set this False: the engine passes a
  0/1 validity row instead and per-row scales are rejected.
* ``init_state(dim, n_hint)``  -> state pytree of jnp leaves.
* ``fold_block(state, payload, weights, scale)``  -> state. Runs inside
  the engine's AOT-compiled step executable; ``partial``/``carve``
  kwargs let an engine inject its strategy-specific implementation
  (Pallas weighted-sum / top-k carve kernels) without owning semantics.
* ``finalize(state)``  -> (P,). Runs OUTSIDE compiled artifacts (server
  optimizer state mutation, data-dependent trim counts live here).
* ``state_signature(dim, n_hint)`` — hashable tuple mixed into the
  engines' compile-cache keys so carry-state shapes key executables.
* ``state_nbytes(dim, n_hint)`` — carry footprint, for the service's
  robust state budget gate.
* ``discount_state(state, gamma)`` — staleness discount of a carried
  state between async rounds; only weighted (sum) states support it.

For the reducible family the state is exactly the historical
``(weighted_sum, weight_sum)`` tuple and finalize is ``combine``, so
streamed results stay bit-identical with the pre-protocol engines.
"""
from __future__ import annotations

import abc
from typing import Callable, Optional, Tuple

import jax.numpy as jnp


def dequant_payload(payload, dim: int) -> jnp.ndarray:
    """In-trace dequantization of a compressed (codes, scales) payload to
    a dense (rows, dim) fp32 block. codes: (rows, nblocks*blk) int8;
    scales: (rows, nblocks) fp32. Matches CompressedBlock.dequantize."""
    codes, scales = payload
    rows, pq = codes.shape
    nblocks = scales.shape[1]
    blk = pq // nblocks
    u = codes.astype(jnp.float32).reshape(rows, nblocks, blk)
    u = (u * scales[:, :, None]).reshape(rows, pq)
    return u[:, :dim]


class FusionAlgorithm(abc.ABC):
    """Base class. Subclasses are stateless and jit-friendly."""

    name: str = "base"
    reducible: bool = False
    coordinatewise: bool = False

    # the streamed fold consumes real client weights (and staleness
    # scales). Order-statistic reducers override to False: the engine
    # then passes a 0/1 validity row and rejects per-row scales.
    weighted: bool = True

    # set when per-client full-row norms are needed before the weighted sum
    # (e.g. ClippedAvg) — the distributed engine psums squared norms across
    # parameter shards and calls partial_with_norms instead of partial.
    needs_row_norms: bool = False

    @abc.abstractmethod
    def fuse(self, updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
        """updates: (n, P); weights: (n,) fp32. Returns (P,)."""

    # -- hooks for the reducible (map-reduce) path -------------------------
    def effective_weights(self, weights: jnp.ndarray) -> jnp.ndarray:
        """Normalize the weight semantics BEFORE any padding, so padded
        rows (weight 0) never contribute. IterAvg overrides to ones."""
        return weights

    def partial(self, updates: jnp.ndarray, weights: jnp.ndarray):
        """Local 'map' stage: returns (weighted_sum (P,), weight_sum ())."""
        raise NotImplementedError(f"{self.name} is not reducible")

    def partial_with_norms(self, updates, weights, row_norms):
        """Like partial() but given exact full-row L2 norms (n,)."""
        raise NotImplementedError(f"{self.name} does not use row norms")

    def combine(self, weighted_sum: jnp.ndarray, weight_sum: jnp.ndarray):
        """Final 'reduce' stage after summing partials across shards."""
        raise NotImplementedError(f"{self.name} is not reducible")

    # -- streaming reducer protocol ---------------------------------------
    @property
    def streamable(self) -> bool:
        """Whether the fusion can fold streamed blocks into a bounded
        carry state. Sum-reducible fusions stream by construction."""
        return self.reducible

    def init_state(self, dim: int, n_hint: Optional[int] = None):
        """Fresh carry state for a streamed round over ``dim`` params.
        ``n_hint`` is the expected client count — order-statistic
        reducers size their top-k buffers from it."""
        if not self.reducible:
            raise NotImplementedError(f"{self.name} is not streamable")
        del n_hint
        return (jnp.zeros((dim,), jnp.float32), jnp.zeros((), jnp.float32))

    def fold_block(self, state, payload, weights, scale=None, *,
                   partial: Optional[Callable] = None,
                   carve: Optional[Callable] = None):
        """Fold one (rows, P) block (dense array or compressed
        (codes, scales) payload) into ``state``. ``weights`` is the
        per-row weight vector — a 0/1 validity row for unweighted
        fusions. ``scale`` is a scalar staleness discount applied to
        this block's contribution (weighted fusions fold it into the
        weights before calling). ``partial``/``carve`` are optional
        engine-supplied kernels."""
        del carve, scale
        if not self.reducible:
            raise NotImplementedError(f"{self.name} is not streamable")
        fn = partial if partial is not None else self.partial
        if isinstance(payload, tuple) and partial is None:
            payload = dequant_payload(payload, state[0].shape[0])
        wsum, tot = fn(payload, weights)
        return (state[0] + wsum, state[1] + tot)

    def finalize(self, state) -> jnp.ndarray:
        """Carry state -> fused (P,). Runs outside compiled artifacts."""
        if not self.reducible:
            raise NotImplementedError(f"{self.name} is not streamable")
        return self.combine(state[0], state[1])

    def state_signature(self, dim: int,
                        n_hint: Optional[int] = None) -> Tuple:
        """Hashable description of the carry state's shapes, mixed into
        engine compile-cache keys."""
        if not self.reducible:
            raise NotImplementedError(f"{self.name} is not streamable")
        del n_hint
        return ("sum", dim)

    def state_nbytes(self, dim: int, n_hint: Optional[int] = None) -> int:
        """Bytes of carry state for a streamed round (budget gate)."""
        if not self.reducible:
            raise NotImplementedError(f"{self.name} is not streamable")
        del n_hint
        return 4 * (dim + 1)

    def discount_state(self, state, gamma: float):
        """Staleness-discount a carried state between async rounds."""
        if not self.reducible:
            raise NotImplementedError(f"{self.name} is not streamable")
        return (gamma * state[0], gamma * state[1])

    def __repr__(self) -> str:
        return f"<fusion:{self.name}>"


EPS = 1e-6  # the paper's epsilon in Eq. (1)
