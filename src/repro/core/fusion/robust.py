"""Byzantine-robust fusion algorithms (the paper's §V future work,
implemented here as beyond-paper substance).

CoordMedian  — coordinate-wise median (Yin et al., ICML'18).
TrimmedMean  — coordinate-wise beta-trimmed mean (Yin et al.).
Krum / MultiKrum — Blanchard et al., NeurIPS'17: pick update(s) with the
               smallest sum of distances to their n-f-2 nearest neighbours.
Zeno         — Xie et al.: score updates by estimated descent against a
               validation gradient; average the top (n - b).
GeometricMedian — smoothed Weiszfeld iterations.

Distribution notes: median/trimmed-mean are ``coordinatewise`` (the
distributed engine re-shards coordinates). Krum/Zeno/geomed expose partial
statistics that are psum-reducible across parameter shards (pairwise Gram
blocks / score terms), so no device ever needs a full update row.

Streaming (the reducer protocol in ``base.py``): trimmed mean and median
stream EXACTLY via per-coordinate top-k/bottom-k carving. The carry is
``(sum (P,), count (), topk (K, P), botk (K, P))`` — running column sum
plus the K largest and K smallest values seen per coordinate — and

    trimmed_mean = (sum - sum(top_k) - sum(bot_k)) / (n - 2k)

with k = trim_count(n) <= K. The median is the same carve with
k = (n-1)//2: one survivor for odd n, the mean of the two central
values for even n — identical to ``jnp.median``. O(K*P) carry instead
of O(n*P) dense. K is sized from ``n_hint`` at ``init_state``;
``finalize`` clamps k = min(trim_count(count), K) so async rounds that
close with a different arrival count stay well-defined.

Sentinel safety: ``topk`` is ascending and initialized to -inf (real
values fill from the END), ``botk`` ascending initialized to +inf (real
values fill from the START). After folding ``count`` real rows, the
slices ``topk[K-k:]`` / ``botk[:k]`` hold only real values whenever
k <= count, so sentinels never reach the finalize arithmetic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.fusion.base import EPS, FusionAlgorithm, dequant_payload


def carve_merge(block, valid, ssum, topk, botk):
    """Reference fold: merge a (rows, P) block into the carried
    per-coordinate extremes. ``valid`` is the (rows,) 0/1 row mask
    (0 = padded row). Returns updated (ssum, topk, botk). The Pallas
    kernel in ``kernels/robust_fusion`` computes the same merge tiled."""
    u = block.astype(jnp.float32)
    k_cap = topk.shape[0]
    vm = (valid > 0)[:, None]
    ssum = ssum + jnp.sum(jnp.where(vm, u, 0.0), axis=0)
    hi = jnp.where(vm, u, -jnp.inf)
    topk = jnp.sort(jnp.concatenate([topk, hi], axis=0), axis=0)[-k_cap:]
    lo = jnp.where(vm, u, jnp.inf)
    botk = jnp.sort(jnp.concatenate([botk, lo], axis=0), axis=0)[:k_cap]
    return ssum, topk, botk


class _CarveStream:
    """Streaming mixin for order-statistic (carve) reducers. Subclasses
    define ``trim_count(n)`` — how many extremes to drop per side."""

    weighted = False

    @property
    def streamable(self) -> bool:
        return True

    def trim_count(self, n: int) -> int:
        raise NotImplementedError

    def _capacity(self, n_hint: int) -> int:
        return max(int(self.trim_count(int(n_hint))), 1)

    def init_state(self, dim, n_hint=None):
        if n_hint is None:
            raise ValueError(
                f"{self.name}: streaming needs n_hint (expected client "
                "count) to size the top-k carve buffers")
        k_cap = self._capacity(n_hint)
        return (
            jnp.zeros((dim,), jnp.float32),
            jnp.zeros((), jnp.float32),
            jnp.full((k_cap, dim), -jnp.inf, jnp.float32),
            jnp.full((k_cap, dim), jnp.inf, jnp.float32),
        )

    def fold_block(self, state, payload, weights, scale=None, *,
                   partial=None, carve=None):
        del partial
        if scale is not None:
            raise ValueError(
                f"{self.name}: order statistics cannot discount rows — "
                "staleness scales are unsupported")
        ssum, cnt, topk, botk = state
        if isinstance(payload, tuple):
            payload = dequant_payload(payload, ssum.shape[0])
        fn = carve if carve is not None else carve_merge
        ssum, topk, botk = fn(payload, weights, ssum, topk, botk)
        cnt = cnt + jnp.sum(weights)
        return (ssum, cnt, topk, botk)

    def finalize(self, state):
        ssum, cnt, topk, botk = state
        n = int(cnt)
        if n <= 0:
            raise ValueError(f"{self.name}: empty round (count == 0)")
        k_cap = topk.shape[0]
        k = min(int(self.trim_count(n)), k_cap)
        s = ssum
        if k > 0:
            s = s - jnp.sum(topk[k_cap - k:], axis=0)
            s = s - jnp.sum(botk[:k], axis=0)
        return s / float(n - 2 * k)

    def state_signature(self, dim, n_hint=None):
        if n_hint is None:
            raise ValueError(f"{self.name}: state_signature needs n_hint")
        return ("carve", dim, self._capacity(n_hint))

    def state_nbytes(self, dim, n_hint=None) -> int:
        if n_hint is None:
            raise ValueError(f"{self.name}: state_nbytes needs n_hint")
        return 4 * (dim * (1 + 2 * self._capacity(n_hint)) + 1)

    def discount_state(self, state, gamma):
        raise ValueError(
            f"{self.name}: carried order-statistic state cannot be "
            "staleness-discounted")


class CoordMedian(_CarveStream, FusionAlgorithm):
    name = "coordmedian"
    coordinatewise = True

    def trim_count(self, n: int) -> int:
        # median == trimmed mean that drops all but the central 1 or 2
        return max((int(n) - 1) // 2, 0)

    def fuse(self, updates, weights):
        del weights
        return jnp.median(updates.astype(jnp.float32), axis=0)


@dataclasses.dataclass
class TrimmedMean(_CarveStream, FusionAlgorithm):
    """Drop the beta-fraction largest and smallest per coordinate."""

    beta: float = 0.1
    name = "trimmedmean"
    coordinatewise = True

    def trim_count(self, n: int) -> int:
        # clamp so 2k < n: int(n*beta) can otherwise empty the slice
        # (n=4, beta=0.5 -> k=2 -> mean of zero rows -> NaN)
        n = int(n)
        return max(min(int(n * self.beta), (n - 1) // 2), 0)

    def fuse(self, updates, weights):
        del weights
        n = updates.shape[0]
        k = self.trim_count(n)
        s = jnp.sort(updates.astype(jnp.float32), axis=0)
        if k > 0:
            s = s[k: n - k]
        return jnp.mean(s, axis=0)


@dataclasses.dataclass
class Krum(FusionAlgorithm):
    """(Multi-)Krum. ``n_byzantine`` is the assumed attacker count f;
    ``m`` the number of selected updates to average (1 = classic Krum)."""

    n_byzantine: int = 1
    m: int = 1
    name = "krum"

    def scores_from_gram(self, gram: jnp.ndarray) -> jnp.ndarray:
        """Krum scores from the Gram matrix G = U U^T (n, n)."""
        n = gram.shape[0]
        sq = jnp.diag(gram)
        d2 = sq[:, None] + sq[None, :] - 2.0 * gram      # pairwise ||.||^2
        d2 = d2 + jnp.eye(n) * 1e30                      # exclude self
        k = max(n - self.n_byzantine - 2, 1)
        neg_smallest, _ = jax.lax.top_k(-d2, k)          # k nearest
        return jnp.sum(-neg_smallest, axis=1)            # (n,)

    def select_from_gram(self, gram: jnp.ndarray) -> jnp.ndarray:
        scores = self.scores_from_gram(gram)
        _, idx = jax.lax.top_k(-scores, self.m)
        return idx

    def fuse(self, updates, weights):
        del weights
        u = updates.astype(jnp.float32)
        gram = u @ u.T
        idx = self.select_from_gram(gram)
        return jnp.mean(u[idx], axis=0)


@dataclasses.dataclass
class Zeno(FusionAlgorithm):
    """Zeno scoring against a validation gradient g_val:
    score_i = <u_i, g_val> - rho * ||u_i||^2. Averages the best n - b.
    ``g_val`` is bound per-round by the engine (set_val_grad)."""

    rho: float = 1e-3
    n_suspect: int = 1
    name = "zeno"

    def __post_init__(self):
        self._g_val = None

    def set_val_grad(self, g_val: jnp.ndarray) -> None:
        """Bind g_val IN PLACE. Mutates shared state — under concurrent
        tenants prefer ``with_val_grad`` (or the service's per-call
        ``aggregate(val_grad=...)``), which never touches this instance."""
        self._g_val = g_val

    def with_val_grad(self, g_val) -> "Zeno":
        """Return a clone with ``g_val`` bound, leaving this instance
        untouched (safe under concurrent multi-tenant rounds)."""
        clone = dataclasses.replace(self)
        clone._g_val = (None if g_val is None
                        else jnp.asarray(g_val, jnp.float32))
        return clone

    def scores(self, inner: jnp.ndarray, sqnorm: jnp.ndarray) -> jnp.ndarray:
        """inner: (n,) <u_i, g_val>; sqnorm: (n,) ||u_i||^2."""
        return inner - self.rho * sqnorm

    def fuse(self, updates, weights):
        del weights
        u = updates.astype(jnp.float32)
        g = self._g_val
        if g is None:
            g = jnp.mean(u, axis=0)  # self-referential fallback
        s = self.scores(u @ g, jnp.sum(u * u, axis=1))
        n = u.shape[0]
        keep = max(n - self.n_suspect, 1)
        _, idx = jax.lax.top_k(s, keep)
        return jnp.mean(u[idx], axis=0)


@dataclasses.dataclass
class GeometricMedian(FusionAlgorithm):
    """Smoothed Weiszfeld (RFA, Pillutla et al.)."""

    iters: int = 8
    smooth: float = 1e-6
    name = "geomedian"

    def fuse(self, updates, weights):
        u = updates.astype(jnp.float32)
        w = weights.astype(jnp.float32)
        w = w / (jnp.sum(w) + EPS)
        z = jnp.einsum("np,n->p", u, w)

        def step(z, _):
            d = jnp.linalg.norm(u - z[None, :], axis=1)
            beta = w / jnp.maximum(d, self.smooth)
            beta = beta / jnp.sum(beta)
            return jnp.einsum("np,n->p", u, beta), None

        z, _ = jax.lax.scan(step, z, None, length=self.iters)
        return z
