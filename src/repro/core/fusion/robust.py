"""Byzantine-robust fusion algorithms (the paper's §V future work,
implemented here as beyond-paper substance).

CoordMedian  — coordinate-wise median (Yin et al., ICML'18).
TrimmedMean  — coordinate-wise beta-trimmed mean (Yin et al.).
Krum / MultiKrum — Blanchard et al., NeurIPS'17: pick update(s) with the
               smallest sum of distances to their n-f-2 nearest neighbours.
Zeno         — Xie et al.: score updates by estimated descent against a
               validation gradient; average the top (n - b).
GeometricMedian — smoothed Weiszfeld iterations.

Distribution notes: median/trimmed-mean are ``coordinatewise`` (the
distributed engine re-shards coordinates). Krum/Zeno/geomed expose partial
statistics that are psum-reducible across parameter shards (pairwise Gram
blocks / score terms), so no device ever needs a full update row.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.fusion.base import EPS, FusionAlgorithm


class CoordMedian(FusionAlgorithm):
    name = "coordmedian"
    coordinatewise = True

    def fuse(self, updates, weights):
        del weights
        return jnp.median(updates.astype(jnp.float32), axis=0)


@dataclasses.dataclass
class TrimmedMean(FusionAlgorithm):
    """Drop the beta-fraction largest and smallest per coordinate."""

    beta: float = 0.1
    name = "trimmedmean"
    coordinatewise = True

    def fuse(self, updates, weights):
        del weights
        n = updates.shape[0]
        k = int(n * self.beta)
        s = jnp.sort(updates.astype(jnp.float32), axis=0)
        if k > 0:
            s = s[k: n - k]
        return jnp.mean(s, axis=0)


@dataclasses.dataclass
class Krum(FusionAlgorithm):
    """(Multi-)Krum. ``n_byzantine`` is the assumed attacker count f;
    ``m`` the number of selected updates to average (1 = classic Krum)."""

    n_byzantine: int = 1
    m: int = 1
    name = "krum"

    def scores_from_gram(self, gram: jnp.ndarray) -> jnp.ndarray:
        """Krum scores from the Gram matrix G = U U^T (n, n)."""
        n = gram.shape[0]
        sq = jnp.diag(gram)
        d2 = sq[:, None] + sq[None, :] - 2.0 * gram      # pairwise ||.||^2
        d2 = d2 + jnp.eye(n) * 1e30                      # exclude self
        k = max(n - self.n_byzantine - 2, 1)
        neg_smallest, _ = jax.lax.top_k(-d2, k)          # k nearest
        return jnp.sum(-neg_smallest, axis=1)            # (n,)

    def select_from_gram(self, gram: jnp.ndarray) -> jnp.ndarray:
        scores = self.scores_from_gram(gram)
        _, idx = jax.lax.top_k(-scores, self.m)
        return idx

    def fuse(self, updates, weights):
        del weights
        u = updates.astype(jnp.float32)
        gram = u @ u.T
        idx = self.select_from_gram(gram)
        return jnp.mean(u[idx], axis=0)


@dataclasses.dataclass
class Zeno(FusionAlgorithm):
    """Zeno scoring against a validation gradient g_val:
    score_i = <u_i, g_val> - rho * ||u_i||^2. Averages the best n - b.
    ``g_val`` is bound per-round by the engine (set_val_grad)."""

    rho: float = 1e-3
    n_suspect: int = 1
    name = "zeno"

    def __post_init__(self):
        self._g_val = None

    def set_val_grad(self, g_val: jnp.ndarray) -> None:
        self._g_val = g_val

    def scores(self, inner: jnp.ndarray, sqnorm: jnp.ndarray) -> jnp.ndarray:
        """inner: (n,) <u_i, g_val>; sqnorm: (n,) ||u_i||^2."""
        return inner - self.rho * sqnorm

    def fuse(self, updates, weights):
        del weights
        u = updates.astype(jnp.float32)
        g = self._g_val
        if g is None:
            g = jnp.mean(u, axis=0)  # self-referential fallback
        s = self.scores(u @ g, jnp.sum(u * u, axis=1))
        n = u.shape[0]
        keep = max(n - self.n_suspect, 1)
        _, idx = jax.lax.top_k(s, keep)
        return jnp.mean(u[idx], axis=0)


@dataclasses.dataclass
class GeometricMedian(FusionAlgorithm):
    """Smoothed Weiszfeld (RFA, Pillutla et al.)."""

    iters: int = 8
    smooth: float = 1e-6
    name = "geomedian"

    def fuse(self, updates, weights):
        u = updates.astype(jnp.float32)
        w = weights.astype(jnp.float32)
        w = w / (jnp.sum(w) + EPS)
        z = jnp.einsum("np,n->p", u, w)

        def step(z, _):
            d = jnp.linalg.norm(u - z[None, :], axis=1)
            beta = w / jnp.maximum(d, self.smooth)
            beta = beta / jnp.sum(beta)
            return jnp.einsum("np,n->p", u, beta), None

        z, _ = jax.lax.scan(step, z, None, length=self.iters)
        return z
