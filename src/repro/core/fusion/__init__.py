"""Fusion algorithm library (IBMFL-compatible set + robust extensions)."""
from repro.core.fusion.base import EPS, FusionAlgorithm
from repro.core.fusion.averaging import ClippedAvg, FedAvg, GradAvg, IterAvg
from repro.core.fusion.robust import (
    CoordMedian,
    GeometricMedian,
    Krum,
    TrimmedMean,
    Zeno,
)
from repro.core.fusion.serveropt import FedAdam, FedAvgM

REGISTRY = {
    "fedavg": FedAvg,
    "iteravg": IterAvg,
    "gradavg": GradAvg,
    "clippedavg": ClippedAvg,
    "coordmedian": CoordMedian,
    "trimmedmean": TrimmedMean,
    "krum": Krum,
    "zeno": Zeno,
    "geomedian": GeometricMedian,
    "fedavgm": FedAvgM,
    "fedadam": FedAdam,
}


def get_fusion(name: str, **kw) -> FusionAlgorithm:
    return REGISTRY[name](**kw)


__all__ = [
    "EPS",
    "FusionAlgorithm",
    "FedAvg",
    "IterAvg",
    "GradAvg",
    "ClippedAvg",
    "CoordMedian",
    "TrimmedMean",
    "Krum",
    "Zeno",
    "GeometricMedian",
    "FedAvgM",
    "FedAdam",
    "REGISTRY",
    "get_fusion",
]
