"""Averaging-family fusion algorithms (paper §III-A: 'averaging is the
common building block of most fusion algorithms').

FedAvg  — Eq. (1): M = sum_i w_i * u_i / (n_total + eps), w_i = sample
          counts (IBMFL FedAvgFusionHandler semantics).
IterAvg — unweighted mean (IBMFL IterAvgFusionHandler).
GradAvg — weighted gradient mean (server applies it as a gradient).
ClippedAvg — per-update L2 clip to a threshold, then FedAvg.
FedAvgM/server-momentum and FedAdam live in serveropt.py.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.fusion.base import EPS, FusionAlgorithm


class FedAvg(FusionAlgorithm):
    name = "fedavg"
    reducible = True

    def fuse(self, updates, weights):
        wsum, tot = self.partial(updates, weights)
        return self.combine(wsum, tot)

    def partial(self, updates, weights):
        w = weights.astype(jnp.float32)
        wsum = jnp.einsum("np,n->p", updates.astype(jnp.float32), w)
        return wsum, jnp.sum(w)

    def combine(self, weighted_sum, weight_sum):
        return weighted_sum / (weight_sum + EPS)


class IterAvg(FusionAlgorithm):
    """Unweighted mean. ``effective_weights`` maps everything to 1 so the
    reduction is pad-safe (padded rows carry weight 0)."""

    name = "iteravg"
    reducible = True

    def effective_weights(self, weights):
        return jnp.ones_like(jnp.asarray(weights, jnp.float32))

    def fuse(self, updates, weights):
        w = self.effective_weights(
            weights if weights is not None
            else jnp.ones((updates.shape[0],), jnp.float32)
        )
        wsum, tot = self.partial(updates, w)
        return self.combine(wsum, tot)

    def partial(self, updates, weights):
        w = weights.astype(jnp.float32)
        return jnp.einsum(
            "np,n->p", updates.astype(jnp.float32), w
        ), jnp.sum(w)

    def combine(self, weighted_sum, weight_sum):
        return weighted_sum / (weight_sum + EPS)


class GradAvg(FusionAlgorithm):
    """Same reduction as FedAvg; semantically the inputs are gradients and
    the server optimizer (optim/) applies the fused result."""

    name = "gradavg"
    reducible = True

    def fuse(self, updates, weights):
        wsum, tot = self.partial(updates, weights)
        return self.combine(wsum, tot)

    partial = FedAvg.partial
    combine = FedAvg.combine


@dataclasses.dataclass
class ClippedAvg(FusionAlgorithm):
    """L2-clip each update to ``clip_norm`` then weighted-average.
    Still reducible: the clip is per-client (map side)."""

    clip_norm: float = 10.0
    name = "clippedavg"
    reducible = True
    needs_row_norms = True  # the clip norm is over the FULL row

    def fuse(self, updates, weights):
        norms = jnp.linalg.norm(updates.astype(jnp.float32), axis=1)
        wsum, tot = self.partial_with_norms(updates, weights, norms)
        return self.combine(wsum, tot)

    def partial(self, updates, weights):
        # single-shard case: local norms ARE the full norms
        norms = jnp.linalg.norm(updates.astype(jnp.float32), axis=1)
        return self.partial_with_norms(updates, weights, norms)

    def partial_with_norms(self, updates, weights, row_norms):
        w = weights.astype(jnp.float32)
        scale = jnp.minimum(1.0, self.clip_norm / (row_norms + EPS))
        clipped = updates.astype(jnp.float32) * scale[:, None]
        return jnp.einsum("np,n->p", clipped, w), jnp.sum(w)

    def combine(self, weighted_sum, weight_sum):
        return weighted_sum / (weight_sum + EPS)
