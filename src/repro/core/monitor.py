"""Monitor — Algorithm 1's ``monitor(T_h, P)``: wait until a threshold
count of client updates has landed in the store, or a timeout elapses
(straggler control). The clock is injectable for deterministic tests.

``wait()`` is the serialized gate (block, then aggregate). The async
round mode instead threads ``should_close`` into
``UpdateStore.iter_arrivals`` so the SAME threshold/timeout policy
decides when an in-flight arrival stream closes — the aggregator folds
partial sums for the whole window the serialized path spends idle.

The gate is PLUGGABLE: pass ``policy`` (any ``(count, waited) -> bool``
predicate, e.g. a learned ``repro.core.adaptive.ClosePolicy``) to
replace the built-in static threshold/timeout test while keeping the
wait loop, injectable clock, and result reporting."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core.store import UpdateStore


@dataclasses.dataclass
class MonitorResult:
    ready: bool           # threshold reached (False -> timed out)
    count: int            # updates present when the monitor returned
    waited: float         # seconds waited


class Monitor:
    """Round-close gate over an :class:`UpdateStore`.

    ``threshold`` / ``timeout`` define the static gate and the
    ``ready`` semantics of :class:`MonitorResult`; ``policy`` (optional)
    overrides the close predicate itself — the adaptive controller
    passes its learned :class:`~repro.core.adaptive.ClosePolicy` here
    with ``threshold`` / ``timeout`` mirroring the learned values so
    reporting stays truthful. ``tenant`` scopes the count to one store
    partition, so concurrent tenants' monitors never gate on each
    other's arrivals (``None``: whole spool, the single-tenant
    behavior). ``clock`` / ``sleep`` are injectable for deterministic
    tests.

    Concurrent-round note: each round owns its own Monitor instance
    (nothing here is shared), and N tenants' monitors may block in
    ``wait()`` simultaneously — the store's arrival condition is
    spool-global, so any tenant's write wakes every waiter, each
    re-checks its OWN tenant's O(1) count, and non-owners go back to
    sleep. Spurious wakes cost one counter read; arrivals are never
    missed."""

    def __init__(
        self,
        store: UpdateStore,
        threshold: int,
        timeout: float = 30.0,
        poll_interval: float = 0.01,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        policy: Optional[Callable[[int, float], bool]] = None,
        tenant: Optional[str] = None,
    ):
        self.store = store
        self.threshold = threshold
        self.timeout = timeout
        self.poll_interval = poll_interval
        self.clock = clock
        self.sleep = sleep
        self.policy = policy
        self.tenant = tenant

    def should_close(self, count: int, waited: float) -> bool:
        """The gate, as a pure predicate: True once the threshold is met
        OR the timeout has elapsed. Threshold wins when both land on the
        same poll (a round that fills exactly at the deadline is ready).
        With a pluggable ``policy`` installed, that predicate decides
        instead."""
        if self.policy is not None:
            return self.policy(count, waited)
        return count >= self.threshold or waited >= self.timeout

    def result(self, count: int, waited: float) -> MonitorResult:
        """Structured outcome for a gate that closed at (count, waited)."""
        return MonitorResult(
            ready=count >= self.threshold, count=count, waited=waited
        )

    def wait(self) -> MonitorResult:
        start = self.clock()
        while True:
            count = self.store.count(self.tenant)
            waited = self.clock() - start
            if self.should_close(count, waited):
                return self.result(count, waited)
            # event-driven under the real clock (woken by the store's
            # arrival condition); injected sleeps drive scripted time
            self.store.wait_for_arrival(self.poll_interval, self.sleep)
