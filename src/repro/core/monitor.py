"""Monitor — Algorithm 1's ``monitor(T_h, P)``: wait until a threshold
count of client updates has landed in the store, or a timeout elapses
(straggler control). The clock is injectable for deterministic tests."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core.store import UpdateStore


@dataclasses.dataclass
class MonitorResult:
    ready: bool           # threshold reached (False -> timed out)
    count: int            # updates present when the monitor returned
    waited: float         # seconds waited


class Monitor:
    def __init__(
        self,
        store: UpdateStore,
        threshold: int,
        timeout: float = 30.0,
        poll_interval: float = 0.01,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.store = store
        self.threshold = threshold
        self.timeout = timeout
        self.poll_interval = poll_interval
        self.clock = clock
        self.sleep = sleep

    def wait(self) -> MonitorResult:
        start = self.clock()
        while True:
            count = self.store.count()
            waited = self.clock() - start
            if count >= self.threshold:
                return MonitorResult(ready=True, count=count, waited=waited)
            if waited >= self.timeout:
                return MonitorResult(ready=False, count=count, waited=waited)
            self.sleep(self.poll_interval)
