"""Monitor — Algorithm 1's ``monitor(T_h, P)``: wait until a threshold
count of client updates has landed in the store, or a timeout elapses
(straggler control). The clock is injectable for deterministic tests.

``wait()`` is the serialized gate (block, then aggregate). The async
round mode instead threads ``should_close`` into
``UpdateStore.iter_arrivals`` so the SAME threshold/timeout policy
decides when an in-flight arrival stream closes — the aggregator folds
partial sums for the whole window the serialized path spends idle."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core.store import UpdateStore


@dataclasses.dataclass
class MonitorResult:
    ready: bool           # threshold reached (False -> timed out)
    count: int            # updates present when the monitor returned
    waited: float         # seconds waited


class Monitor:
    def __init__(
        self,
        store: UpdateStore,
        threshold: int,
        timeout: float = 30.0,
        poll_interval: float = 0.01,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.store = store
        self.threshold = threshold
        self.timeout = timeout
        self.poll_interval = poll_interval
        self.clock = clock
        self.sleep = sleep

    def should_close(self, count: int, waited: float) -> bool:
        """The gate, as a pure predicate: True once the threshold is met
        OR the timeout has elapsed. Threshold wins when both land on the
        same poll (a round that fills exactly at the deadline is ready)."""
        return count >= self.threshold or waited >= self.timeout

    def result(self, count: int, waited: float) -> MonitorResult:
        """Structured outcome for a gate that closed at (count, waited)."""
        return MonitorResult(
            ready=count >= self.threshold, count=count, waited=waited
        )

    def wait(self) -> MonitorResult:
        start = self.clock()
        while True:
            count = self.store.count()
            waited = self.clock() - start
            if self.should_close(count, waited):
                return self.result(count, waited)
            self.sleep(self.poll_interval)
