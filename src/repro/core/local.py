"""Single-node aggregation engines (paper §III-D1).

``jnp`` strategy  — the faithful baseline: plain dense ops on one device,
                    the analogue of the frameworks' single-threaded NumPy.
``pallas`` strategy — the TPU analogue of the paper's Numba path: the
                    streaming fused kernel (one HBM pass, VMEM tiling).

Both support *chunked streaming* for reducible fusions so a memory-capped
node can still aggregate more clients than fit at once (the knob used by
the Fig. 1/2 memory-wall benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion.base import FusionAlgorithm
from repro.kernels.fused_fusion.kernel import weighted_sum_pallas
from repro.kernels.robust_fusion.kernel import (
    coordmedian_pallas,
    trimmedmean_pallas,
)


@dataclasses.dataclass
class LocalEngine:
    """Fuses on the local device."""

    strategy: str = "jnp"        # "jnp" | "pallas"
    memory_cap_bytes: Optional[int] = None  # simulate a memory-limited node
    interpret: bool = True       # pallas interpret mode (CPU container)

    name: str = "local"

    def fuse(self, fusion: FusionAlgorithm, updates, weights) -> jnp.ndarray:
        updates = jnp.asarray(updates)
        if weights is None:
            weights = jnp.ones((updates.shape[0],), jnp.float32)
        weights = fusion.effective_weights(jnp.asarray(weights, jnp.float32))
        n, P = updates.shape
        batch_bytes = updates.dtype.itemsize * P

        if self.memory_cap_bytes is not None:
            max_rows = max(int(self.memory_cap_bytes // max(batch_bytes, 1)), 1)
            if max_rows < n:
                if not fusion.reducible:
                    raise MemoryError(
                        f"{fusion.name}: {n} updates x {batch_bytes} B exceed "
                        f"the {self.memory_cap_bytes} B cap and the fusion "
                        "is not streamable — classify as DISTRIBUTED"
                    )
                return self._streamed(fusion, updates, weights, max_rows)

        if fusion.reducible:
            wsum, tot = self._partial(fusion, updates, weights)
            return fusion.combine(wsum, tot)
        if self.strategy == "pallas" and fusion.name == "coordmedian":
            return coordmedian_pallas(updates, interpret=self.interpret)
        if self.strategy == "pallas" and fusion.name == "trimmedmean":
            trim = int(n * fusion.beta)
            return trimmedmean_pallas(updates, trim, interpret=self.interpret)
        return fusion.fuse(updates, weights)

    # -- internals ----------------------------------------------------------
    def _partial(self, fusion, updates, weights):
        if self.strategy == "pallas" and fusion.name in (
            "fedavg", "gradavg", "iteravg", "fedavgm", "fedadam"
        ):
            w = (
                jnp.ones_like(weights) if fusion.name == "iteravg" else weights
            )
            wsum = weighted_sum_pallas(updates, w, interpret=self.interpret)
            return wsum, jnp.sum(w)
        return fusion.partial(updates, weights)

    def _streamed(self, fusion, updates, weights, max_rows) -> jnp.ndarray:
        """Accumulate reducible partials over client chunks — bounded
        resident set (the single-node answer to the memory wall)."""
        n = updates.shape[0]
        wsum = None
        tot = None
        for lo in range(0, n, max_rows):
            hi = min(lo + max_rows, n)
            ws, t = self._partial(fusion, updates[lo:hi], weights[lo:hi])
            wsum = ws if wsum is None else wsum + ws
            tot = t if tot is None else tot + t
        return fusion.combine(wsum, tot)
