"""Single-node aggregation engines (paper §III-D1).

``jnp`` strategy  — the faithful baseline: plain dense ops on one device,
                    the analogue of the frameworks' single-threaded NumPy.
``pallas`` strategy — the TPU analogue of the paper's Numba path: the
                    streaming fused kernel (one HBM pass, VMEM tiling).

Both support *chunked streaming* for reducible fusions so a memory-capped
node can still aggregate more clients than fit at once (the knob used by
the Fig. 1/2 memory-wall benchmarks).

Compiled paths persist across rounds (the tentpole):

  * dense reducible rounds bucket the client count to the next power of
    two (zero-weight padded rows) and reuse ONE AOT-compiled executable
    per (fusion, bucket, P, dtype) — elastic rounds stop re-tracing;
  * the memory-capped path is a single ``lax.scan`` over fixed-size
    client chunks (ONE executable) instead of the seed's Python loop of
    per-chunk jit dispatches;
  * ``fuse_stream`` consumes (chunk, P) blocks straight off an
    ``UpdateStore.iter_chunks`` iterator — the dense (n, P) matrix never
    exists on the host — accumulating with one cached step executable.

``combine`` always runs OUTSIDE the compiled artifacts because FedAvgM /
FedAdam carry python-side server state that must advance every round.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import BLOCK, CompressedBlock
from repro.core.fusion.base import FusionAlgorithm
from repro.kernels.fused_fusion.kernel import (
    weighted_sum_dequant_pallas,
    weighted_sum_pallas,
)
from repro.kernels.robust_fusion.kernel import (
    coordmedian_pallas,
    topk_carve_pallas,
    trimmedmean_pallas,
)
from repro.utils.jitcache import CompiledCache, bucket_rows, fusion_cache_key

# fusions whose weighted-sum partial routes through the fused Pallas kernel
_PALLAS_WSUM = ("fedavg", "gradavg", "iteravg", "fedavgm", "fedadam")


def _check_scale(scale) -> np.ndarray:
    """A block's optional third element must be a NUMERIC per-row scale —
    catch the easy mistake of feeding ``UpdateStore.iter_arrivals``
    (whose third element is the client-id list) to an engine directly."""
    arr = np.asarray(scale)
    if arr.dtype.kind not in "fiu":
        raise TypeError(
            "fuse_stream: blocks must be (updates, weights[, scale]) with "
            f"a numeric per-row scale, got dtype {arr.dtype}; note "
            "UpdateStore.iter_arrivals yields (block, weights, client_ids)"
            " — adapt it (as AggregationService's async round does) before"
            " streaming into an engine"
        )
    return arr


@dataclasses.dataclass
class StreamReport:
    """Phase accounting for one streamed aggregation."""

    ingest_seconds: float = 0.0    # stalls waiting on store blocks
    compile_seconds: float = 0.0   # executable build (0.0 on warm rounds)
    compute_seconds: float = 0.0   # device time in the step executable
    n_rows: int = 0
    n_blocks: int = 0
    chunk_rows: int = 0
    # actual payload bytes ingested (pre-padding; codes + scales for
    # compressed blocks) — what RoundReport.bytes_ingested reports
    ingest_bytes: int = 0
    # pre-finalize carry state (flat tuple of np arrays, the fusion's
    # reducer-state pytree) so async rounds can carry it forward
    acc_state: Optional[tuple] = None
    # the sum-family view of acc_state, kept populated for reducible
    # fusions (back-compat with callers that carry (wsum, tot) directly)
    acc_wsum: Optional[np.ndarray] = None
    acc_tot: float = 0.0


@dataclasses.dataclass
class LocalEngine:
    """Fuses on the local device."""

    strategy: str = "jnp"        # "jnp" | "pallas"
    memory_cap_bytes: Optional[int] = None  # simulate a memory-limited node
    interpret: bool = True       # pallas interpret mode (CPU container)

    name: str = "local"

    def __post_init__(self):
        self.cache = CompiledCache(name=f"local:{self.strategy}")
        # per-THREAD compile accounting: concurrent tenants' rounds share
        # this engine, and one round's warm fold must not read another
        # round's cold compile time (or vice versa)
        self._tls = threading.local()

    @property
    def last_compile_seconds(self) -> float:
        """Compile seconds paid by the CURRENT thread's last fuse call
        (0.0 on warm rounds). Thread-local, so concurrent rounds on a
        shared engine each see their own compile phase."""
        return getattr(self._tls, "compile_seconds", 0.0)

    @last_compile_seconds.setter
    def last_compile_seconds(self, value: float) -> None:
        self._tls.compile_seconds = value

    # -- public --------------------------------------------------------------
    def fuse(
        self, fusion: FusionAlgorithm, updates, weights, device_sem=None,
    ) -> jnp.ndarray:
        """Dense fuse. ``device_sem`` (optional semaphore) bounds
        concurrent device execution like ``fuse_stream``'s. On the
        REDUCIBLE paths (cached executables) it is held only around
        executable invocation — a cold compile builds outside it, so
        one tenant's first-bucket compile never stalls other tenants'
        folds. The pallas order-statistic and fallback paths compile
        lazily inside their first call, so a cold round there holds
        the semaphore through its compile (they have no AOT cache to
        warm separately)."""
        updates = jnp.asarray(updates)
        if weights is None:
            weights = jnp.ones((updates.shape[0],), jnp.float32)
        weights = fusion.effective_weights(jnp.asarray(weights, jnp.float32))
        n, P = updates.shape
        batch_bytes = updates.dtype.itemsize * P
        self.last_compile_seconds = 0.0
        sem = device_sem if device_sem is not None \
            else contextlib.nullcontext()

        if self.memory_cap_bytes is not None:
            max_rows = max(int(self.memory_cap_bytes // max(batch_bytes, 1)), 1)
            if max_rows < n:
                if not fusion.streamable:
                    raise MemoryError(
                        f"{fusion.name}: {n} updates x {batch_bytes} B exceed "
                        f"the {self.memory_cap_bytes} B cap and the fusion "
                        "is not streamable — classify as DISTRIBUTED"
                    )
                if not fusion.reducible:
                    # order-statistic reducer: chunk the dense input
                    # through the streamed carve fold (bounded carry)
                    def chunks():
                        for i in range(0, n, max_rows):
                            yield updates[i: i + max_rows], \
                                weights[i: i + max_rows]

                    fused, _ = self.fuse_stream(
                        fusion, chunks(), chunk_rows=max_rows,
                        device_sem=device_sem, n_hint=n,
                    )
                    return fused
                return self._streamed(fusion, updates, weights, max_rows,
                                      device_sem)

        if fusion.reducible:
            return self._fuse_reducible_dense(fusion, updates, weights,
                                              device_sem)
        if self.strategy == "pallas" and fusion.name == "coordmedian":
            with sem:
                return self._bounded(
                    coordmedian_pallas(updates, interpret=self.interpret),
                    device_sem,
                )
        if self.strategy == "pallas" and fusion.name == "trimmedmean":
            trim = fusion.trim_count(n)
            with sem:
                return self._bounded(
                    trimmedmean_pallas(updates, trim,
                                       interpret=self.interpret),
                    device_sem,
                )
        with sem:
            return self._bounded(fusion.fuse(updates, weights), device_sem)

    @staticmethod
    def _bounded(out, device_sem):
        """Wait for ``out`` while a device semaphore is installed —
        async dispatch would otherwise escape the execution bound."""
        if device_sem is not None:
            jax.block_until_ready(out)
        return out

    def fuse_stream(
        self,
        fusion: FusionAlgorithm,
        blocks: Iterable[Tuple[np.ndarray, ...]],
        init: Optional[tuple] = None,
        chunk_rows: Optional[int] = None,
        device_sem=None,
        n_hint: Optional[int] = None,
    ) -> Tuple[jnp.ndarray, StreamReport]:
        """Fuse a streamable fusion from an iterator of (chunk, P) blocks
        (e.g. ``UpdateStore.iter_chunks``; ``iter_arrivals`` yields client
        ids as its third element, so adapt it — the AggregationService
        async round does — rather than feeding it here directly) without
        ever holding the dense matrix: one cached step executable folds
        each block into the fusion's reducer carry state (the reducer
        protocol in ``fusion/base.py``) — a (P,) fp32 weighted-sum pair
        for the reducible family, the O(K*P) top-k carve state for
        order-statistic fusions. ``n_hint`` (the expected client count)
        sizes order-statistic carve buffers; reducible fusions ignore it.
        Order-statistic (``fusion.weighted == False``) streams ignore
        client weights — the engine passes a 0/1 validity row — and
        reject per-row staleness scales with a ValueError.

        Blocks are ``(updates, weights)`` or ``(updates, weights, scale)``
        — the optional NUMERIC (c,) ``scale`` multiplies the EFFECTIVE
        weights, so staleness discounting bites even for fusions (IterAvg)
        that remap client weights. ``updates`` is a dense (c, P) array OR
        a :class:`repro.core.compress.CompressedBlock` (int8 codes + fp32
        per-block scales): compressed blocks fold WITHOUT host
        dequantization — the pallas strategy folds the scales into the
        weighted-sum kernel, the jnp strategy into the einsum — and a
        single round may freely mix dense and compressed blocks
        (stragglers may be uncompressed): each payload kind gets its own
        cached step executable (the compile cache is keyed by payload
        dtype/shape), all folding into ONE shared (P,) fp32 accumulator.
        ``chunk_rows`` pins the step
        executable's row count (undersized blocks are zero-weight padded):
        pass the configured chunk so elastic/async rounds whose LAST block
        varies still hit one cached executable — the key
        ``is_warm_stream`` probes. Unset, the first block's size is used.
        ``init`` seeds the carry state with a previous round's
        ``acc_state`` — the async carry-over; for reducible fusions this
        is the historical (wsum, tot) tuple. The final pre-finalize state
        is returned on the report (``acc_state``, plus
        ``acc_wsum``/``acc_tot`` for reducible fusions).
        ``device_sem`` (optional semaphore / context manager) bounds
        concurrent DEVICE execution when several rounds stream through
        one engine at once: each block's step and the final combine
        acquire it, while ingest stalls (the straggler wait) stay
        outside — so concurrent tenants overlap their waits but the
        hardware only runs the configured number of folds at a time.
        Returns (fused, StreamReport).

        All carry state (``state``/``step``) is per-call local:
        concurrent ``fuse_stream`` calls on one shared engine never
        cross their folds (only the compile cache is shared, and it is
        single-flight per key)."""
        if not fusion.streamable:
            raise ValueError(
                f"{fusion.name} is not streamable — streamed aggregation "
                "needs a reducer decomposition (weighted sum or "
                "order-statistic carve)"
            )
        weighted = fusion.weighted
        rep = StreamReport()
        sem = device_sem if device_sem is not None \
            else contextlib.nullcontext()
        it = iter(blocks)
        steps: dict = {}   # payload kind -> cached step executable
        state = sig = None  # flat tuple of jnp leaves + its cache sig
        chunk = dim = None
        compile_total = 0.0
        self.last_compile_seconds = 0.0
        while True:
            t0 = time.perf_counter()
            try:
                item = next(it)
            except StopIteration:
                break
            rep.ingest_seconds += time.perf_counter() - t0
            block, w = item[0], item[1]
            scale = _check_scale(item[2]) if len(item) > 2 else None
            if scale is not None and not weighted:
                raise ValueError(
                    f"{fusion.name}: per-row staleness scales are "
                    "unsupported — order statistics cannot discount rows"
                )
            compressed = isinstance(block, CompressedBlock)
            rows = block.rows if compressed else block.shape[0]
            bdim = block.dim if compressed else block.shape[1]
            if chunk is None:
                dim = bdim
                chunk = int(chunk_rows) if chunk_rows else rows
                rep.chunk_rows = chunk
                state = self._stream_state(fusion, dim, n_hint, init)
                sig = fusion.state_signature(dim, n_hint)
            elif bdim != dim:
                raise ValueError(
                    f"fuse_stream: block dim {bdim} != stream dim {dim}"
                )
            rep.ingest_bytes += int(block.nbytes)   # pre-padding payload
            kind = ("q", block.codes.shape[1], block.block) if compressed \
                else ("d", np.dtype(block.dtype).str)
            step = steps.get(kind)
            if step is None:
                avals = tuple(
                    jax.ShapeDtypeStruct(np.shape(leaf),
                                         np.asarray(leaf).dtype)
                    for leaf in state
                )
                if compressed:
                    step, compile_s = self._stream_step_q(
                        fusion, chunk, dim, block.codes.shape[1],
                        block.block, sig, avals,
                    )
                else:
                    step, compile_s = self._stream_step(
                        fusion, chunk, dim, block.dtype, sig, avals,
                    )
                steps[kind] = step
                # mixed rounds accumulate one compile per payload kind
                compile_total += compile_s
                rep.compile_seconds = compile_total
                self.last_compile_seconds = compile_total
            if rows > chunk:
                raise ValueError(
                    f"fuse_stream: block of {rows} rows exceeds "
                    f"chunk_rows={chunk}"
                )
            if rows < chunk:           # ragged final block: zero-weight pad
                wpad = np.zeros((chunk,), np.float32)
                wpad[:rows] = w
                w = wpad
                if compressed:
                    qpad = np.zeros((chunk, block.codes.shape[1]), np.int8)
                    qpad[:rows] = block.codes
                    spad = np.zeros(
                        (chunk, block.scales.shape[1]), np.float32
                    )
                    spad[:rows] = block.scales
                    block = CompressedBlock(codes=qpad, scales=spad,
                                            dim=dim)
                else:
                    padded = np.zeros((chunk, dim), block.dtype)
                    padded[:rows] = block
                    block = padded
            if weighted:
                w = np.array(
                    fusion.effective_weights(jnp.asarray(w, jnp.float32))
                )
                if scale is not None:
                    w[:rows] *= np.asarray(scale, np.float32)[:rows]
                if rows < chunk:
                    w[rows:] = 0.0     # effective_weights may remap pads
            else:
                # order-statistic fold: weights carry only row VALIDITY
                w = np.zeros((chunk,), np.float32)
                w[:rows] = 1.0
            t0 = time.perf_counter()
            with sem:
                if compressed:
                    state = step(block.codes, block.scales, w, *state)
                else:
                    state = step(block, w, *state)
                if device_sem is not None:
                    # dispatch is async: holding the semaphore only
                    # bounds execution if we wait for it (single-tenant
                    # rounds skip the sync and keep the pipeline deep)
                    jax.block_until_ready(state)  # lint: disable=sync-under-sem -- deliberate: the permit must cover device EXECUTION, not just dispatch (PR 5's device_concurrency contract)
            rep.compute_seconds += time.perf_counter() - t0
            rep.n_rows += rows
            rep.n_blocks += 1
        if rep.n_blocks == 0:
            if init is None:
                raise ValueError("fuse_stream: empty block iterator")
            # carry-only round: nothing arrived, finalize the carried state
            state = tuple(jnp.asarray(x, jnp.float32) for x in init)
        t0 = time.perf_counter()
        rep.acc_state = tuple(np.asarray(leaf) for leaf in state)
        if fusion.reducible:
            rep.acc_wsum = rep.acc_state[0]
            rep.acc_tot = float(rep.acc_state[1])
        with sem:
            fused = jax.block_until_ready(fusion.finalize(state))  # lint: disable=sync-under-sem -- deliberate: the permit must cover device EXECUTION, not just dispatch (PR 5's device_concurrency contract)
        rep.compute_seconds += time.perf_counter() - t0
        return fused, rep

    @staticmethod
    def _stream_state(fusion, dim, n_hint, init):
        """Fresh (or carried) reducer state as a flat tuple of jnp
        leaves. Carried leaves must match the fresh state's shapes."""
        proto = tuple(fusion.init_state(dim, n_hint))
        if init is None:
            return proto
        if len(init) != len(proto):
            raise ValueError(
                f"fuse_stream: carried state has {len(init)} leaves, "
                f"{fusion.name} expects {len(proto)}"
            )
        state = tuple(
            jnp.asarray(x, np.asarray(p).dtype) for x, p in zip(init, proto)
        )
        for got, want in zip(state, proto):
            if got.shape != want.shape:
                raise ValueError(
                    f"fuse_stream: carried accumulator has dim "
                    f"{got.shape}, stream blocks have dim {dim}"
                )
        return state

    # -- cache introspection (planner reuse term) -----------------------------
    def is_warm(self, fusion, n: int, P: int, dtype) -> bool:
        if not fusion.reducible:
            return False
        row_bytes = np.dtype(dtype).itemsize * P
        if self.memory_cap_bytes is not None:
            max_rows = max(int(self.memory_cap_bytes // max(row_bytes, 1)), 1)
            if max_rows < n:
                return self._scan_key(fusion, n, max_rows, P, dtype) \
                    in self.cache
        return self._dense_key(fusion, n, P, dtype) in self.cache

    def is_warm_stream(self, fusion, chunk: int, P: int, dtype,
                       block: Optional[int] = None,
                       n_hint: Optional[int] = None) -> bool:
        """Warm-path probe for the streamed step executable. ``dtype``
        int8 probes the COMPRESSED step (int8 codes + fp32 scales at
        quantization block ``block``, default ``compress.BLOCK``) —
        the key a compressed round's first fold would build. ``n_hint``
        matters for order-statistic fusions, whose carve-state capacity
        (and hence executable) is sized from it."""
        if not fusion.streamable:
            return False
        try:
            sig = fusion.state_signature(P, n_hint)
        except ValueError:   # carve fusion with no n_hint: can't stream
            return False
        if np.dtype(dtype) == np.int8:
            blk = int(block) if block else BLOCK
            Pq = -(-P // blk) * blk
            return self._step_key_q(fusion, chunk, P, Pq, blk, sig) \
                in self.cache
        return self._step_key(fusion, chunk, P, dtype, sig) in self.cache

    # -- internals ------------------------------------------------------------
    def _dense_key(self, fusion, n, P, dtype):
        return ("dense", fusion_cache_key(fusion), self.strategy,
                bucket_rows(n), P, np.dtype(dtype).str)

    def _step_key(self, fusion, chunk, P, dtype, sig):
        return ("stream", fusion_cache_key(fusion), self.strategy,
                chunk, P, np.dtype(dtype).str, sig)

    def _step_key_q(self, fusion, chunk, P, Pq, blk, sig):
        return ("streamq", fusion_cache_key(fusion), self.strategy,
                chunk, P, Pq, blk, sig)

    def _scan_key(self, fusion, n, max_rows, P, dtype):
        # keyed by chunk COUNT, not n: rounds sharing ceil(n/chunk) reuse
        # the executable. (No pow2 bucketing here — padding the dense
        # input up to a bucket would double peak memory on exactly the
        # memory-capped path; at most chunk-1 zero rows are acceptable.)
        k = -(-n // max_rows)
        return ("streamscan", fusion_cache_key(fusion), self.strategy,
                k, max_rows, P, np.dtype(dtype).str)

    def _partial_fn(self, fusion):
        """The stateless 'map' stage — closed over fusion hyperparameters,
        never over server state."""
        use_pallas = self.strategy == "pallas" and fusion.name in _PALLAS_WSUM
        interpret = self.interpret

        def partial(u, w):
            if use_pallas:
                return weighted_sum_pallas(u, w, interpret=interpret), \
                    jnp.sum(w)
            return fusion.partial(u, w)

        return partial

    def _fuse_reducible_dense(self, fusion, updates, weights,
                              device_sem=None):
        n, P = updates.shape
        B = bucket_rows(n)
        key = self._dense_key(fusion, n, P, updates.dtype)
        partial = self._partial_fn(fusion)
        # compile OUTSIDE the device semaphore (single-flight per key)
        fn, compile_s = self.cache.get(
            key, lambda: partial,
            jax.ShapeDtypeStruct((B, P), updates.dtype),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        )
        self.last_compile_seconds = compile_s
        if B != n:   # zero-weight rows: no contribution to any reducible op
            updates = jnp.pad(updates, ((0, B - n), (0, 0)))
            weights = jnp.pad(weights, (0, B - n))
        sem = device_sem if device_sem is not None \
            else contextlib.nullcontext()
        with sem:
            wsum, tot = fn(updates, weights)
            return self._bounded(fusion.combine(wsum, tot), device_sem)

    def _carve_fn(self, fusion):
        """Strategy-specific carve kernel injected into the fusion's
        fold (None = the fusion's jnp reference merge)."""
        del fusion
        if self.strategy != "pallas":
            return None
        interpret = self.interpret

        def carve(u, valid, ssum, topk, botk):
            return topk_carve_pallas(u, valid, ssum, topk, botk,
                                     interpret=interpret)

        return carve

    def _fold_fn(self, fusion):
        """The per-block fold: fusion-owned semantics with this engine's
        strategy-specific kernels injected."""
        if fusion.reducible:
            partial = self._partial_fn(fusion)
            return lambda st, u, w: tuple(
                fusion.fold_block(st, u, w, partial=partial))
        carve = self._carve_fn(fusion)
        return lambda st, u, w: tuple(
            fusion.fold_block(st, u, w, carve=carve))

    def _stream_step(self, fusion, chunk, P, dtype, sig, state_avals):
        """One compiled fold step: (block, w, *state) -> updated state
        tuple (reducible: (wsum, tot); carve: (sum, count, topk, botk))."""
        key = self._step_key(fusion, chunk, P, dtype, sig)
        fold = self._fold_fn(fusion)

        def build():
            def step(u, w, *state):
                return fold(tuple(state), u, w)

            return step

        return self.cache.get(
            key, build,
            jax.ShapeDtypeStruct((chunk, P), np.dtype(dtype)),
            jax.ShapeDtypeStruct((chunk,), jnp.float32),
            *state_avals,
        )

    def _partial_q_fn(self, fusion, dim, blk):
        """The 'map' stage for COMPRESSED blocks: (codes (c, Pq) int8,
        scales (c, Pq//blk) fp32, w (c,)) -> (partial wsum (dim,), tot).
        The fp32 update matrix never exists on the host; on device it
        either never materializes at all (pallas: scales fold into the
        weighted-sum kernel tile by tile; jnp weighted-sum fusions: the
        per-row weight and per-block scale fold into one einsum with the
        same MAC count as the dense path) or exists only as a transient
        inside the compiled step (general reducible fusions that need
        real update values, e.g. clipping norms)."""
        use_pallas = self.strategy == "pallas" and fusion.name in _PALLAS_WSUM
        # _PALLAS_WSUM fusions' partial IS the plain weighted sum + sum(w),
        # which is what justifies the scale-folding shortcut for exactly
        # this set under the jnp strategy too
        plain_wsum = fusion.name in _PALLAS_WSUM
        interpret = self.interpret

        def partial_q(q, s, w):
            if use_pallas:
                ws = weighted_sum_dequant_pallas(
                    q, s, w, block=blk, interpret=interpret
                )
                return ws[:dim], jnp.sum(w)
            c, Pq = q.shape
            B = Pq // blk
            if plain_wsum:
                # block-batched contraction over clients: out[b] =
                # (w * s[:, b]) @ codes[:, b] — XLA lowers it to B small
                # matvecs, ~4x faster here than the flat (c, B, blk)
                # einsum because the transposed int8 operand is
                # convert-and-contracted per block
                ws = jnp.einsum(
                    "bn,bnk->bk",
                    (w[:, None] * s).T,
                    q.reshape(c, B, blk).transpose(1, 0, 2)
                     .astype(jnp.float32),
                ).reshape(-1)[:dim]
                return ws, jnp.sum(w)
            u = (q.astype(jnp.float32).reshape(c, B, blk)
                 * s[:, :, None]).reshape(c, Pq)[:, :dim]
            return fusion.partial(u, w)

        return partial_q

    def _stream_step_q(self, fusion, chunk, P, Pq, blk, sig, state_avals):
        """The compressed twin of ``_stream_step``: (codes, scales, w,
        *state) -> updated state, the same carry as the dense step —
        which is what lets mixed dense/compressed rounds share one
        accumulator. For carve fusions the (codes, scales) payload is
        dequantized in-trace inside the fold (bit-identical to the host
        dequant, so the order statistics match the dense path)."""
        key = self._step_key_q(fusion, chunk, P, Pq, blk, sig)
        if fusion.reducible:
            partial_q = self._partial_q_fn(fusion, P, blk)

            def fold(state, q, s, w):
                partial = lambda payload, wv: partial_q(
                    payload[0], payload[1], wv)
                return tuple(fusion.fold_block(state, (q, s), w,
                                               partial=partial))
        else:
            carve = self._carve_fn(fusion)

            def fold(state, q, s, w):
                return tuple(fusion.fold_block(state, (q, s), w,
                                               carve=carve))

        def build():
            def step(q, s, w, *state):
                return fold(tuple(state), q, s, w)

            return step

        return self.cache.get(
            key, build,
            jax.ShapeDtypeStruct((chunk, Pq), np.int8),
            jax.ShapeDtypeStruct((chunk, Pq // blk), jnp.float32),
            jax.ShapeDtypeStruct((chunk,), jnp.float32),
            *state_avals,
        )

    def _streamed(self, fusion, updates, weights, max_rows,
                  device_sem=None) -> jnp.ndarray:
        """Memory-capped dense input: ONE scanned executable over fixed
        (max_rows, P) client chunks — bounded resident set, no Python loop
        of per-chunk jit dispatches (the seed behavior)."""
        n, P = updates.shape
        k = -(-n // max_rows)
        padded_n = k * max_rows
        key = self._scan_key(fusion, n, max_rows, P, updates.dtype)
        partial = self._partial_fn(fusion)

        def build():
            def scanned(u3, w2):
                def body(carry, xs):
                    u, w = xs
                    ws, t = partial(u, w)
                    return (carry[0] + ws, carry[1] + t), None

                init = (jnp.zeros((P,), jnp.float32),
                        jnp.zeros((), jnp.float32))
                (wsum, tot), _ = jax.lax.scan(body, init, (u3, w2))
                return wsum, tot

            return scanned

        fn, compile_s = self.cache.get(
            key, build,
            jax.ShapeDtypeStruct((k, max_rows, P), updates.dtype),
            jax.ShapeDtypeStruct((k, max_rows), jnp.float32),
        )
        self.last_compile_seconds = compile_s
        if padded_n != n:
            updates = jnp.pad(updates, ((0, padded_n - n), (0, 0)))
            weights = jnp.pad(weights, (0, padded_n - n))
        sem = device_sem if device_sem is not None \
            else contextlib.nullcontext()
        with sem:
            wsum, tot = fn(
                updates.reshape(k, max_rows, P),
                weights.reshape(k, max_rows),
            )
            return self._bounded(fusion.combine(wsum, tot), device_sem)
