"""repro: a distributed & elastic aggregation service for federated
learning on TPU/JAX, plus the assigned 10-architecture model stack.

Public surface:
    repro.core     — the paper's aggregation service
    repro.models   — build_model(config)
    repro.configs  — ARCHITECTURES / get_config / input shapes
    repro.fl       — federated runtime
    repro.launch   — mesh / dryrun / train / serve / aggregate
"""
__version__ = "1.0.0"
