"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba2 backbone with ONE
shared transformer block (attention + MLP) whose weights are re-used at
every interleave point (every ``hybrid_shared_every``-th Mamba layer).

Train/prefill: inner scan over each segment's stacked Mamba layers, the
shared block applied between segments (python loop over n_segments — the
shared block's params are a single copy, so HLO stays small).
Decode: unrolled; Mamba layers carry (conv, ssm) state, the shared block
keeps a (windowed) ring KV cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import (
    Model,
    next_token_loss,
    embed_tokens,
    init_embedding,
    lm_logits,
)
from repro.models.cache import (
    cache_valid_mask,
    init_attn_cache,
    update_attn_cache,
)
from repro.models.layers.attention import (
    reshard_for_attention,
    attention_output,
    blockwise_attention,
    decode_attention,
    init_attention,
    project_qkv,
)
from repro.models.layers.mamba2 import (
    dims_from_config,
    init_mamba2,
    init_mamba2_cache,
    mamba2_decode_step,
    mamba2_forward,
)
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.norms import rms_norm
from repro.models.runtime_flags import maybe_scan
from repro.models.sharding import shard

PyTree = Any


def _segments(cfg: ModelConfig) -> List[int]:
    """Mamba-layer counts per segment; the shared block runs after every
    full segment (not after a trailing partial one)."""
    k = cfg.hybrid_shared_every
    if k == 0:
        return [cfg.n_layers]
    n_full = cfg.n_layers // k
    rem = cfg.n_layers - n_full * k
    return [k] * n_full + ([rem] if rem else [])


def init_zamba(key, cfg: ModelConfig) -> Dict[str, PyTree]:
    ke, km, ka, kf = jax.random.split(key, 4)
    dims = dims_from_config(cfg)
    dtype = cfg.param_dtype
    segs = _segments(cfg)

    def init_m(k):
        return {
            "norm": jnp.zeros((cfg.d_model,), dtype),
            "cell": init_mamba2(k, dims, dtype),
        }

    m_keys = jax.random.split(km, cfg.n_layers)
    mamba = jax.vmap(init_m)(m_keys)
    params = {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "mamba": mamba,  # stacked (n_layers, ...); sliced per segment
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.hybrid_shared_every:
        params["shared"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(
                ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, False, dtype,
            ),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, dtype),
        }
    return params


def _shared_block(params, cfg: ModelConfig, h: jax.Array,
                  positions: jax.Array) -> jax.Array:
    s = params["shared"]
    x = rms_norm(h, s["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(s["attn"], x, positions, cfg.rope_theta)
    q, k, v = reshard_for_attention(q, k, v)
    attn = blockwise_attention(
        q, k, v, causal=True, window=cfg.attn.sliding_window
    )
    h = h + attention_output(s["attn"], attn)
    x = rms_norm(h, s["ln2"], cfg.norm_eps)
    h = h + mlp(s["mlp"], x)
    return shard(h, "batch", "seq", None)


def zamba_hidden(params, cfg: ModelConfig, tokens: jax.Array,
                 remat: bool = True) -> jax.Array:
    dims = dims_from_config(cfg)
    h = embed_tokens(params["embed"], tokens)
    B, T = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    segs = _segments(cfg)

    def m_body(hh, layer):
        x = rms_norm(hh, layer["norm"], cfg.norm_eps)
        hh = hh + mamba2_forward(layer["cell"], dims, x)
        return shard(hh, "batch", "seq", None), None

    if remat:
        m_body = jax.checkpoint(m_body, prevent_cse=False)
    off = 0
    for si, seg_len in enumerate(segs):
        seg = jax.tree_util.tree_map(
            lambda l: l[off: off + seg_len], params["mamba"]
        )
        h, _ = maybe_scan(m_body, h, seg)
        off += seg_len
        if cfg.hybrid_shared_every and seg_len == cfg.hybrid_shared_every:
            h = _shared_block(params, cfg, h, positions)
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def zamba_loss(params, cfg: ModelConfig, batch):
    h = zamba_hidden(params, cfg, batch["tokens"])
    loss = next_token_loss(h, params["embed"], None, batch["labels"])
    return loss, {"ce": loss}


def zamba_prefill(params, cfg: ModelConfig, batch):
    h = zamba_hidden(params, cfg, batch["tokens"], remat=False)
    return lm_logits(h[:, -1:, :], params["embed"], None)[:, 0]


# -- decode -----------------------------------------------------------------


def zamba_init_cache(cfg: ModelConfig, batch: int, length: int,
                     dtype=None, force_local: bool = False,
                     spec_only: bool = False) -> List:
    """[mamba states ... interleaved with shared-block AttnCaches].

    The shared block's cache is windowed (cfg.attn.sliding_window), which
    keeps long_500k memory bounded; each invocation point has its OWN kv
    cache (weights are shared, activations are not).
    """
    dtype = dtype or cfg.param_dtype
    dims = dims_from_config(cfg)
    segs = _segments(cfg)
    w = cfg.attn.sliding_window
    s_attn = min(length, w) if w > 0 else length
    caches: List = []
    for seg_len in segs:
        for _ in range(seg_len):
            caches.append(init_mamba2_cache(batch, dims, dtype))
        if cfg.hybrid_shared_every and seg_len == cfg.hybrid_shared_every:
            caches.append(
                init_attn_cache(batch, s_attn, cfg.n_kv_heads,
                                cfg.resolved_head_dim, dtype)
            )
    if spec_only:
        caches = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), caches
        )
    return caches


def zamba_decode_step(params, cfg: ModelConfig, cache: List,
                      token: jax.Array, pos: jax.Array,
                      force_local: bool = False):
    del force_local
    dims = dims_from_config(cfg)
    B = token.shape[0]
    h = embed_tokens(params["embed"], token)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    segs = _segments(cfg)
    new_cache: List = []
    ci = 0
    li = 0
    for seg_len in segs:
        for _ in range(seg_len):
            layer = jax.tree_util.tree_map(lambda l: l[li], params["mamba"])
            x = rms_norm(h, layer["norm"], cfg.norm_eps)
            st, y = mamba2_decode_step(layer["cell"], dims, cache[ci], x)
            h = h + y
            new_cache.append(st)
            ci += 1
            li += 1
        if cfg.hybrid_shared_every and seg_len == cfg.hybrid_shared_every:
            s = params["shared"]
            x = rms_norm(h, s["ln1"], cfg.norm_eps)
            q, k, v = project_qkv(s["attn"], x, positions, cfg.rope_theta)
            c = update_attn_cache(cache[ci], k, v, pos)
            valid = cache_valid_mask(c.k.shape[1], pos, B)
            attn = decode_attention(q, c.k, c.v, valid)
            h = h + attention_output(s["attn"], attn)
            x = rms_norm(h, s["ln2"], cfg.norm_eps)
            h = h + mlp(s["mlp"], x)
            new_cache.append(c)
            ci += 1
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return new_cache, lm_logits(h, params["embed"], None)[:, 0]


def build_zamba(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda rng: init_zamba(rng, cfg),
        loss=lambda p, b: zamba_loss(p, cfg, b),
        prefill=lambda p, b: zamba_prefill(p, cfg, b),
        init_cache=functools.partial(zamba_init_cache, cfg),
        decode_step=lambda p, c, t, pos, **kw: zamba_decode_step(
            p, cfg, c, t, pos, **kw
        ),
    )
