"""Shared model machinery: embeddings, LM head, losses, the Model facade."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.runtime_flags import maybe_scan
from repro.models.sharding import shard

PyTree = Any


def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * d ** -0.5).astype(dtype)


def embed_tokens(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    h = embed[tokens]
    return shard(h, "batch", None, None)


def lm_logits(h: jax.Array, embed: jax.Array,
              head: Optional[jax.Array]) -> jax.Array:
    """h: (B, T, d) -> (B, T, vocab). Tied (embed.T) or separate head."""
    if head is not None:
        logits = jnp.einsum("btd,dv->btv", h, head)
    else:
        logits = jnp.einsum("btd,vd->btv", h, embed)
    return shard(logits.astype(jnp.float32), "batch", None, "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in fp32. logits (B,T,V), labels (B,T)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


LOSS_CHUNK = 256


def next_token_loss(h: jax.Array, embed: jax.Array,
                    head: Optional[jax.Array], labels: jax.Array,
                    chunk: int = LOSS_CHUNK) -> jax.Array:
    """Next-token CE without materializing full (B, T, V) logits.

    Scans sequence chunks; each chunk's logits are built, consumed and
    (via remat) rebuilt in backward — peak logits memory is
    (B, chunk, V) instead of (B, T, V). Mandatory for the 152k–262k
    vocabularies at 4k–32k sequence lengths.
    """
    B, T, d = h.shape
    # shift: position t predicts labels[t+1]; last position is masked
    labels_shift = jnp.concatenate(
        [labels[:, 1:], jnp.zeros((B, 1), labels.dtype)], axis=1
    )
    mask = jnp.concatenate(
        [jnp.ones((B, T - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)],
        axis=1,
    )
    if T % chunk:
        chunk = T
    nc = T // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    yc = jnp.moveaxis(labels_shift.reshape(B, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    def body(carry, inp):
        s, n = carry
        h_, y_, m_ = inp
        logits = lm_logits(h_, embed, head)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m_
        return (s + jnp.sum(nll), n + jnp.sum(m_)), None

    body = jax.checkpoint(body, prevent_cse=False)
    (s, n), _ = maybe_scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, yc, mc),
    )
    return s / jnp.maximum(n, 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    """Functional model facade — what the launcher/dry-run/FL stack uses.

    init(rng) -> params
    loss(params, batch) -> (scalar loss, metrics dict)         [train]
    prefill(params, batch) -> last-position logits (B, vocab)  [prefill]
    init_cache(batch, length, dtype, force_local) -> cache     [decode]
    decode_step(params, cache, token, pos) -> (cache, logits)  [decode]
    """

    config: ModelConfig
    init: Callable[[jax.Array], PyTree]
    loss: Callable[[PyTree, Dict[str, jax.Array]], Tuple[jax.Array, Dict]]
    prefill: Callable[[PyTree, Dict[str, jax.Array]], jax.Array]
    init_cache: Callable[..., List]
    decode_step: Callable[..., Tuple[List, jax.Array]]
