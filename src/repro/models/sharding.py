"""Logical sharding annotations for the model stack.

Models call ``shard(x, "batch", "seq", None)`` at layer boundaries. When an
``AxisRules`` context is active (set by the launcher/dry-run), the logical
names resolve to mesh axes and a ``with_sharding_constraint`` is applied;
otherwise the call is the identity, so smoke tests on one CPU device are
untouched.

Logical axes used across the stack:
  batch   - data-parallel batch dim
  seq     - sequence dim (sequence parallelism for the residual stream)
  embed   - residual-stream feature dim (usually unsharded)
  heads   - attention-head dim (tensor parallelism)
  kv      - kv-head dim
  ff      - MLP hidden dim
  expert  - MoE expert dim (expert parallelism)
  vocab   - vocabulary dim
  ctx     - decode-time KV-cache sequence dim (context parallelism)
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar[Optional["AxisRules"]] = contextvars.ContextVar(
    "repro_axis_rules", default=None
)


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Maps logical axis names to (tuples of) mesh axis names."""

    mesh: Mesh
    rules: Dict[str, Optional[Tuple[str, ...]]]

    def spec(self, *logical: Optional[str]) -> P:
        axes = []
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            m = self.rules.get(name)
            if m is None:
                axes.append(None)
            elif isinstance(m, str):
                axes.append(m)
            else:
                axes.append(tuple(m) if len(m) > 1 else m[0])
        return P(*axes)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    token = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def current_rules() -> Optional[AxisRules]:
    return _ACTIVE.get()


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o rules)."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs {logical}")
    spec = rules.spec(*logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


# Default logical->mesh mappings -------------------------------------------

def train_rules(mesh: Mesh) -> AxisRules:
    """Training/prefill: batch over (pod, data), tensor dims over model,
    residual-stream sequence over model (sequence parallelism)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return AxisRules(
        mesh=mesh,
        rules={
            "batch": data_axes,
            "seq": ("model",),
            "embed": None,
            "heads": ("model",),
            "kv": ("model",),
            "ff": ("model",),
            "expert": ("model",),
            "vocab": ("model",),
            "ctx": None,
            "dmodel": None,
        },
    )


def decode_rules(mesh: Mesh, batch: int) -> AxisRules:
    """Decode: batch over (pod, data) when divisible; the KV-cache
    sequence dim over model (context-parallel attention — softmax over a
    sharded key axis costs only tiny cross-shard reductions), extended to
    the data axes too when the batch isn't shardable (long_500k)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    batch_sharded = batch % n_data == 0 and batch >= n_data
    return AxisRules(
        mesh=mesh,
        rules={
            "batch": data_axes if batch_sharded else None,
            "seq": None,
            "embed": None,
            "heads": ("model",),
            "kv": None,
            "ff": ("model",),
            "expert": ("model",),
            "vocab": ("model",),
            "ctx": ("model",) if batch_sharded else data_axes + ("model",),
            # feature dim of token activations, matching the FSDP'd (data-
            # sharded) weight contraction dim: keeps the all-expert decode
            # mix as partial-dot + psum instead of weight all-gathers
            "dmodel": data_axes,
        },
    )
