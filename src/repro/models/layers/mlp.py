"""SwiGLU MLP (the dense FFN used by every transformer-family arch here)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MLPParams(NamedTuple):
    w_gate: jax.Array  # (d, ff)
    w_up: jax.Array    # (d, ff)
    w_down: jax.Array  # (ff, d)


def init_mlp(key, d_model: int, d_ff: int, dtype) -> MLPParams:
    kg, ku, kd = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    mk = lambda k, shape, s: (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)
    return MLPParams(
        w_gate=mk(kg, (d_model, d_ff), s_in),
        w_up=mk(ku, (d_model, d_ff), s_in),
        w_down=mk(kd, (d_ff, d_model), s_out),
    )


def mlp(p: MLPParams, x: jax.Array) -> jax.Array:
    g = jnp.einsum("btd,df->btf", x, p.w_gate)
    u = jnp.einsum("btd,df->btf", x, p.w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("btf,fd->btd", h, p.w_down)
