"""Mixture-of-Experts layer with three TPU-adapted execution paths.

``a2a`` (train/prefill under a mesh) — shard_map expert parallelism:
    tokens stay on their (data x model)-sharded devices; each device
    routes locally into per-expert capacity buffers, ``all_to_all`` over
    the model axis ships buffers to the expert owners, experts run as
    dense MXU matmuls, and a second all_to_all ships results back. This
    is the canonical TPU schedule (GShard/Switch); collective volume is
    ~2 x tokens x d_model instead of the TB-scale traffic XLA emits for a
    cross-axis scatter (measured in EXPERIMENTS.md §Perf).

``dense-mix`` (decode) — with one token per sequence the step is HBM-
    bandwidth-bound on weight reads, and nearly every expert is hit by
    some token in the batch, so computing ALL experts and mixing by the
    (top-k masked) gate costs no extra HBM traffic and removes every
    gather/scatter. Extra FLOPs are free under the bandwidth roof.

``scatter`` (no mesh: CPU smoke tests/examples) — static-capacity
    buffers via scatter/gather, O(n*k*d + E*C*d) memory.

All three compute the same function (tests assert equivalence up to
capacity drops).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.utils.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers.mlp import MLPParams, init_mlp, mlp
from repro.models.sharding import current_rules, shard


class MoEParams(NamedTuple):
    router: jax.Array         # (d, E) fp32
    w_gate: jax.Array         # (E, d, ff)
    w_up: jax.Array           # (E, d, ff)
    w_down: jax.Array         # (E, ff, d)
    shared: Optional[MLPParams]  # fused shared experts (ff_shared = n_shared*ff)


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int,
             dtype) -> MoEParams:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    mk = lambda k, shape, s: (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)
    return MoEParams(
        router=(jax.random.normal(kr, (d_model, n_experts), jnp.float32) * s_in),
        w_gate=mk(kg, (n_experts, d_model, d_ff), s_in),
        w_up=mk(ku, (n_experts, d_model, d_ff), s_in),
        w_down=mk(kd, (n_experts, d_ff, d_model), s_out),
        shared=(
            init_mlp(ks, d_model, n_shared * d_ff, dtype) if n_shared else None
        ),
    )


# ---------------------------------------------------------------------------
# routing helpers (shared by all paths)
# ---------------------------------------------------------------------------


def _route(xt: jax.Array, router: jax.Array, top_k: int):
    """xt (n, d) -> (gate_vals (n,k), gate_idx (n,k), probs (n,E))."""
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    return gate_vals, gate_idx, probs


def _aux_loss(probs: jax.Array, gate_idx: jax.Array, E: int) -> jax.Array:
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=1),
        axis=0,
    )
    return E * jnp.sum(me * ce)


def _positions_in_expert(flat_idx: jax.Array, E: int) -> jax.Array:
    """Rank of each assignment among same-expert assignments (sort-based,
    O(n*k) memory)."""
    nk = flat_idx.shape[0]
    order = jnp.argsort(flat_idx, stable=True)
    sorted_idx = flat_idx[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_idx].add(1)
    starts = jnp.cumsum(counts) - counts
    ranks_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[sorted_idx]
    return jnp.zeros((nk,), jnp.int32).at[order].set(ranks_sorted)


def _capacity(n_tok: int, top_k: int, E: int, cf: float) -> int:
    c = int(max(top_k * n_tok * cf / E, 8))
    c = min(c, n_tok * top_k)
    return -(-c // 8) * 8


def _dispatch_combine_local(xt, router, wg, wu, wd, top_k, cf):
    """The scatter-path kernel on LOCAL (or global, meshless) tokens."""
    n_tok, d = xt.shape
    E = router.shape[1]
    gate_vals, gate_idx, probs = _route(xt, router, top_k)
    capacity = _capacity(n_tok, top_k, E, cf)
    flat_idx = gate_idx.reshape(-1)
    pos = _positions_in_expert(flat_idx, E)
    keep = pos < capacity
    slot = jnp.where(keep, pos, capacity - 1)

    buf = jnp.zeros((E, capacity, d), xt.dtype)
    contrib = jnp.repeat(xt, top_k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = buf.at[flat_idx, slot].add(contrib, mode="drop")

    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)

    gathered = out_buf[flat_idx, slot]
    gathered = gathered * (
        gate_vals.reshape(-1)[:, None].astype(xt.dtype)
        * keep[:, None].astype(xt.dtype)
    )
    out = jnp.sum(gathered.reshape(n_tok, top_k, d), axis=1)
    return out, _aux_loss(probs, gate_idx, E)


# ---------------------------------------------------------------------------
# paths
# ---------------------------------------------------------------------------


def _moe_scatter(p, x, top_k, cf):
    B, T, d = x.shape
    out, aux = _dispatch_combine_local(
        x.reshape(B * T, d), p.router, p.w_gate, p.w_up, p.w_down, top_k, cf
    )
    if p.shared is not None:
        out = out + mlp(p.shared, x).reshape(B * T, d)
    return out.reshape(B, T, d), aux


def _moe_dense_mix(p, x, top_k):
    """Decode path: all experts, gate-masked mix."""
    B, T, d = x.shape
    E = p.router.shape[1]
    xt = x.reshape(B * T, d)
    gate_vals, gate_idx, probs = _route(xt, p.router, top_k)
    # dense gates (n, E): top-k renormalized, zero elsewhere
    gates = jnp.zeros((B * T, E), jnp.float32).at[
        jnp.arange(B * T)[:, None], gate_idx
    ].set(gate_vals)
    # match the FSDP'd weight layout on the contraction dim -> partial
    # dots + psum (n is tiny; gathering full expert weights would be huge)
    xt = shard(xt, None, "dmodel")
    g = jnp.einsum("nd,edf->nef", xt, p.w_gate)
    u = jnp.einsum("nd,edf->nef", xt, p.w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, None, None, "dmodel")
    y = jnp.einsum("nef,efd->ned", h, p.w_down)
    out = jnp.einsum("ned,ne->nd", y, gates.astype(x.dtype))
    if p.shared is not None:
        out = out + mlp(p.shared, x).reshape(B * T, d)
    return out.reshape(B, T, d), _aux_loss(probs, gate_idx, E)


def _moe_a2a(p, x, top_k, cf, rules):
    """shard_map expert-parallel path (see module docstring)."""
    mesh = rules.mesh
    model_ax = "model"
    n_model = mesh.shape[model_ax]
    dp = rules.rules.get("batch")
    dp_spec = tuple(dp) if dp and len(dp) > 1 else (dp[0] if dp else None)
    seq_ax = rules.rules.get("seq")
    seq_spec = seq_ax[0] if seq_ax else None
    B, T, d = x.shape
    E = p.router.shape[1]
    E_loc = E // n_model

    x_spec = P(dp_spec, seq_spec, None)
    w_spec = P(model_ax, None, None)

    def local(x_loc, router, wg, wu, wd):
        bl, tl, _ = x_loc.shape
        n_loc = bl * tl
        xt = x_loc.reshape(n_loc, d)
        gate_vals, gate_idx, probs = _route(xt, router, top_k)
        capacity = _capacity(n_loc, top_k, E, cf)
        flat_idx = gate_idx.reshape(-1)
        pos = _positions_in_expert(flat_idx, E)
        keep = pos < capacity
        slot = jnp.where(keep, pos, capacity - 1)

        buf = jnp.zeros((E, capacity, d), xt.dtype)
        contrib = jnp.repeat(xt, top_k, axis=0) * keep[:, None].astype(
            xt.dtype
        )
        buf = buf.at[flat_idx, slot].add(contrib, mode="drop")

        # ship buffers to expert owners: (E, C, d) -> (E_loc, m*C, d)
        buf = jax.lax.all_to_all(
            buf, model_ax, split_axis=0, concat_axis=1, tiled=True
        )
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
        # ship results back: (E_loc, m*C, d) -> (E, C, d)
        out_buf = jax.lax.all_to_all(
            out_buf, model_ax, split_axis=1, concat_axis=0, tiled=True
        )
        gathered = out_buf[flat_idx, slot]
        gathered = gathered * (
            gate_vals.reshape(-1)[:, None].astype(xt.dtype)
            * keep[:, None].astype(xt.dtype)
        )
        out = jnp.sum(gathered.reshape(n_loc, top_k, d), axis=1)
        aux = _aux_loss(probs, gate_idx, E)
        aux = jax.lax.pmean(aux, mesh.axis_names)
        return out.reshape(bl, tl, d), aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    out, aux = fn(x, p.router, p.w_gate, p.w_up, p.w_down)
    if p.shared is not None:
        out = out + mlp(p.shared, x)
    return out, aux


def moe(
    p: MoEParams,
    x: jax.Array,              # (B, T, d)
    top_k: int,
    capacity_factor: float,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,T,d), aux load-balance loss scalar)."""
    B, T, d = x.shape
    E = p.router.shape[1]
    rules = current_rules()
    if T == 1:
        return _moe_dense_mix(p, x, top_k)
    if rules is not None and "model" in rules.mesh.axis_names:
        n_model = rules.mesh.shape["model"]
        seq_ok = rules.rules.get("seq") and T % n_model == 0
        if E % n_model == 0 and seq_ok:
            return _moe_a2a(p, x, top_k, capacity_factor, rules)
    return _moe_scatter(p, x, top_k, capacity_factor)
