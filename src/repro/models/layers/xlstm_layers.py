"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-
parallel) and sLSTM (scalar memory, block-diagonal recurrence, scanned).

TPU adaptation: the mLSTM runs in the chunkwise formulation (intra-chunk
parallel tiles + inter-chunk state scan — same schedule as Mamba2's SSD, so
the same MXU/VMEM blocking applies) with log-domain stabilization (the
paper's m-state). The sLSTM is inherently sequential and runs as a
``lax.scan`` over time with all heads vectorized.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.norms import group_norm

NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================


class MLSTMParams(NamedTuple):
    w_up: jax.Array        # (d, d_inner) x branch
    w_z: jax.Array         # (d, d_inner) output-gate branch
    conv_w: jax.Array      # (4, d_inner) causal depthwise conv on x branch
    w_q: jax.Array         # (d_inner, d_qk)
    w_k: jax.Array         # (d_inner, d_qk)
    w_v: jax.Array         # (d_inner, d_v)
    w_if: jax.Array        # (d_inner, 2*nh) input/forget gate pre-acts
    b_if: jax.Array        # (2*nh,)
    gn_scale: jax.Array    # (d_v,)
    w_out: jax.Array       # (d_v, d)


class MLSTMDims(NamedTuple):
    d_model: int
    d_inner: int
    d_qk: int
    d_v: int
    n_heads: int
    chunk: int

    @property
    def h_qk(self) -> int:
        return self.d_qk // self.n_heads

    @property
    def h_v(self) -> int:
        return self.d_v // self.n_heads


def mlstm_dims(cfg) -> MLSTMDims:
    x = cfg.xlstm
    d_inner = 2 * cfg.d_model
    return MLSTMDims(
        d_model=cfg.d_model,
        d_inner=d_inner,
        d_qk=int(d_inner * x.mlstm_qk_dim_factor),
        d_v=int(d_inner * x.mlstm_v_dim_factor),
        n_heads=cfg.n_heads,
        chunk=x.chunk,
    )


def init_mlstm(key, dims: MLSTMDims, dtype) -> MLSTMParams:
    ks = jax.random.split(key, 8)
    d, di = dims.d_model, dims.d_inner
    mk = lambda k, shape, s: (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)
    nh = dims.n_heads
    return MLSTMParams(
        w_up=mk(ks[0], (d, di), d ** -0.5),
        w_z=mk(ks[1], (d, di), d ** -0.5),
        conv_w=mk(ks[2], (4, di), 0.3),
        w_q=mk(ks[3], (di, dims.d_qk), di ** -0.5),
        w_k=mk(ks[4], (di, dims.d_qk), di ** -0.5),
        w_v=mk(ks[5], (di, dims.d_v), di ** -0.5),
        w_if=(jax.random.normal(ks[6], (di, 2 * nh), jnp.float32) * di ** -0.5),
        # forget-gate bias init positive: long memory at init
        b_if=jnp.concatenate([jnp.zeros((nh,)), jnp.full((nh,), 3.0)]),
        gn_scale=jnp.zeros((dims.d_v,), dtype),
        w_out=mk(ks[7], (dims.d_v, d), dims.d_v ** -0.5),
    )


def _mlstm_qkvif(p: MLSTMParams, dims: MLSTMDims, x: jax.Array):
    """x: (B, T, d) -> q,k,v (B,T,nh,h*), i_raw,f_log (B,T,nh), z (B,T,di)."""
    B, T, _ = x.shape
    nh = dims.n_heads
    xb = jnp.einsum("btd,de->bte", x, p.w_up)
    z = jnp.einsum("btd,de->bte", x, p.w_z)
    # causal conv + silu on the x branch (width 4)
    W = p.conv_w.shape[0]
    pad = jnp.pad(xb, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pad[:, k: k + T, :] * p.conv_w[k] for k in range(W))
    xc = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    q = jnp.einsum("bte,ef->btf", xc, p.w_q).reshape(B, T, nh, dims.h_qk)
    k = jnp.einsum("bte,ef->btf", xc, p.w_k).reshape(B, T, nh, dims.h_qk)
    v = jnp.einsum("bte,ef->btf", xb, p.w_v).reshape(B, T, nh, dims.h_v)
    gates = (
        jnp.einsum("bte,eg->btg", xc.astype(jnp.float32), p.w_if) + p.b_if
    )
    i_raw = gates[..., :nh]                        # (B, T, nh)
    f_log = jax.nn.log_sigmoid(gates[..., nh:])    # (B, T, nh)
    return q, k, v, i_raw, f_log, z, xb


class MLSTMState(NamedTuple):
    C: jax.Array      # (B, nh, h_qk, h_v) matrix memory (scaled by exp(-m))
    n: jax.Array      # (B, nh, h_qk) normalizer
    m: jax.Array      # (B, nh) running log stabilizer
    conv: jax.Array   # (B, 3, d_inner) conv tail for decode


def init_mlstm_state(batch: int, dims: MLSTMDims, dtype) -> MLSTMState:
    nh = dims.n_heads
    return MLSTMState(
        C=jnp.zeros((batch, nh, dims.h_qk, dims.h_v), jnp.float32),
        n=jnp.zeros((batch, nh, dims.h_qk), jnp.float32),
        m=jnp.full((batch, nh), 0.0, jnp.float32),
        conv=jnp.zeros((batch, 3, dims.d_inner), dtype),
    )


def mlstm_forward(p: MLSTMParams, dims: MLSTMDims, x: jax.Array) -> jax.Array:
    """Chunkwise-parallel mLSTM. x: (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    nh, hq, hv = dims.n_heads, dims.h_qk, dims.h_v
    L = min(dims.chunk, T)
    if T % L:
        L = T
    nc = T // L
    q, k, v, i_raw, f_log, z, _ = _mlstm_qkvif(p, dims, x)
    scale = hq ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    ch = lambda a: jnp.moveaxis(a.reshape(B, nc, L, *a.shape[2:]), 1, 0)
    qc, kc, vc = ch(qf), ch(kf), ch(vf)            # (nc, B, L, nh, .)
    ic, fc = ch(i_raw), ch(f_log)                  # (nc, B, L, nh)

    def chunk_step(state, inp):
        q_, k_, v_, i_, f_ = inp
        C, n, m = state
        b = jnp.cumsum(f_, axis=1)                 # (B, L, nh)
        btot = b[:, -1, :]                         # (B, nh)
        # intra-chunk log weights D[t,s] = b_t - b_s + i_s  (s <= t)
        D = (
            b[:, :, None, :] - b[:, None, :, :] + i_[:, None, :, :]
        )                                          # (B, t, s, nh)
        causal = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(causal[None, :, :, None], D, NEG)
        d_state = b + m[:, None, :]                # (B, L, nh): inter term
        m_t = jnp.maximum(jnp.max(D, axis=2), d_state)  # (B, L, nh)
        w = jnp.exp(D - m_t[:, :, None, :])        # (B, t, s, nh)
        sc = jnp.exp(d_state - m_t)                # (B, L, nh)

        qk = jnp.einsum("blhq,bshq->blsh", q_, k_)  # (B, t, s, nh)
        num = jnp.einsum("blsh,blsh,bshv->blhv", qk, w, v_)
        num = num + jnp.einsum("blhq,bhqv,blh->blhv", q_, C, sc)
        nvec = jnp.einsum("blsh,bshq->blhq", w, k_) + jnp.einsum(
            "bhq,blh->blhq", n, sc
        )
        den = jnp.maximum(
            jnp.abs(jnp.einsum("blhq,blhq->blh", q_, nvec)),
            jnp.exp(-m_t),
        )
        h = num / den[..., None]                   # (B, L, nh, hv)

        # carry update (log-domain)
        g = b[:, -1:, :] - b + i_                  # (B, L, nh) decay-to-end + i
        m_local = jnp.max(g, axis=1)               # (B, nh)
        m_new = jnp.maximum(m + btot, m_local)
        wC = jnp.exp(g - m_new[:, None, :])        # (B, L, nh)
        C_new = (
            C * jnp.exp(m + btot - m_new)[..., None, None]
            + jnp.einsum("blh,blhq,blhv->bhqv", wC, k_, v_)
        )
        n_new = n * jnp.exp(m + btot - m_new)[..., None] + jnp.einsum(
            "blh,blhq->bhq", wC, k_
        )
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, nh, hq, hv), jnp.float32)
    n0 = jnp.zeros((B, nh, hq), jnp.float32)
    m0 = jnp.zeros((B, nh), jnp.float32)
    # checkpoint: avoid saving every chunk's (L, L, nh) weight tile
    chunk_step_ck = jax.checkpoint(chunk_step, prevent_cse=False)
    _, hs = jax.lax.scan(chunk_step_ck, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, nh * hv).astype(x.dtype)
    h = group_norm(h, p.gn_scale, n_groups=nh)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[..., : h.shape[-1]]
    return jnp.einsum("btv,vd->btd", h, p.w_out)


def mlstm_decode_step(
    p: MLSTMParams, dims: MLSTMDims, state: MLSTMState, x: jax.Array
) -> Tuple[MLSTMState, jax.Array]:
    """One-token recurrent mLSTM step. x: (B, 1, d)."""
    B = x.shape[0]
    nh, hq, hv = dims.n_heads, dims.h_qk, dims.h_v
    xb = jnp.einsum("btd,de->bte", x, p.w_up)
    z = jnp.einsum("btd,de->bte", x, p.w_z)
    window = jnp.concatenate([state.conv, xb], axis=1)      # (B, 4, di)
    conv = jnp.einsum("bwc,wc->bc", window, p.conv_w)[:, None, :]
    xc = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    q = jnp.einsum("bte,ef->btf", xc, p.w_q).reshape(B, nh, hq)
    k = jnp.einsum("bte,ef->btf", xc, p.w_k).reshape(B, nh, hq)
    v = jnp.einsum("bte,ef->btf", xb, p.w_v).reshape(B, nh, hv)
    gates = jnp.einsum("bte,eg->bg", xc.astype(jnp.float32), p.w_if) + p.b_if
    i_raw, f_log = gates[:, :nh], jax.nn.log_sigmoid(gates[:, nh:])

    m_new = jnp.maximum(f_log + state.m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(f_log + state.m - m_new)
    qf = q.astype(jnp.float32) * hq ** -0.5
    C = state.C * f[..., None, None] + i[..., None, None] * (
        k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    n = state.n * f[..., None] + i[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhq,bhqv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhq,bhq->bh", qf, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, nh * hv).astype(x.dtype)
    h = group_norm(h, p.gn_scale, n_groups=nh)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[..., : h.shape[-1]]
    out = jnp.einsum("btv,vd->btd", h, p.w_out)
    return MLSTMState(C=C, n=n, m=m_new, conv=window[:, 1:, :]), out


# ===========================================================================
# sLSTM
# ===========================================================================


class SLSTMParams(NamedTuple):
    w_in: jax.Array        # (d, 4d) i,f,z,o pre-activations from input
    r: jax.Array           # (nh, 4, hd, hd) block-diagonal recurrence
    b: jax.Array           # (4d,)
    gn_scale: jax.Array    # (d,)
    w_gate: jax.Array      # (d, up) gated FFN after the cell
    w_upp: jax.Array       # (d, up)
    w_down: jax.Array      # (up, d)


class SLSTMDims(NamedTuple):
    d_model: int
    n_heads: int
    up: int

    @property
    def h(self) -> int:
        return self.d_model // self.n_heads


def slstm_dims(cfg) -> SLSTMDims:
    return SLSTMDims(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        up=int(cfg.d_model * cfg.xlstm.proj_factor),
    )


def init_slstm(key, dims: SLSTMDims, dtype) -> SLSTMParams:
    ks = jax.random.split(key, 5)
    d, nh, hd = dims.d_model, dims.n_heads, dims.h
    mk = lambda k, shape, s: (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)
    b = jnp.zeros((4 * d,))
    # forget-gate bias positive
    b = b.at[d: 2 * d].set(3.0)
    return SLSTMParams(
        w_in=mk(ks[0], (d, 4 * d), d ** -0.5),
        r=(jax.random.normal(ks[1], (nh, 4, hd, hd), jnp.float32) * hd ** -0.5),
        b=b,
        gn_scale=jnp.zeros((d,), dtype),
        w_gate=mk(ks[2], (d, dims.up), d ** -0.5),
        w_upp=mk(ks[3], (d, dims.up), d ** -0.5),
        w_down=mk(ks[4], (dims.up, d), dims.up ** -0.5),
    )


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, nh, hd)
    n: jax.Array
    m: jax.Array
    h: jax.Array


def init_slstm_state(batch: int, dims: SLSTMDims) -> SLSTMState:
    z = jnp.zeros((batch, dims.n_heads, dims.h), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, m=z, h=z)


def _slstm_cell(p: SLSTMParams, dims: SLSTMDims, state: SLSTMState,
                pre: jax.Array) -> SLSTMState:
    """pre: (B, 4d) input pre-activation (x W + b). Adds recurrence and
    advances the cell one step."""
    B = pre.shape[0]
    d, nh, hd = dims.d_model, dims.n_heads, dims.h
    rec = jnp.einsum("bhx,hgxy->bghy", state.h, p.r)   # (B, 4, nh, hd)
    g = pre.reshape(B, 4, nh, hd) + rec
    i_raw, f_raw, z_raw, o_raw = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    f_log = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(f_log + state.m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(f_log + state.m - m_new)
    c = f * state.c + i * jnp.tanh(z_raw)
    n = f * state.n + i
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, m=m_new, h=h)


def slstm_forward(p: SLSTMParams, dims: SLSTMDims, x: jax.Array) -> jax.Array:
    """Sequential scan over time. x: (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    pre = jnp.einsum("btd,dg->btg", x.astype(jnp.float32), p.w_in) + p.b

    def step(state, pre_t):
        new = _slstm_cell(p, dims, state, pre_t)
        return new, new.h

    state0 = init_slstm_state(B, dims)
    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(pre, 0, 1))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, d).astype(x.dtype)
    h = group_norm(h, p.gn_scale, n_groups=dims.n_heads)
    # gated FFN
    gte = jnp.einsum("btd,du->btu", h, p.w_gate)
    up = jnp.einsum("btd,du->btu", h, p.w_upp)
    y = jax.nn.gelu(gte.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("btu,ud->btd", y, p.w_down)


def slstm_decode_step(
    p: SLSTMParams, dims: SLSTMDims, state: SLSTMState, x: jax.Array
) -> Tuple[SLSTMState, jax.Array]:
    B = x.shape[0]
    pre = (
        jnp.einsum("btd,dg->bg", x.astype(jnp.float32), p.w_in) + p.b
    )
    new = _slstm_cell(p, dims, state, pre)
    h = new.h.reshape(B, 1, dims.d_model).astype(x.dtype)
    h = group_norm(h, p.gn_scale, n_groups=dims.n_heads)
    gte = jnp.einsum("btd,du->btu", h, p.w_gate)
    up = jnp.einsum("btd,du->btu", h, p.w_upp)
    y = jax.nn.gelu(gte.astype(jnp.float32)).astype(x.dtype) * up
    return new, jnp.einsum("btu,ud->btd", y, p.w_down)
