"""Grouped-query attention: blockwise (flash-style) prefill + cached decode.

Pure-jnp implementation used everywhere lowering must succeed (the Pallas
flash kernel in ``repro.kernels.flash_attention`` is numerically checked
against THIS module's math and is switched in on real TPU builds).

Memory discipline: scores are never materialized beyond a
(q_chunk x kv_chunk) tile — mandatory for the 32k prefill shapes.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers.rope import apply_rope
from repro.models.sharding import shard

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array  # (d, nq, hd)
    wk: jax.Array  # (d, nkv, hd)
    wv: jax.Array  # (d, nkv, hd)
    wo: jax.Array  # (nq, hd, d)
    bq: Optional[jax.Array]  # (nq, hd) | None
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool, dtype) -> AttnParams:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = (n_heads * head_dim) ** -0.5
    mk = lambda k, shape, s: (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)
    return AttnParams(
        wq=mk(kq, (d_model, n_heads, head_dim), s_in),
        wk=mk(kk, (d_model, n_kv, head_dim), s_in),
        wv=mk(kv, (d_model, n_kv, head_dim), s_in),
        wo=mk(ko, (n_heads, head_dim, d_model), s_out),
        bq=jnp.zeros((n_heads, head_dim), dtype) if qkv_bias else None,
        bk=jnp.zeros((n_kv, head_dim), dtype) if qkv_bias else None,
        bv=jnp.zeros((n_kv, head_dim), dtype) if qkv_bias else None,
    )


def project_qkv(p: AttnParams, x: jax.Array, positions: jax.Array,
                rope_theta: float):
    """x: (B, T, d) -> q (B,T,nq,hd), k/v (B,T,nkv,hd), rope applied."""
    q = jnp.einsum("btd,dnh->btnh", x, p.wq)
    k = jnp.einsum("btd,dnh->btnh", x, p.wk)
    v = jnp.einsum("btd,dnh->btnh", x, p.wv)
    if p.bq is not None:
        q = q + p.bq
        k = k + p.bk
        v = v + p.bv
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _chunked(x: jax.Array, chunk: int) -> jax.Array:
    """(B, T, ...) -> (n_chunks, B, chunk, ...)."""
    B, T = x.shape[:2]
    n = T // chunk
    return jnp.moveaxis(x.reshape(B, n, chunk, *x.shape[2:]), 1, 0)


def reshard_for_attention(q: jax.Array, k: jax.Array, v: jax.Array):
    """Re-shard q/k/v for the blockwise tile loops.

    The residual stream is sequence-sharded over ``model`` (cheap to keep
    resident), but slicing an S-sharded k/v inside the tile scan emits a
    halo exchange PER TILE (measured: tens of thousands of small
    all-gathers/permutes per step). Gathering k/v's sequence dim ONCE here
    and sharding q's heads over ``model`` (when divisible — GQA kv heads
    are few and stay replicated) turns that into 2 activation-sized
    collectives per layer: the Megatron attention layout, entered from a
    sequence-parallel residual.
    """
    from repro.models.sharding import current_rules

    rules = current_rules()
    if rules is None:
        return q, k, v
    model_n = rules.mesh.shape.get("model", 1)
    if q.shape[2] % model_n:
        # non-divisible head counts: measured BOTH alternatives (§Perf) —
        # pad-sharding q and replicating q each cost MORE collective
        # traffic than leaving the sequence-sharded layout alone
        return q, k, v
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, None, None)
    v = shard(v, "batch", None, None, None)
    return q, k, v


def _tile_dead(causal: bool, window, q_start, q_chunk, k_start, kv_chunk):
    """True when a (q, kv) tile is fully masked and can be skipped."""
    dead = jnp.asarray(False)
    if causal:
        dead = jnp.logical_or(dead, k_start > q_start + q_chunk - 1)
    dead = jnp.logical_or(
        dead, (window > 0) & (k_start + kv_chunk - 1 <= q_start - window)
    )
    return dead


def _tile_mask(causal: bool, win_eff, q_start, q_chunk, k_start, kv_chunk):
    qpos = q_start + jnp.arange(q_chunk)
    kpos = k_start + jnp.arange(kv_chunk)
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    mask &= qpos[:, None] - kpos[None, :] < win_eff
    return mask


def blockwise_attention(
    q: jax.Array,           # (B, T, nq, hd)
    k: jax.Array,           # (B, S, nkv, hd)
    v: jax.Array,           # (B, S, nkv, hd)
    *,
    causal: bool = True,
    window=0,               # 0 = full causal; may be a traced int32 scalar
    q_chunk: int = 512,
    kv_chunk: int = 512,
    q_offset: int = 0,      # absolute position of q[0] relative to k[0]
) -> jax.Array:
    """FlashAttention-style blockwise attention with a custom VJP.

    Forward: online softmax over (q_chunk x kv_chunk) tiles; only one tile
    of scores is ever live. Backward: recomputes tile probabilities from
    the saved logsumexp (the flash backward), so NOTHING per-tile is saved
    — without this, ``lax.scan``'s reverse pass would checkpoint every
    tile's softmax (O(T*S) memory, unlowerable at 32k).

    Fully-masked tiles are skipped with ``lax.cond`` in both passes.
    ``window`` may be a traced scalar (per-layer dynamic patterns under
    ``lax.scan``, e.g. gemma3's 5:1 local:global); 0 disables windowing.
    """
    B, T, nq, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    group = nq // nkv
    q_chunk = min(q_chunk, T)
    kv_chunk = min(kv_chunk, S)
    if T % q_chunk:
        q_chunk = T  # fallback; shapes in this repo keep chunks divisible
    if S % kv_chunk:
        kv_chunk = S
    scale = hd ** -0.5
    n_qc, n_kc = T // q_chunk, S // kv_chunk
    no_window = jnp.iinfo(jnp.int32).max

    def _forward(qf, kf, vf, window):
        """Returns out (B,T,nq,hd) fp32-accurate and lse (nqc,B,nkv,group,qc)."""
        win_eff = jnp.where(window > 0, window, no_window)
        qc = _chunked(qf.reshape(B, T, nkv, group, hd), q_chunk)
        kc = _chunked(kf, kv_chunk)
        vc = _chunked(vf, kv_chunk)

        def per_q_chunk(carry, inp):
            qi, q_blk = inp
            q_start = qi * q_chunk + q_offset

            def kv_step(state, kv_inp):
                ki, k_blk, v_blk = kv_inp
                acc, m, l = state
                k_start = ki * kv_chunk

                def attend(_):
                    s = jnp.einsum(
                        "bqngh,bknh->bngqk", q_blk, k_blk,
                        preferred_element_type=jnp.float32,
                    ) * scale
                    mask = _tile_mask(causal, win_eff, q_start, q_chunk,
                                      k_start, kv_chunk)
                    s = jnp.where(mask, s, NEG_INF)
                    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                    p = jnp.exp(s - m_new[..., None])
                    alpha = jnp.exp(m - m_new)
                    l_new = l * alpha + jnp.sum(p, axis=-1)
                    acc_new = acc * alpha[..., None] + jnp.einsum(
                        "bngqk,bknh->bngqh", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32,
                    )
                    return acc_new, m_new, l_new

                dead = _tile_dead(causal, window, q_start, q_chunk,
                                  k_start, kv_chunk)
                new_state = jax.lax.cond(
                    dead, lambda _: (acc, m, l), attend, operand=None
                )
                return new_state, None

            acc0 = jnp.zeros((B, nkv, group, q_chunk, hd), jnp.float32)
            m0 = jnp.full((B, nkv, group, q_chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, nkv, group, q_chunk), jnp.float32)
            (acc, m, l), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0), (jnp.arange(n_kc), kc, vc)
            )
            lsafe = jnp.maximum(l, 1e-30)
            out = acc / lsafe[..., None]
            out = jnp.moveaxis(out, 3, 1).reshape(B, q_chunk, nkv * group, hd)
            lse = m + jnp.log(lsafe)                  # (B, nkv, group, qc)
            return carry, (out.astype(qf.dtype), lse)

        _, (outs, lses) = jax.lax.scan(
            per_q_chunk, None, (jnp.arange(n_qc), qc)
        )
        out = jnp.moveaxis(outs, 0, 1).reshape(B, T, nq, hd)
        return out, lses

    @jax.custom_vjp
    def attn(qf, kf, vf, window):
        return _forward(qf, kf, vf, window)[0]

    def attn_fwd(qf, kf, vf, window):
        out, lses = _forward(qf, kf, vf, window)
        return out, (qf, kf, vf, window, out, lses)

    def attn_bwd(res, dout):
        qf, kf, vf, window, out, lses = res
        win_eff = jnp.where(window > 0, window, no_window)
        qcs = _chunked(qf.reshape(B, T, nkv, group, hd), q_chunk)
        kcs = _chunked(kf, kv_chunk)
        vcs = _chunked(vf, kv_chunk)
        docs = _chunked(dout.reshape(B, T, nkv, group, hd), q_chunk)
        outs = _chunked(out.reshape(B, T, nkv, group, hd), q_chunk)
        # delta_i = sum_h dout_i * out_i  (per query position, fp32)
        deltas = jnp.sum(
            docs.astype(jnp.float32) * outs.astype(jnp.float32), axis=-1
        )                                             # (nqc,B,qc,nkv,group)
        deltas = jnp.moveaxis(deltas, 2, 4)           # (nqc,B,nkv,group,qc)

        def per_kv_chunk(dq_acc, kv_inp):
            ki, k_blk, v_blk = kv_inp
            k_start = ki * kv_chunk

            def per_q_chunk(state, q_inp):
                dk_blk, dv_blk = state
                qi, q_blk, do_blk, lse_blk, dl_blk = q_inp
                q_start = qi * q_chunk + q_offset

                def attend(_):
                    s = jnp.einsum(
                        "bqngh,bknh->bngqk", q_blk, k_blk,
                        preferred_element_type=jnp.float32,
                    ) * scale
                    mask = _tile_mask(causal, win_eff, q_start, q_chunk,
                                      k_start, kv_chunk)
                    s = jnp.where(mask, s, NEG_INF)
                    p = jnp.exp(s - lse_blk[..., None])   # (B,n,g,qc,kc) f32
                    pb = p.astype(k_blk.dtype)
                    do_r = jnp.moveaxis(do_blk, 1, 3)     # (B,n,g,qc,hd)
                    dv_c = jnp.einsum("bngqk,bngqh->bknh", pb, do_r,
                                      preferred_element_type=jnp.float32)
                    dp = jnp.einsum("bngqh,bknh->bngqk", do_r, v_blk,
                                    preferred_element_type=jnp.float32)
                    ds = p * (dp - dl_blk[..., None]) * scale
                    dsb = ds.astype(k_blk.dtype)
                    dq_c = jnp.einsum("bngqk,bknh->bngqh", dsb, k_blk,
                                      preferred_element_type=jnp.float32)
                    dk_c = jnp.einsum("bngqk,bngqh->bknh", dsb,
                                      jnp.moveaxis(q_blk, 1, 3),
                                      preferred_element_type=jnp.float32)
                    return dk_blk + dk_c, dv_blk + dv_c, dq_c

                dead = _tile_dead(causal, window, q_start, q_chunk,
                                  k_start, kv_chunk)
                dk_new, dv_new, dq_c = jax.lax.cond(
                    dead,
                    lambda _: (
                        dk_blk, dv_blk,
                        jnp.zeros((B, nkv, group, q_chunk, hd), jnp.float32),
                    ),
                    attend,
                    operand=None,
                )
                return (dk_new, dv_new), dq_c

            z = jnp.zeros((B, kv_chunk, nkv, hd), jnp.float32)
            (dk_blk, dv_blk), dq_chunks = jax.lax.scan(
                per_q_chunk, (z, z),
                (jnp.arange(n_qc), qcs, docs, lses, deltas),
            )
            return dq_acc + dq_chunks, (dk_blk, dv_blk)

        dq0 = jnp.zeros((n_qc, B, nkv, group, q_chunk, hd), jnp.float32)
        dq_acc, (dks, dvs) = jax.lax.scan(
            per_kv_chunk, dq0, (jnp.arange(n_kc), kcs, vcs)
        )
        # reassemble: dq (nqc,B,n,g,qc,hd) -> (B,T,nq,hd)
        dq = jnp.moveaxis(jnp.moveaxis(dq_acc, 4, 2), 0, 1)
        dq = dq.reshape(B, T, nq, hd).astype(qf.dtype)
        dk = jnp.moveaxis(dks, 0, 1).reshape(B, S, nkv, hd).astype(kf.dtype)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(B, S, nkv, hd).astype(vf.dtype)
        return dq, dk, dv, None

    attn.defvjp(attn_fwd, attn_bwd)
    return attn(q, k, v, jnp.asarray(window, jnp.int32))


def decode_attention(
    q: jax.Array,            # (B, 1, nq, hd)
    k_cache: jax.Array,      # (B, S, nkv, hd)  (circular for windowed layers)
    v_cache: jax.Array,
    valid_mask: jax.Array,   # (B, S) bool — which cache slots are live
) -> jax.Array:
    """Single-token attention against a cache. Scores are (B, nq, S).

    The cache stays in its storage dtype (bf16): the matmuls accumulate in
    fp32 via ``preferred_element_type`` — casting the cache itself would
    materialize a full fp32 copy of every layer's KV (the dominant decode
    memory term at 32k).
    """
    B, _, nq, hd = q.shape
    nkv = k_cache.shape[2]
    group = nq // nkv
    scale = hd ** -0.5
    qf = q.reshape(B, nkv, group, hd)
    s = jnp.einsum(
        "bngh,bsnh->bngs", qf, k_cache,
        preferred_element_type=jnp.float32,
    ) * scale
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bngs,bsnh->bngh", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, nq, hd).astype(q.dtype)


def cross_attention(
    p: AttnParams,
    x: jax.Array,            # (B, T, d) decoder stream
    enc_k: jax.Array,        # (B, S, nkv, hd) precomputed encoder keys
    enc_v: jax.Array,
) -> jax.Array:
    """Full (non-causal) cross-attention; S is small (encoder frames)."""
    B, T, _ = x.shape
    q = jnp.einsum("btd,dnh->btnh", x, p.wq)
    if p.bq is not None:
        q = q + p.bq
    nq, hd = q.shape[2], q.shape[3]
    nkv = enc_k.shape[2]
    group = nq // nkv
    scale = hd ** -0.5
    qf = q.reshape(B, T, nkv, group, hd).astype(jnp.float32)
    s = jnp.einsum("btngh,bsnh->bngts", qf, enc_k.astype(jnp.float32)) * scale
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngts,bsnh->btngh", pr, enc_v.astype(jnp.float32))
    o = o.reshape(B, T, nq, hd).astype(x.dtype)
    return jnp.einsum("btnh,nhd->btd", o, p.wo)


def attention_output(p: AttnParams, attn: jax.Array) -> jax.Array:
    """(B, T, nq, hd) @ wo -> (B, T, d)."""
    return jnp.einsum("btnh,nhd->btd", attn, p.wo)
