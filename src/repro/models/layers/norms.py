"""Normalization layers (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm computed in fp32, cast back to the input dtype.

    Uses the gemma-style ``(1 + scale)`` parameterization so zero-init
    scales are the identity transform.
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype=jnp.float32):
    return jnp.zeros((d,), dtype=dtype)


def group_norm(x: jnp.ndarray, scale: jnp.ndarray, n_groups: int,
               eps: float = 1e-6) -> jnp.ndarray:
    """Per-head group norm used by the xLSTM/Mamba gated-norm paths.

    x: (..., d) normalized independently in ``n_groups`` equal groups.
    """
    dtype = x.dtype
    *lead, d = x.shape
    g = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mean = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.var(g, axis=-1, keepdims=True)
    y = (g - mean) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*lead, d)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)
