"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for half the head dimension (fp32)."""
    half = head_dim // 2
    exponents = jnp.arange(half, dtype=jnp.float32) / half
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x`` of shape (B, T, H, D) by per-token ``positions`` (B, T).

    Split-halves convention (as in Llama/NeoX): rotate (x1, x2) ->
    (x1*cos - x2*sin, x2*cos + x1*sin).
    """
    B, T, H, D = x.shape
    inv_freq = rope_frequencies(D, theta)                  # (D/2,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]                   # (B, T, 1, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : D // 2], x32[..., D // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
