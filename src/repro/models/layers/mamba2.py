"""Mamba2 (SSD — state-space duality) block, TPU-adapted.

Chunked SSD for train/prefill: intra-chunk quadratic attention-like term +
inter-chunk state recurrence via ``lax.scan`` (chunk length from config;
the quadratic tile is MXU-friendly). O(1)-state recurrent step for decode.

Follows the minimal SSD formulation of Dao & Gu (arXiv:2405.21060) with a
single B/C group shared across heads (ngroups=1), causal depthwise conv on
(x, B, C), softplus dt with per-head bias, and a gated group norm.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.norms import group_norm


class Mamba2Params(NamedTuple):
    w_in: jax.Array       # (d_model, 2*d_inner + 2*N + H)
    conv_w: jax.Array     # (conv_width, d_inner + 2*N) depthwise
    dt_bias: jax.Array    # (H,)
    a_log: jax.Array      # (H,)
    d_skip: jax.Array     # (H,)
    norm_scale: jax.Array  # (d_inner,)
    w_out: jax.Array      # (d_inner, d_model)


class Mamba2Dims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    state: int
    conv_width: int
    chunk: int


def dims_from_config(cfg) -> Mamba2Dims:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = s.n_ssm_heads or (d_inner // s.head_dim)
    return Mamba2Dims(
        d_model=cfg.d_model,
        d_inner=d_inner,
        n_heads=n_heads,
        head_dim=s.head_dim,
        state=s.state_dim,
        conv_width=s.conv_width,
        chunk=s.chunk,
    )


def init_mamba2(key, dims: Mamba2Dims, dtype) -> Mamba2Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d, di, H, N, W = (
        dims.d_model, dims.d_inner, dims.n_heads, dims.state, dims.conv_width
    )
    s_in = d ** -0.5
    return Mamba2Params(
        w_in=(jax.random.normal(k1, (d, 2 * di + 2 * N + H), jnp.float32) * s_in).astype(dtype),
        conv_w=(jax.random.normal(k2, (W, di + 2 * N), jnp.float32) * 0.3).astype(dtype),
        dt_bias=jnp.full((H,), -3.0, jnp.float32),  # softplus ~= 0.05
        a_log=jnp.zeros((H,), jnp.float32),         # A = -exp(0) = -1
        d_skip=jnp.ones((H,), jnp.float32),
        norm_scale=jnp.zeros((di,), dtype),
        w_out=(jax.random.normal(k3, (di, d), jnp.float32) * di ** -0.5).astype(dtype),
    )


def _split_in(proj: jax.Array, dims: Mamba2Dims):
    di, N, H = dims.d_inner, dims.state, dims.n_heads
    z = proj[..., :di]
    xbc = proj[..., di: 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, conv_w: jax.Array) -> jax.Array:
    """Depthwise causal conv over time: xbc (B, T, C), conv_w (W, C)."""
    W = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    T = xbc.shape[1]
    for k in range(W):
        out = out + pad[:, k: k + T, :] * conv_w[k]
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype)


def mamba2_forward(
    p: Mamba2Params, dims: Mamba2Dims, x: jax.Array
) -> jax.Array:
    """x: (B, T, d_model) -> (B, T, d_model). T divisible by chunk (or
    chunk clipped to T)."""
    B, T, _ = x.shape
    di, H, P, N = dims.d_inner, dims.n_heads, dims.head_dim, dims.state
    L = min(dims.chunk, T)
    if T % L:
        L = T
    nc = T // L

    proj = jnp.einsum("btd,de->bte", x, p.w_in)
    z, xbc, dt_raw = _split_in(proj, dims)
    xbc = _causal_conv(xbc, p.conv_w)
    xs = xbc[..., :di].reshape(B, T, H, P)
    Bm = xbc[..., di: di + N].astype(jnp.float32)           # (B, T, N)
    Cm = xbc[..., di + N:].astype(jnp.float32)              # (B, T, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)  # (B, T, H)
    A = -jnp.exp(p.a_log)                                   # (H,)
    lam = dt * A                                            # (B, T, H) log-decay (<0)
    xdt = xs.astype(jnp.float32) * dt[..., None]            # (B, T, H, P)

    # chunk views, chunk dim leading for the scan
    ch = lambda a: jnp.moveaxis(a.reshape(B, nc, L, *a.shape[2:]), 1, 0)
    lam_c, B_c, C_c, xdt_c = ch(lam), ch(Bm), ch(Cm), ch(xdt)
    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(h, inp):
        lam_, B_, C_, xdt_ = inp        # (B,L,H), (B,L,N), (B,L,N), (B,L,H,P)
        cum = jnp.cumsum(lam_, axis=1)                      # (B, L, H)
        # intra-chunk: W[t,s] = C_t.B_s * exp(cum_t - cum_s), s <= t
        cb = jnp.einsum("btm,bsm->bts", C_, B_)             # (B, L, L)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        w = cb[..., None] * jnp.where(
            causal[None, :, :, None], decay, 0.0
        )                                                   # (B, t, s, H)
        y = jnp.einsum("btsh,bshp->bthp", w, xdt_)
        # inter-chunk: y[t] += C_t . h_chunk_start * exp(cum_t)
        y = y + jnp.einsum("btm,bhmp,bth->bthp", C_, h, jnp.exp(cum))
        # state update to chunk end
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)        # (B, L, H)
        S = jnp.einsum("blh,blm,blhp->bhmp", decay_to_end, B_, xdt_)
        h_new = h * jnp.exp(cum[:, -1, :])[..., None, None] + S
        return h_new, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    # checkpoint: scan-reverse otherwise saves every chunk's (L, L, H)
    # decay tensor — recompute instead (same trick as flash attention)
    chunk_step_ck = jax.checkpoint(chunk_step, prevent_cse=False)
    _, ys = jax.lax.scan(chunk_step_ck, h0, (lam_c, B_c, C_c, xdt_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)          # (B, T, H, P)
    y = y + xs.astype(jnp.float32) * p.d_skip[None, None, :, None]
    y = y.reshape(B, T, di)

    # gated norm + out projection
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = group_norm(y, p.norm_scale, n_groups=H)
    return jnp.einsum("bte,ed->btd", y, p.w_out)


# -- decode -----------------------------------------------------------------


class Mamba2Cache(NamedTuple):
    conv: jax.Array   # (B, W-1, d_inner + 2N) last inputs
    state: jax.Array  # (B, H, N, P) fp32


def init_mamba2_cache(batch: int, dims: Mamba2Dims, dtype) -> Mamba2Cache:
    return Mamba2Cache(
        conv=jnp.zeros(
            (batch, dims.conv_width - 1, dims.d_inner + 2 * dims.state), dtype
        ),
        state=jnp.zeros(
            (batch, dims.n_heads, dims.state, dims.head_dim), jnp.float32
        ),
    )


def mamba2_decode_step(
    p: Mamba2Params, dims: Mamba2Dims, cache: Mamba2Cache, x: jax.Array
) -> Tuple[Mamba2Cache, jax.Array]:
    """x: (B, 1, d_model) one token -> (new_cache, y (B, 1, d_model))."""
    B = x.shape[0]
    di, H, P, N, W = (
        dims.d_inner, dims.n_heads, dims.head_dim, dims.state, dims.conv_width
    )
    proj = jnp.einsum("btd,de->bte", x, p.w_in)
    z, xbc_new, dt_raw = _split_in(proj, dims)
    window = jnp.concatenate([cache.conv, xbc_new], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, p.conv_w)[:, None, :]
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)

    xs = xbc[..., :di].reshape(B, H, P)
    Bm = xbc[:, 0, di: di + N].astype(jnp.float32)           # (B, N)
    Cm = xbc[:, 0, di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p.dt_bias)  # (B, H)
    dec = jnp.exp(dt * -jnp.exp(p.a_log))                    # (B, H)
    xdt = xs.astype(jnp.float32) * dt[..., None]             # (B, H, P)

    state = cache.state * dec[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bm, xdt
    )
    y = jnp.einsum("bn,bhnp->bhp", Cm, state)
    y = y + xs.astype(jnp.float32) * p.d_skip[None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = group_norm(y, p.norm_scale, n_groups=H)
    out = jnp.einsum("bte,ed->btd", y, p.w_out)
    new_cache = Mamba2Cache(conv=window[:, 1:, :], state=state)
    return new_cache, out
