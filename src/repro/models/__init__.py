"""Model stack: the 10 assigned architectures as composable JAX modules."""
from repro.models.base import Model
from repro.models.registry import build_model

__all__ = ["Model", "build_model"]
