"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv frontend is stubbed per the task carve-out:
inputs are precomputed frame embeddings (B, n_frames, d_model). The
encoder is a bidirectional transformer over frames; the decoder adds
cross-attention to the encoder output. Decode caches: self-attn ring
buffer + precomputed cross-attn K/V per layer.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import (
    Model,
    next_token_loss,
    embed_tokens,
    init_embedding,
    lm_logits,
)
from repro.models.cache import (
    AttnCache,
    attn_cache_spec,
    cache_valid_mask,
    init_attn_cache,
    update_attn_cache,
)
from repro.models.layers.attention import (
    reshard_for_attention,
    AttnParams,
    attention_output,
    blockwise_attention,
    cross_attention,
    decode_attention,
    init_attention,
    project_qkv,
)
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.norms import rms_norm
from repro.models.runtime_flags import maybe_scan
from repro.models.sharding import shard

PyTree = Any


def _init_enc_layer(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    dtype = cfg.param_dtype
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(
            ka, cfg.d_model, cfg.n_heads, cfg.n_heads,
            cfg.resolved_head_dim, False, dtype,
        ),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ka, kx, km = jax.random.split(key, 3)
    dtype = cfg.param_dtype
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, False, dtype,
        ),
        "lnx": jnp.zeros((cfg.d_model,), dtype),
        "xattn": init_attention(
            kx, cfg.d_model, cfg.n_heads, cfg.n_heads,
            cfg.resolved_head_dim, False, dtype,
        ),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(key, cfg: ModelConfig) -> Dict[str, PyTree]:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array,
           remat: bool = True) -> jax.Array:
    """frames: (B, S, d) stub embeddings -> encoder output (B, S, d)."""
    h = shard(frames, "batch", None, None)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(hh, layer):
        x = rms_norm(hh, layer["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(layer["attn"], x, positions, cfg.rope_theta)
        q, k, v = reshard_for_attention(q, k, v)
        attn = blockwise_attention(q, k, v, causal=False)
        hh = hh + attention_output(layer["attn"], attn)
        x = rms_norm(hh, layer["ln2"], cfg.norm_eps)
        hh = hh + mlp(layer["mlp"], x)
        return hh, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = maybe_scan(body, h, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _enc_kv(layer, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output for one layer."""
    p: AttnParams = layer["xattn"]
    k = jnp.einsum("bsd,dnh->bsnh", enc_out, p.wk)
    v = jnp.einsum("bsd,dnh->bsnh", enc_out, p.wv)
    return k, v


def decoder_forward(params, cfg: ModelConfig, tokens: jax.Array,
                    enc_out: jax.Array, remat: bool = True) -> jax.Array:
    h = embed_tokens(params["embed"], tokens)
    B, T = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(hh, layer):
        x = rms_norm(hh, layer["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(layer["attn"], x, positions, cfg.rope_theta)
        q, k, v = reshard_for_attention(q, k, v)
        attn = blockwise_attention(q, k, v, causal=True)
        hh = hh + attention_output(layer["attn"], attn)
        x = rms_norm(hh, layer["lnx"], cfg.norm_eps)
        ek, ev = _enc_kv(layer, enc_out, cfg)
        hh = hh + cross_attention(layer["xattn"], x, ek, ev)
        x = rms_norm(hh, layer["ln2"], cfg.norm_eps)
        hh = hh + mlp(layer["mlp"], x)
        hh = shard(hh, "batch", "seq", None)
        return hh, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = maybe_scan(body, h, params["dec_layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def encdec_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    enc_out = encode(params, cfg, batch["audio_frames"])
    h = decoder_forward(params, cfg, batch["tokens"], enc_out)
    loss = next_token_loss(h, params["embed"], None, batch["labels"])
    return loss, {"ce": loss}


def encdec_prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    enc_out = encode(params, cfg, batch["audio_frames"], remat=False)
    h = decoder_forward(params, cfg, batch["tokens"], enc_out, remat=False)
    return lm_logits(h[:, -1:, :], params["embed"], None)[:, 0]


# -- decode -----------------------------------------------------------------


class EncDecCache(NamedTuple):
    self_kv: AttnCache        # decoder self-attn ring cache
    cross_k: jax.Array        # (B, S_enc, nH, hd) precomputed
    cross_v: jax.Array


def encdec_init_cache(cfg: ModelConfig, batch: int, length: int,
                      dtype=None, force_local: bool = False,
                      spec_only: bool = False) -> List[EncDecCache]:
    dtype = dtype or cfg.param_dtype
    S_enc = cfg.n_audio_frames
    caches = []
    for _ in range(cfg.n_layers):
        if spec_only:
            kv = attn_cache_spec(batch, length, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype)
            x = jax.ShapeDtypeStruct(
                (batch, S_enc, cfg.n_heads, cfg.resolved_head_dim), dtype
            )
        else:
            kv = init_attn_cache(batch, length, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype)
            x = jnp.zeros(
                (batch, S_enc, cfg.n_heads, cfg.resolved_head_dim), dtype
            )
        caches.append(EncDecCache(self_kv=kv, cross_k=x, cross_v=x))
    return caches


def encdec_decode_step(params, cfg: ModelConfig, cache: List[EncDecCache],
                       token: jax.Array, pos: jax.Array,
                       force_local: bool = False):
    B = token.shape[0]
    h = embed_tokens(params["embed"], token)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    new_cache: List[EncDecCache] = []
    for li in range(cfg.n_layers):
        layer = jax.tree_util.tree_map(lambda l: l[li], params["dec_layers"])
        x = rms_norm(h, layer["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(layer["attn"], x, positions, cfg.rope_theta)
        c = update_attn_cache(cache[li].self_kv, k, v, pos)
        valid = cache_valid_mask(c.k.shape[1], pos, B)
        attn = decode_attention(q, c.k, c.v, valid)
        h = h + attention_output(layer["attn"], attn)
        x = rms_norm(h, layer["lnx"], cfg.norm_eps)
        h = h + cross_attention(
            layer["xattn"], x, cache[li].cross_k, cache[li].cross_v
        )
        x = rms_norm(h, layer["ln2"], cfg.norm_eps)
        h = h + mlp(layer["mlp"], x)
        new_cache.append(EncDecCache(self_kv=c, cross_k=cache[li].cross_k,
                                     cross_v=cache[li].cross_v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return new_cache, lm_logits(h, params["embed"], None)[:, 0]


def build_encdec(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda rng: init_encdec(rng, cfg),
        loss=lambda p, b: encdec_loss(p, cfg, b),
        prefill=lambda p, b: encdec_prefill(p, cfg, b),
        init_cache=functools.partial(encdec_init_cache, cfg),
        decode_step=lambda p, c, t, pos, **kw: encdec_decode_step(
            p, cfg, c, t, pos, **kw
        ),
    )
