"""Generic GQA decoder: dense, MoE, and VLM families.

Train/prefill scan over a stacked layer pytree (compile-time O(1 layer));
decode unrolls layers in Python so per-layer caches may have heterogeneous
shapes (window-length ring buffers for sliding-window layers).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import (
    Model,
    next_token_loss,
    embed_tokens,
    init_embedding,
    lm_logits,
)
from repro.models.cache import (
    AttnCache,
    attn_cache_spec,
    cache_valid_mask,
    init_attn_cache,
    update_attn_cache,
)
from repro.models.layers.attention import (
    reshard_for_attention,
    attention_output,
    blockwise_attention,
    decode_attention,
    init_attention,
    project_qkv,
)
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.moe import init_moe, moe
from repro.models.layers.norms import rms_norm
from repro.models.runtime_flags import maybe_scan
from repro.models.sharding import shard

PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig) -> Dict[str, PyTree]:
    ka, km = jax.random.split(key)
    dtype = cfg.param_dtype
    layer: Dict[str, PyTree] = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qkv_bias, dtype,
        ),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.moe is not None:
        layer["moe"] = init_moe(
            km, cfg.d_model, cfg.d_ff, cfg.moe.n_experts, cfg.moe.n_shared,
            dtype,
        )
    else:
        layer["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, dtype)
    return layer


def init_decoder(key, cfg: ModelConfig) -> Dict[str, PyTree]:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    params: Dict[str, PyTree] = {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_embedding(
            kh, cfg.vocab, cfg.d_model, cfg.param_dtype
        ).T
    return params


def layer_windows(cfg: ModelConfig, force_local: bool = False) -> list:
    """Per-layer window sizes as a static python list (0 = global).
    Implements the local:global pattern (gemma3: 5 local then 1 global)."""
    w, ratio = cfg.attn.sliding_window, cfg.attn.local_to_global
    if w == 0:
        return [0] * cfg.n_layers
    if ratio == 0 or force_local:
        return [w] * cfg.n_layers
    return [0 if i % (ratio + 1) == ratio else w for i in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# forward (train / prefill) — scan over layers
# ---------------------------------------------------------------------------


def _layer_forward(cfg: ModelConfig, layer: Dict[str, PyTree],
                   h: jax.Array, positions: jax.Array,
                   window) -> Tuple[jax.Array, jax.Array]:
    """One decoder layer. Returns (h, moe_aux)."""
    x = rms_norm(h, layer["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(layer["attn"], x, positions, cfg.rope_theta)
    q, k, v = reshard_for_attention(q, k, v)
    attn = blockwise_attention(q, k, v, causal=True, window=window)
    h = h + attention_output(layer["attn"], attn)
    h = shard(h, "batch", "seq", None)
    x = rms_norm(h, layer["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe(layer["moe"], x, cfg.moe.top_k, cfg.moe.capacity_factor)
    else:
        y, aux = mlp(layer["mlp"], x), jnp.zeros((), jnp.float32)
    h = h + y
    h = shard(h, "batch", "seq", None)
    return h, aux


def decoder_hidden(
    params: Dict[str, PyTree],
    cfg: ModelConfig,
    tokens: jax.Array,
    patch_embeds: Optional[jax.Array] = None,
    remat: bool = True,
) -> Tuple[jax.Array, jax.Array, int]:
    """Embeds (+ VLM patch prefix), scans layers. Returns
    (hidden (B, T', d), total moe aux, text_offset)."""
    h = embed_tokens(params["embed"], tokens)
    offset = 0
    if patch_embeds is not None:
        h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
        offset = patch_embeds.shape[1]
        h = shard(h, "batch", "seq", None)
    B, T = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    windows = jnp.asarray(layer_windows(cfg), jnp.int32)

    def body(carry, xs):
        hh, aux = carry
        layer, win = xs
        hh, a = _layer_forward(cfg, layer, hh, positions, win)
        return (hh, aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), _ = maybe_scan(body, (h, jnp.zeros((), jnp.float32)),
                             (params["layers"], windows))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux, offset


def decoder_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    h, aux, offset = decoder_hidden(
        params, cfg, batch["tokens"], batch.get("patch_embeds")
    )
    if offset:
        h = h[:, offset:, :]
    loss = next_token_loss(
        h, params["embed"], params.get("head"), batch["labels"]
    )
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux / cfg.n_layers
    return loss, {"ce": loss, "moe_aux": aux}


def decoder_prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """Last-position logits (B, vocab)."""
    h, _, _ = decoder_hidden(
        params, cfg, batch["tokens"], batch.get("patch_embeds"), remat=False
    )
    return lm_logits(h[:, -1:, :], params["embed"], params.get("head"))[:, 0]


# ---------------------------------------------------------------------------
# decode — unrolled layers, per-layer ring caches
# ---------------------------------------------------------------------------


def decoder_init_cache(cfg: ModelConfig, batch: int, length: int,
                       dtype=None, force_local: bool = False,
                       spec_only: bool = False) -> List[AttnCache]:
    dtype = dtype or cfg.param_dtype
    wins = layer_windows(cfg, force_local)
    mk = attn_cache_spec if spec_only else init_attn_cache
    caches = []
    for li in range(cfg.n_layers):
        s = min(length, wins[li]) if wins[li] > 0 else length
        caches.append(
            mk(batch, s, cfg.n_kv_heads, cfg.resolved_head_dim, dtype)
        )
    return caches


def _take_layer(layers: PyTree, i: int) -> PyTree:
    return jax.tree_util.tree_map(lambda l: l[i], layers)


def decoder_decode_step(
    params, cfg: ModelConfig, cache: List[AttnCache], token: jax.Array,
    pos: jax.Array, force_local: bool = False,
) -> Tuple[List[AttnCache], jax.Array]:
    """One decode step. token (B, 1) int32, pos scalar int32 (tokens so
    far). Returns (new_cache, logits (B, vocab))."""
    B = token.shape[0]
    h = embed_tokens(params["embed"], token)          # (B, 1, d)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    new_cache: List[AttnCache] = []
    for li in range(cfg.n_layers):
        layer = _take_layer(params["layers"], li)
        x = rms_norm(h, layer["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(layer["attn"], x, positions, cfg.rope_theta)
        c = update_attn_cache(cache[li], k, v, pos)
        # windowed layers use ring caches, which bound the horizon already
        valid = cache_valid_mask(c.k.shape[1], pos, B)
        attn = decode_attention(q, c.k, c.v, valid)
        h = h + attention_output(layer["attn"], attn)
        x = rms_norm(h, layer["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe(layer["moe"], x, cfg.moe.top_k,
                       cfg.moe.capacity_factor)
        else:
            y = mlp(layer["mlp"], x)
        h = h + y
        new_cache.append(c)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(h, params["embed"], params.get("head"))[:, 0]
    return new_cache, logits


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------


def build_decoder(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda rng: init_decoder(rng, cfg),
        loss=lambda p, b: decoder_loss(p, cfg, b),
        prefill=lambda p, b: decoder_prefill(p, cfg, b),
        init_cache=functools.partial(decoder_init_cache, cfg),
        decode_step=lambda p, c, t, pos, **kw: decoder_decode_step(
            p, cfg, c, t, pos, **kw
        ),
    )
