"""Model registry: ModelConfig -> Model facade by family."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.base import Model
from repro.models.decoder import build_decoder
from repro.models.encdec import build_encdec
from repro.models.xlstm import build_xlstm
from repro.models.zamba import build_zamba


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return build_decoder(cfg)
    if cfg.family == "audio":
        return build_encdec(cfg)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return build_xlstm(cfg)
    if cfg.family in ("ssm", "hybrid") and cfg.ssm is not None:
        return build_zamba(cfg)
    raise ValueError(f"unknown family {cfg.family!r} for {cfg.arch_id}")
