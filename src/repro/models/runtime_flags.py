"""Process-wide model-lowering flags.

``scan_layers=False`` unrolls layer stacks instead of ``lax.scan``-ing
them. The dry-run unrolls so ``compiled.cost_analysis()`` counts every
layer (XLA reports while-loop bodies once); interactive/CPU runs keep
scan for O(1-layer) compile times.
"""
from __future__ import annotations

import contextlib

scan_layers: bool = True


@contextlib.contextmanager
def unrolled_layers():
    global scan_layers
    prev = scan_layers
    scan_layers = False
    try:
        yield
    finally:
        scan_layers = prev


def maybe_scan(body, init, xs, length=None):
    """lax.scan when scan_layers else a python loop over the leading dim."""
    import jax
    import jax.numpy as jnp

    if scan_layers:
        return jax.lax.scan(body, init, xs, length=length)
    n = length
    if n is None:
        n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs) if xs is not None else None
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys
