"""Decode-time caches.

Attention layers hold (k, v) ring buffers — full-length for global layers,
window-length for sliding-window layers (this is what makes gemma3-style
long-context decode sub-quadratic in memory). SSM layers hold O(1) states.

Caches are per-layer python lists (decode unrolls layers), so layer types
and cache shapes may differ freely within one model.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.sharding import shard


class AttnCache(NamedTuple):
    k: jax.Array  # (B, S_l, n_kv, hd) — keys stored pre-rotated (RoPE applied)
    v: jax.Array  # (B, S_l, n_kv, hd)


def init_attn_cache(batch: int, length: int, n_kv: int, head_dim: int,
                    dtype) -> AttnCache:
    z = jnp.zeros((batch, length, n_kv, head_dim), dtype)
    return AttnCache(k=z, v=z)


def attn_cache_spec(batch: int, length: int, n_kv: int, head_dim: int,
                    dtype) -> AttnCache:
    s = jax.ShapeDtypeStruct((batch, length, n_kv, head_dim), dtype)
    return AttnCache(k=s, v=s)


def update_attn_cache(cache: AttnCache, k_new: jax.Array, v_new: jax.Array,
                      pos: jax.Array) -> AttnCache:
    """Write one token's (k, v) at ring slot ``pos % S_l``.

    k_new/v_new: (B, 1, n_kv, hd); pos: scalar int32 (lockstep batch).
    """
    S = cache.k.shape[1]
    slot = jnp.mod(pos, S)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    return AttnCache(k=shard_cache(k), v=shard_cache(v))


def shard_cache(x: jax.Array) -> jax.Array:
    """Cache layout: batch over data when possible, else ctx (sequence)."""
    return shard(x, "batch", "ctx", "kv", None)


def cache_valid_mask(cache_len: int, pos: jax.Array, batch: int) -> jax.Array:
    """(B, S_l) mask of live slots after ``pos+1`` tokens have been written.

    Slots fill in order; once the ring wraps, every slot is live.
    """
    idx = jnp.arange(cache_len)
    live = (idx <= pos) | (pos >= cache_len)
    return jnp.broadcast_to(live[None, :], (batch, cache_len))
