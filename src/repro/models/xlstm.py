"""xLSTM language model (arXiv:2405.04517): mLSTM blocks with periodic
sLSTM blocks (xLSTM[a:b] ratio), pre-norm residual stream.

Layer pattern for ``slstm_every = k``: blocks are grouped into segments of
(k-1) mLSTM blocks + 1 sLSTM block; train/prefill scans segments (outer)
and the mLSTM stack (inner) so compile size stays O(1 block). Decode
unrolls and carries recurrent states — O(1) memory in context length, so
long_500k applies.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import (
    Model,
    next_token_loss,
    embed_tokens,
    init_embedding,
    lm_logits,
)
from repro.models.layers.norms import rms_norm
from repro.models.layers.xlstm_layers import (
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
    mlstm_decode_step,
    mlstm_dims,
    mlstm_forward,
    slstm_decode_step,
    slstm_dims,
    slstm_forward,
)
from repro.models.runtime_flags import maybe_scan
from repro.models.sharding import shard

PyTree = Any


def _segment_shape(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_segments, mlstm_per_segment). slstm_every=k -> segments of
    (k-1) mLSTM + 1 sLSTM."""
    k = cfg.xlstm.slstm_every
    if k == 0:
        return 1, cfg.n_layers
    assert cfg.n_layers % k == 0, "n_layers must divide into segments"
    return cfg.n_layers // k, k - 1


def init_xlstm(key, cfg: ModelConfig) -> Dict[str, PyTree]:
    ke, km, ks = jax.random.split(key, 3)
    n_seg, m_per = _segment_shape(cfg)
    mdims = mlstm_dims(cfg)
    sdims = slstm_dims(cfg)
    dtype = cfg.param_dtype

    def seg_m(k):
        return jax.vmap(lambda kk: {
            "norm": jnp.zeros((cfg.d_model,), dtype),
            "cell": init_mlstm(kk, mdims, dtype),
        })(jax.random.split(k, m_per))

    m_keys = jax.random.split(km, n_seg)
    s_keys = jax.random.split(ks, n_seg)
    params = {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "mlstm": jax.vmap(seg_m)(m_keys),  # (n_seg, m_per, ...)
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.xlstm.slstm_every:
        params["slstm"] = jax.vmap(lambda k: {
            "norm": jnp.zeros((cfg.d_model,), dtype),
            "cell": init_slstm(k, sdims, dtype),
        })(s_keys)
    return params


def xlstm_hidden(params, cfg: ModelConfig, tokens: jax.Array,
                 remat: bool = True) -> jax.Array:
    mdims = mlstm_dims(cfg)
    sdims = slstm_dims(cfg)
    h = embed_tokens(params["embed"], tokens)
    n_seg, m_per = _segment_shape(cfg)

    def m_body(hh, layer):
        x = rms_norm(hh, layer["norm"], cfg.norm_eps)
        hh = hh + mlstm_forward(layer["cell"], mdims, x)
        return shard(hh, "batch", "seq", None), None

    if remat:
        m_body = jax.checkpoint(m_body, prevent_cse=False)

    def seg_body(hh, seg):
        hh, _ = maybe_scan(m_body, hh, seg["m"])
        if cfg.xlstm.slstm_every:
            s = seg["s"]
            x = rms_norm(hh, s["norm"], cfg.norm_eps)
            hh = hh + slstm_forward(s["cell"], sdims, x)
            hh = shard(hh, "batch", "seq", None)
        return hh, None

    segs = {"m": params["mlstm"]}
    if cfg.xlstm.slstm_every:
        segs["s"] = params["slstm"]
    if remat:
        seg_body = jax.checkpoint(seg_body, prevent_cse=False)
    h, _ = maybe_scan(seg_body, h, segs)
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def xlstm_loss(params, cfg: ModelConfig, batch):
    h = xlstm_hidden(params, cfg, batch["tokens"])
    loss = next_token_loss(h, params["embed"], None, batch["labels"])
    return loss, {"ce": loss}


def xlstm_prefill(params, cfg: ModelConfig, batch):
    h = xlstm_hidden(params, cfg, batch["tokens"], remat=False)
    return lm_logits(h[:, -1:, :], params["embed"], None)[:, 0]


# -- decode -----------------------------------------------------------------


def xlstm_init_cache(cfg: ModelConfig, batch: int, length: int,
                     dtype=None, force_local: bool = False,
                     spec_only: bool = False) -> List:
    """Recurrent states per block, in block order. ``length`` is unused —
    xLSTM state is O(1) in context length (that's the point)."""
    del length, force_local
    dtype = dtype or cfg.param_dtype
    mdims = mlstm_dims(cfg)
    sdims = slstm_dims(cfg)
    n_seg, m_per = _segment_shape(cfg)
    caches: List = []
    for _ in range(n_seg):
        for _ in range(m_per):
            caches.append(init_mlstm_state(batch, mdims, dtype))
        if cfg.xlstm.slstm_every:
            caches.append(init_slstm_state(batch, sdims))
    if spec_only:
        caches = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), caches
        )
    return caches


def xlstm_decode_step(params, cfg: ModelConfig, cache: List,
                      token: jax.Array, pos: jax.Array,
                      force_local: bool = False):
    del pos, force_local  # recurrent: position only lives in the state
    mdims = mlstm_dims(cfg)
    sdims = slstm_dims(cfg)
    n_seg, m_per = _segment_shape(cfg)
    h = embed_tokens(params["embed"], token)
    new_cache: List = []
    ci = 0
    for si in range(n_seg):
        for mi in range(m_per):
            layer = jax.tree_util.tree_map(
                lambda l: l[si][mi], params["mlstm"]
            )
            x = rms_norm(h, layer["norm"], cfg.norm_eps)
            st, y = mlstm_decode_step(layer["cell"], mdims, cache[ci], x)
            h = h + y
            new_cache.append(st)
            ci += 1
        if cfg.xlstm.slstm_every:
            layer = jax.tree_util.tree_map(lambda l: l[si], params["slstm"])
            x = rms_norm(h, layer["norm"], cfg.norm_eps)
            st, y = slstm_decode_step(layer["cell"], sdims, cache[ci], x)
            h = h + y
            new_cache.append(st)
            ci += 1
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return new_cache, lm_logits(h, params["embed"], None)[:, 0]


def build_xlstm(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda rng: init_xlstm(rng, cfg),
        loss=lambda p, b: xlstm_loss(p, cfg, b),
        prefill=lambda p, b: xlstm_prefill(p, cfg, b),
        init_cache=functools.partial(xlstm_init_cache, cfg),
        decode_step=lambda p, c, t, pos, **kw: xlstm_decode_step(
            p, cfg, c, t, pos, **kw
        ),
    )
