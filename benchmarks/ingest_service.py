"""Ingest front-end benchmark: sustained HTTP uploads/s and client-
observed ingest latency for K tenants x N simulated clients, with
mid-run disconnect injection — then fair-scheduled rounds checked
formula-exact against the trace (zero lost, zero duplicated updates).

  PYTHONPATH=src python benchmarks/ingest_service.py            # full
  PYTHONPATH=src python benchmarks/ingest_service.py --quick    # tier-1

The full run is the acceptance shape: K=4 tenants x 256 clients each
(P=4000 fp32 -> ~16 KiB frames), an uploader worker pool (clients
outnumber threads ~16:1, like real keep-alive front-ends), and for a
deterministic subset of clients a PARTIAL upload first — the frame's
header plus half its body, then a hard socket close mid-request. The
front-end must land nothing for those, the client retries, and every
(tenant, client) registers EXACTLY once: the store count, per-tenant
round inclusion, and the fused-vs-formula check together pin down
"zero lost / zero duplicated".

Emits BENCH_ingest.json (schema in benchmarks/README.md)."""
from __future__ import annotations

import argparse
import json
import queue
import socket
import threading
import time

import numpy as np

from repro.core import AggregationService, FairRoundScheduler, UpdateStore
from repro.serving import HttpStoreClient, encode_update
from repro.workload import (
    FixedSize,
    RegimeSchedule,
    UniformArrivals,
    WorkloadSpec,
    trace_payload,
)


def make_trace(k, n, p, seed):
    spec = WorkloadSpec(
        tenants=tuple(f"app{i}" for i in range(k)),
        n_clients=n, rounds=1,
        regimes=RegimeSchedule.single(UniformArrivals(spread=0.0)),
        sizes=FixedSize(dim=p),
    )
    return spec.build(seed)


def dense_tenant(tenant_round, seed):
    u = np.stack([
        trace_payload(seed, tenant_round.tenant, ev.client_id,
                      tenant_round.dim)
        for ev in tenant_round.events
    ])
    w = np.asarray([ev.weight for ev in tenant_round.events], np.float32)
    return u, w


def fedavg_formula(u, w):
    return np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)


def partial_upload(port, token, body, fraction=0.5):
    """A mid-upload disconnect: send the full request head declaring
    the real Content-Length, then only ``fraction`` of the body, then
    a hard close. The server must land NOTHING for it."""
    cut = max(1, int(len(body) * fraction))
    head = (
        f"POST /v1/upload HTTP/1.1\r\n"
        f"Host: 127.0.0.1\r\n"
        f"Authorization: Bearer {token}\r\n"
        f"Content-Type: application/octet-stream\r\n"
        f"Content-Length: {len(body)}\r\n\r\n"
    ).encode()
    s = socket.create_connection(("127.0.0.1", port), timeout=5.0)
    try:
        s.sendall(head + body[:cut])
        # FIN after the partial body: the server reads the head plus
        # half the payload, then hits EOF short of Content-Length —
        # deterministic (an RST can destroy buffered-but-unread bytes
        # and race the accept, making the server miss the request
        # entirely)
    finally:
        s.close()


def run_uploads(port, tokens, jobs, workers, disconnect_set, seed,
                dim):
    """Drain ``jobs`` ((tenant, client_id, weight)) through a worker
    pool of keep-alive HTTP clients. Clients in ``disconnect_set``
    suffer a mid-upload disconnect FIRST, then upload for real.
    Returns (latencies_seconds, disconnects_injected)."""
    q: "queue.Queue" = queue.Queue()
    for job in jobs:
        q.put(job)
    lat_lists = [[] for _ in range(workers)]
    injected = [0] * workers
    errors = []

    def worker(idx):
        clients = {}
        while True:
            try:
                tenant, cid, weight = q.get_nowait()
            except queue.Empty:
                return
            try:
                cli = clients.get(tenant)
                if cli is None:
                    cli = clients[tenant] = HttpStoreClient(
                        "127.0.0.1", port, token=tokens[tenant],
                        max_attempts=16,
                    )
                u = trace_payload(seed, tenant, cid, dim)
                if (tenant, cid) in disconnect_set:
                    partial_upload(
                        port, tokens[tenant],
                        encode_update(cid, u, weight=weight),
                    )
                    injected[idx] += 1
                t0 = time.perf_counter()
                cli.write(cid, u, weight=weight, tenant=tenant)
                lat_lists[idx].append(time.perf_counter() - t0)
            except BaseException as e:   # pragma: no cover - surfaced
                errors.append(f"{tenant}/{cid}: {e!r}")
                return

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise RuntimeError(f"{len(errors)} upload workers failed: "
                           f"{errors[:5]}")
    lats = sorted(x for lst in lat_lists for x in lst)
    return lats, sum(injected), wall


def bench(k, n, p, workers, disconnects, timeout, seed):
    from repro.serving import IngestServer

    trace = make_trace(k, n, p, seed)
    tenants = [tr.tenant for tr in trace.rounds[0].tenants]
    tokens = {t: f"tok-{t}" for t in tenants}
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=timeout,
        stream_chunk_bytes=max(p * 4 * max(n // 4, 1), 1 << 20),
    )
    jobs, refs, disconnect_set = [], {}, set()
    for tr in trace.rounds[0].tenants:
        refs[tr.tenant] = dense_tenant(tr, seed)
        for i, ev in enumerate(tr.events):
            jobs.append((tr.tenant, ev.client_id, float(ev.weight)))
            if i < disconnects:
                disconnect_set.add((tr.tenant, ev.client_id))
    # deterministic job interleaving across tenants (not per-tenant
    # runs of N): round-robin by client index
    jobs.sort(key=lambda j: (j[1], j[0]))

    with IngestServer(
        store, {tok: t for t, tok in tokens.items()},
        queue_size=max(4 * workers, 64), batch_max=32,
        read_timeout=5.0,
    ) as srv:
        lats, injected, wall = run_uploads(
            srv.port, tokens, jobs, workers, disconnect_set, seed, p,
        )
        counts = {t: store.count(tenant=t) for t in tenants}
        # the torn connections' handler threads run concurrently with
        # the uploaders — give their disconnect accounting a moment to
        # settle before snapshotting
        deadline = time.perf_counter() + 10.0
        metrics = srv.metrics()
        while (metrics.get("disconnect", 0) < injected
               and time.perf_counter() < deadline):
            time.sleep(0.05)
            metrics = srv.metrics()

        with FairRoundScheduler(svc, max_running=2) as sched:
            results = sched.run_round(tenants, from_store=True,
                                      expected_clients=n)
        exact = {}
        for t in tenants:
            fused, rep = results[t]
            u, w = refs[t]
            ref = fedavg_formula(u, w)
            exact[t] = bool(
                rep.n_clients == n
                and np.allclose(np.asarray(fused), ref,
                                rtol=1e-5, atol=1e-5)
            )

    total = len(jobs)

    def pct(q):
        return float(lats[min(int(q * len(lats)), len(lats) - 1)])

    payload = {
        "bench": "ingest_service",
        "config": {
            "tenants": k, "clients_per_tenant": n, "dim": p,
            "workers": workers, "disconnects_per_tenant": disconnects,
            "seed": seed,
        },
        "uploads": {
            "total": total,
            "accepted": metrics.get("accepted", 0),
            "disconnects_injected": injected,
            "disconnects_seen": metrics.get("disconnect", 0),
            "wall_seconds": wall,
            "sustained_uploads_per_s": total / max(wall, 1e-9),
            "p50_latency_s": pct(0.50),
            "p99_latency_s": pct(0.99),
            "batches": metrics.get("batches", 0),
            "max_batch": metrics.get("max_batch", 0),
        },
        "store_counts": counts,
        "rounds_exact": exact,
        "trace_hash": trace.trace_hash(),
    }
    payload["acceptance"] = bool(
        all(c == n for c in counts.values())        # zero lost / dup
        and metrics.get("accepted", 0) == total     # every job landed
        and injected == k * disconnects             # faults were real
        and metrics.get("disconnect", 0) >= injected
        and all(exact.values())                     # fused == formula
    )
    return payload


def main():
    ap = argparse.ArgumentParser(
        description="HTTP ingest throughput/latency under K tenants x "
                    "N clients with mid-run disconnects."
    )
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--clients", type=int, default=256,
                    help="clients per tenant")
    ap.add_argument("--dim", type=int, default=4_000)
    ap.add_argument("--workers", type=int, default=16,
                    help="uploader pool size (keep-alive connections)")
    ap.add_argument("--disconnects", type=int, default=8,
                    help="clients per tenant that disconnect "
                         "mid-upload before retrying")
    ap.add_argument("--timeout", type=float, default=30.0,
                    help="round gate deadline")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="tier-1 smoke: 4 tenants x 64 clients, "
                         "P=2000")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default BENCH_ingest.json "
                         "next to this script's repo root)")
    args = ap.parse_args()
    if args.quick:
        args.clients = min(args.clients, 64)
        args.dim = min(args.dim, 2_000)
        args.workers = min(args.workers, 8)
        args.disconnects = min(args.disconnects, 4)

    payload = bench(args.tenants, args.clients, args.dim, args.workers,
                    args.disconnects, args.timeout, args.seed)
    payload["config"]["quick"] = bool(args.quick)
    up = payload["uploads"]
    print(f"[ingest] {payload['config']['tenants']}x"
          f"{payload['config']['clients_per_tenant']} uploads="
          f"{up['accepted']}/{up['total']} "
          f"sustained={up['sustained_uploads_per_s']:.0f}/s "
          f"p50={up['p50_latency_s'] * 1e3:.1f}ms "
          f"p99={up['p99_latency_s'] * 1e3:.1f}ms "
          f"disconnects={up['disconnects_injected']} "
          f"acceptance={payload['acceptance']}")
    out = args.out
    if out is None:
        import os
        out = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_ingest.json",
        )
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[ingest] wrote {out}")


if __name__ == "__main__":
    main()
