"""Fig. 3 — NumPy fusion ignores extra cores.

Paper: IBMFL FedAvg time is flat in core count because NumPy's reduction
is single-threaded. CPU analogue: single-threaded numpy loop (the IBMFL
implementation shape: per-client loop of scaled adds) vs the vectorized
XLA path — the gap is the headroom parallel execution leaves on the
table, which the Numba/Pallas path (fig5) then claims."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_updates, timeit
from repro.core import LocalEngine
from repro.core.fusion import FedAvg


def _ibmfl_style_numpy(u: np.ndarray, w: np.ndarray) -> np.ndarray:
    # IBMFL FusionHandler: python loop over parties, accumulate in numpy
    acc = np.zeros_like(u[0])
    for i in range(u.shape[0]):
        acc = acc + u[i] * w[i]
    return acc / (w.sum() + 1e-6)


def run():
    for n, p in ((64, 10_000), (256, 10_000), (64, 100_000)):
        u, w = make_updates(n, p)
        t_np = timeit(lambda: _ibmfl_style_numpy(u, w))
        eng = LocalEngine(strategy="jnp")
        t_jx = timeit(lambda: eng.fuse(FedAvg(), u, w))
        emit(f"fig3/numpy_loop_n{n}_p{p}", t_np * 1e6, "cores_used=1")
        emit(f"fig3/xla_fused_n{n}_p{p}", t_jx * 1e6,
             f"speedup={t_np / t_jx:.2f}x")
