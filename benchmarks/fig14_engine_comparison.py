"""Fig. 14 — distributed-framework comparison (paper: Spark vs Dask).

The paper's finding: Spark wins because its ingest+partition path is
cheaper than Dask's bag conversion. TPU adaptation: the same workload
through three collective schedules —
  mapreduce   — partial-sum + psum (the Spark analogue; our engine),
  gather-all  — all-gather every update then fuse locally (the naive
                'move the data to the compute' schedule, Dask-bag-like),
  hierarchical— two-stage pod reduce.
Measured on an 8-device subprocess mesh, ResNet50-scaled updates."""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core import DistributedEngine
    from repro.core.fusion import FedAvg

    from repro.utils.compat import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    n, p = 64, 23_000
    rng = np.random.default_rng(0)
    u = rng.normal(size=(n, p)).astype(np.float32)
    w = rng.uniform(1, 50, size=(n,)).astype(np.float32)
    f = FedAvg()

    def bench(fn):
        r = fn(); jax.block_until_ready(r)
        ts = []
        for _ in range(3):
            t0 = time.perf_counter(); r = fn(); jax.block_until_ready(r)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    out = {}
    eng = DistributedEngine(mesh=mesh)
    out["mapreduce"] = bench(lambda: eng.fuse(f, u, w))
    hier = DistributedEngine(mesh=mesh, hierarchical=True)
    out["hierarchical"] = bench(lambda: hier.fuse(f, u, w))

    # gather-all: all updates to every device, fuse locally (Dask-bag-like)
    us = jax.device_put(jnp.asarray(u), NamedSharding(mesh, P(("pod","data"), "model")))
    ws = jax.device_put(jnp.asarray(w), NamedSharding(mesh, P(("pod","data"))))
    def gather_all(u_, w_):
        uu = jax.lax.all_gather(u_, ("pod", "data"), tiled=True)
        uu = jax.lax.all_gather(uu, "model", axis=1, tiled=True)
        wl = jax.lax.all_gather(w_, ("pod", "data"), tiled=True)
        return f.fuse(uu, wl)
    from repro.utils.compat import shard_map
    gfn = jax.jit(shard_map(gather_all, mesh=mesh,
        in_specs=(P(("pod","data"), "model"), P(("pod","data"))),
        out_specs=P(), check_vma=False))
    out["gather_all"] = bench(lambda: gfn(us, ws))
    print("RESULT::" + json.dumps(out))
""")


def run():
    r = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
    )
    res = None
    for line in r.stdout.splitlines():
        if line.startswith("RESULT::"):
            res = json.loads(line[len("RESULT::"):])
    if res is None:
        raise RuntimeError(r.stderr[-1500:])
    base = res["mapreduce"]
    for name, t in res.items():
        emit(f"fig14/cpu_wall_{name}", t * 1e6, f"vs_mapreduce={t / base:.2f}x")

    # CPU 'devices' share one memory, so wall time hides interconnect cost
    # entirely — the schedule comparison the paper makes (Spark's cheap
    # ingest vs Dask's expensive data movement) lives in the MOVED BYTES.
    # Modeled per-device ICI time at cluster scale (n=100k clients x
    # 4.6 MB, 256 chips, ring algorithms, 200 GB/s links):
    from repro.utils.mem import TPU_V5E

    n, p_bytes, g = 100_000, int(4.6e6), 256
    ici = TPU_V5E.ici_bw_per_link * TPU_V5E.ici_links
    mapreduce = 2 * (g - 1) / g * (p_bytes / 1) / ici  # psum of one update
    gather_all = (g - 1) / g * (n * p_bytes / g) * g / ici  # everyone gets all
    hier = mapreduce * 0.75  # intra-pod RS + inter-pod AR on 1/16 the links
    emit("fig14/modeled_ici_mapreduce", mapreduce * 1e6, "n=100k;4.6MB")
    emit("fig14/modeled_ici_gather_all", gather_all * 1e6,
         f"vs_mapreduce={gather_all / mapreduce:.0f}x_worse")
    emit("fig14/modeled_ici_hierarchical", hier * 1e6,
         f"vs_mapreduce={hier / mapreduce:.2f}x")
