"""Aggregation ingest+fuse throughput: dense (seed behavior) vs the
zero-materialization streamed pipeline vs the Pallas path.

Measures one full aggregator round from a populated UpdateStore to the
fused (P,) vector, per path:

  dense_seed — the seed pipeline: ``read_stacked`` materializes the dense
               (n, P) matrix on the host, then an eager (unjitted,
               re-dispatched) fusion over the full matrix.
  dense      — ``read_stacked`` + the bucketed cached-executable engine.
  streamed   — ``UpdateStore.iter_chunks`` double-buffered blocks through
               ``LocalEngine.fuse_stream`` (peak host ingest O(chunk*P)).
  streamed_pallas — same pipeline with the fused Pallas kernel
               (interpret mode on CPU: illustrative, not performant).

Emits BENCH_aggregation.json with per-round seconds, rows/s and bytes/s.
Acceptance target: streamed >= 2x dense_seed rows/s at n=4096, P=1M.

Usage:
  python benchmarks/agg_throughput.py --quick           # CI smoke
  python benchmarks/agg_throughput.py --n 4096 --p 1000000
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import LocalEngine, UpdateStore, get_fusion


def populate(n: int, p: int, dtype: str, seed: int = 0) -> UpdateStore:
    store = UpdateStore()
    rng = np.random.default_rng(seed)
    block = 256
    for lo in range(0, n, block):
        rows = min(block, n - lo)
        u = rng.normal(size=(rows, p)).astype(dtype)
        for i in range(rows):
            store.write(f"c{lo + i:06d}", u[i], weight=1.0 + (lo + i) % 7)
    return store


def run_dense_seed(store: UpdateStore, fusion):
    stacked, w = store.read_stacked()
    u = jnp.asarray(stacked)
    out = fusion.fuse(u, jnp.asarray(w))   # eager: fresh dispatch per round
    return np.asarray(out)


def make_dense_cached(strategy: str):
    eng = LocalEngine(strategy=strategy)

    def run(store: UpdateStore, fusion):
        stacked, w = store.read_stacked()
        return np.asarray(eng.fuse(fusion, stacked, w))

    return run


def make_streamed(strategy: str, chunk_bytes: int):
    eng = LocalEngine(strategy=strategy)

    def run(store: UpdateStore, fusion):
        _, p, dtype = store.meta()
        chunk = max(1, chunk_bytes // (p * dtype.itemsize))
        fused, _ = eng.fuse_stream(fusion, store.iter_chunks(chunk))
        return np.asarray(fused)

    return run


def bench(name, fn, store, fusion, rounds):
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        out = fn(store, fusion)
        times.append(time.perf_counter() - t0)
    return times, out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--p", type=int, default=1_000_000)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--chunk-mb", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="small sizes + all paths (CI smoke)")
    ap.add_argument("--pallas", action="store_true",
                    help="include interpret-mode pallas at full scale")
    ap.add_argument("--out", default="BENCH_aggregation.json")
    args = ap.parse_args()
    if args.quick:
        args.n, args.p = 512, 20_000

    fusion = get_fusion("fedavg")
    row_bytes = args.p * np.dtype(args.dtype).itemsize
    print(f"populating store: n={args.n} P={args.p} "
          f"({args.n * row_bytes / 1e9:.2f} GB)")
    store = populate(args.n, args.p, args.dtype)

    chunk_bytes = args.chunk_mb << 20
    paths = {
        "dense_seed": run_dense_seed,
        "dense": make_dense_cached("jnp"),
        "streamed": make_streamed("jnp", chunk_bytes),
    }
    if args.quick or args.pallas:
        paths["streamed_pallas"] = make_streamed("pallas", chunk_bytes)

    results = {}
    ref = None
    for name, fn in paths.items():
        times, out = bench(name, fn, store, fusion, args.rounds)
        if ref is None:
            ref = out
        else:
            np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
        best = min(times)
        results[name] = {
            "seconds_per_round": [round(t, 4) for t in times],
            "best_seconds": round(best, 4),
            "rows_per_s": round(args.n / best, 1),
            "bytes_per_s": round(args.n * row_bytes / best, 0),
        }
        print(f"{name:16s} best={best:8.3f}s "
              f"rows/s={results[name]['rows_per_s']:>10} "
              f"(rounds: {[f'{t:.2f}' for t in times]})")

    speedup = (
        results["streamed"]["rows_per_s"]
        / results["dense_seed"]["rows_per_s"]
    )
    payload = {
        "config": {
            "n": args.n, "p": args.p, "dtype": args.dtype,
            "chunk_mb": args.chunk_mb, "rounds": args.rounds,
            "fusion": "fedavg", "host": "ci-cpu",
        },
        "results": results,
        "speedup_streamed_vs_dense_seed": round(speedup, 2),
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"streamed vs dense_seed: {speedup:.2f}x  -> {args.out}")


if __name__ == "__main__":
    main()
