"""§Roofline — the three-term roofline per (arch x shape x mesh), from the
dry-run artifacts in results/dryrun/.

  compute term    = EXEC_FLOPS / (chips x 197 TFLOP/s)   [analytic; XLA's
                    cost_analysis counts scan bodies once — reported too]
  memory term     = HBM bytes / (chips x 819 GB/s)       [analytic stream
                    model; measured 'bytes accessed' alongside]
  collective term = collective bytes / (chips x 4 links x 50 GB/s)
                    [measured: while-aware HLO parse, per-device bytes]

Per pair: dominant term, MODEL_FLOPS/EXEC_FLOPS useful-compute ratio, and
a one-line lever on the dominant term. Emits CSV + a markdown table at
results/roofline.md (EXPERIMENTS.md §Roofline embeds it)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import INPUT_SHAPES, get_config
from repro.utils.flops import flops_for
from repro.utils.mem import TPU_V5E

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "results", "roofline.md")


def _lever(dom: str, rec: dict, cfg) -> str:
    if dom == "collective":
        if cfg.moe is not None and rec["shape"] == "train_4k":
            return "shard_map all-to-all expert dispatch (drop scatter)"
        return "all-gather weights once per layer / reshard residual"
    if dom == "memory":
        if rec["kind"] == "decode":
            return "shrink/quantize KV cache (int8 KV, windowed layers)"
        return "recompute less (selective remat), bf16 moments"
    return "larger tiles / fewer remat passes (compute-bound is the goal)"


def analyze(rec: dict, hw=TPU_V5E) -> dict:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES.get(rec["shape"])
    chips = rec["n_chips"]
    fr = flops_for(cfg, shape, n_chips=chips) if shape else None

    coll_bytes = rec["collectives"]["total_bytes"]  # per device
    coll_t = coll_bytes / (hw.ici_bw_per_link * hw.ici_links)
    if fr is not None:
        comp_t = fr.exec_flops / (chips * hw.peak_flops_bf16)
        mem_t = fr.hbm_bytes_analytic / hw.hbm_bw
        useful = fr.useful_ratio
        model_fl = fr.model_flops
        exec_fl = fr.exec_flops
    else:  # aggregate step
        comp_t = (rec["per_device"]["flops"] or 0.0) / hw.peak_flops_bf16
        mem_t = (rec["per_device"]["bytes_accessed"] or 0.0) / hw.hbm_bw
        useful = 1.0
        model_fl = exec_fl = (rec["per_device"]["flops"] or 0.0) * chips
    terms = {"compute": comp_t, "memory": mem_t, "collective": coll_t}
    dom = max(terms, key=terms.get)
    total = sum(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "ok": rec.get("ok", False), "fits_hbm": rec.get("fits_hbm"),
        "compute_s": comp_t, "memory_s": mem_t, "collective_s": coll_t,
        "dominant": dom,
        "roofline_fraction": (max(terms.values()) / total) if total else 0.0,
        "model_flops": model_fl, "exec_flops": exec_fl,
        "useful_ratio": useful,
        "hlo_flops_per_dev": rec["per_device"].get("flops"),
        "peak_gib": (rec["per_device"].get("peak_bytes_est") or 0) / 2**30,
        "lever": _lever(dom, rec, cfg),
    }


def run(mesh_filter: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            emit(f"roofline/{rec['arch']}_{rec['shape']}_{rec['mesh']}",
                 0.0, "DRYRUN_FAILED")
            continue
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        r = analyze(rec)
        rows.append(r)
        emit(
            f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dom={r['dominant']};comp={r['compute_s']:.3e}s;"
            f"mem={r['memory_s']:.3e}s;coll={r['collective_s']:.3e}s;"
            f"useful={r['useful_ratio']:.2f};fits={r['fits_hbm']}",
        )
    _write_md(rows)
    return rows


def _write_md(rows):
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("| arch | shape | mesh | compute s | memory s | collective s"
                " | dominant | MODEL/EXEC | peak GiB | fits | lever |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|---|\n")
        for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
            f.write(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
                f"| {r['collective_s']:.3e} | **{r['dominant']}** "
                f"| {r['useful_ratio']:.2f} | {r['peak_gib']:.2f} "
                f"| {'yes' if r['fits_hbm'] else 'NO'} | {r['lever']} |\n"
            )
