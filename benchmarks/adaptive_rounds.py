"""Adaptive (learned-gate) rounds vs the static threshold/timeout gate.

Three arrival scenarios — expressed as ``repro.workload`` arrival
processes and compiled to a trace, so both gates replay IDENTICAL
arrival schedules (async/overlapped rounds throughout):

  uniform    — every client arrives, spread evenly over the straggler
               window (``UniformArrivals``): the learned gate must
               MATCH the static gate (both close on the last arrival;
               there is nothing to save).
  bursty     — 90% of the fleet lands in an early burst, the rest DROP
               (``BurstyArrivals``): the static full-threshold gate
               burns its whole timeout every round; the learned gate
               thresholds at the attainable fraction and closes on the
               burst.
  heavy_tail — lognormal arrival offsets with the extreme tail past
               the timeout (``LognormalArrivals``, effectively
               dropped): the static gate times out; the learned gate
               closes just past the attainable tail.

Per mode we report mean round wall-clock and mean inclusion (clients
folded / clients expected). The acceptance bar (ISSUE 3): adaptive
matches-or-beats static wall-clock at equal-or-better inclusion in
>= 2 of 3 scenarios. Learning rounds (the static-gated warmup the
controller observes) are excluded from the measured means and reported
separately.

Emits BENCH_adaptive.json.

Usage:
  python benchmarks/adaptive_rounds.py --quick     # CI smoke (~30 s)
  python benchmarks/adaptive_rounds.py             # full  (~2 min)
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import AggregationService, UpdateStore
from repro.workload import (
    BurstyArrivals,
    FixedSize,
    LognormalArrivals,
    RegimeSchedule,
    UniformArrivals,
    WorkloadSpec,
    start_writer,
)


def scenario_process(name: str, spread: float):
    """The scenario's arrival process (drop-out is the process's
    business: clients it never emits simply don't arrive)."""
    if name == "uniform":
        return UniformArrivals(spread=spread)
    if name == "bursty":
        return BurstyArrivals(spread=spread, arrive_frac=0.9,
                              window=(0.05, 0.15))
    if name == "heavy_tail":
        return LognormalArrivals(spread=spread, sigma=0.6,
                                 median_frac=0.2, drop_clients=2)
    raise ValueError(name)


def scenario_round(name: str, n: int, p: int, spread: float,
                   seed: int = 0):
    """One traced tenant-round for the scenario — replayed identically
    by every gate and every measured round."""
    spec = WorkloadSpec(
        tenants=("default",), n_clients=n, rounds=1,
        regimes=RegimeSchedule.single(scenario_process(name, spread),
                                      name=name),
        sizes=FixedSize(p),
    )
    return spec.build(seed).rounds[0].tenant("default")


def run_rounds(adaptive, tenant_round, seed, expected, p, timeout,
               rounds, warmup, cost_bias):
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=timeout,
        adaptive=adaptive, cost_bias=cost_bias,
        stream_chunk_bytes=max(p * 4 * max(expected // 4, 1), 1 << 20),
    )
    walls, inclusions, learn_walls = [], [], []
    for r in range(warmup + rounds):
        writer = start_writer(store, tenant_round, seed)
        t0 = time.perf_counter()
        fused, rep = svc.aggregate(
            from_store=True, expected_clients=expected, async_round=True,
        )
        wall = time.perf_counter() - t0
        writer.join()
        store.clear()   # drop anything that raced past the close
        if r < warmup:
            learn_walls.append(wall)
            continue
        walls.append(wall)
        inclusions.append(rep.n_clients / expected)
    pol = rep.close_policy
    return {
        "mean_wall_seconds": float(np.mean(walls)),
        "wall_seconds": walls,
        "mean_inclusion": float(np.mean(inclusions)),
        "learning_wall_seconds": learn_walls,
        "final_gate": {
            "source": pol.source if pol else "static",
            "threshold_frac": pol.threshold_frac if pol else 1.0,
            "deadline": pol.deadline if pol else timeout,
        },
    }


def bench(n, p, spread, timeout, rounds, warmup, cost_bias, seed):
    results, wins = {}, 0
    for name in ("uniform", "bursty", "heavy_tail"):
        tenant_round = scenario_round(name, n, p, spread, seed)
        expected = tenant_round.expected
        per = {}
        for mode, adaptive in (("static", False), ("adaptive", True)):
            per[mode] = run_rounds(
                adaptive, tenant_round, seed, expected, p, timeout,
                rounds, warmup, cost_bias,
            )
            print(f"{name:>10} {mode:>8}: wall "
                  f"{per[mode]['mean_wall_seconds']:.3f}s inclusion "
                  f"{per[mode]['mean_inclusion']:.3f} gate "
                  f"{per[mode]['final_gate']}")
        # match-or-beat: wall within 10% (or faster), inclusion within
        # one client (or better)
        win = (
            per["adaptive"]["mean_wall_seconds"]
            <= per["static"]["mean_wall_seconds"] * 1.10
            and per["adaptive"]["mean_inclusion"]
            >= per["static"]["mean_inclusion"] - 1.0 / expected - 1e-9
        )
        wins += win
        speedup = (per["static"]["mean_wall_seconds"]
                   / per["adaptive"]["mean_wall_seconds"])
        per["speedup"] = speedup
        per["adaptive_matches_or_beats"] = bool(win)
        print(f"{name:>10}  -> speedup {speedup:.2f}x "
              f"{'WIN' if win else 'no win'}")
        results[name] = per
    return results, wins


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--p", type=int, default=100_000)
    ap.add_argument("--spread", type=float, default=1.0)
    ap.add_argument("--timeout", type=float, default=4.0)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--cost-bias", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed (arrival offsets, weights, payloads)")
    ap.add_argument("--out", default="BENCH_adaptive.json")
    args = ap.parse_args()
    if args.quick:
        args.n, args.p = 16, 20_000
        args.spread, args.timeout = 0.5, 1.5
        args.rounds, args.warmup = 2, 2
    results, wins = bench(
        args.n, args.p, args.spread, args.timeout, args.rounds,
        args.warmup, args.cost_bias, args.seed,
    )
    print(f"adaptive matches-or-beats static in {wins}/3 scenarios")
    payload = {
        "benchmark": "adaptive_rounds",
        "config": {
            "n_clients": args.n, "p": args.p,
            "spread_seconds": args.spread,
            "timeout_seconds": args.timeout, "rounds": args.rounds,
            "warmup_rounds": args.warmup, "cost_bias": args.cost_bias,
            "seed": args.seed, "quick": args.quick,
        },
        "results": results,
        "wins": wins,
        "acceptance": wins >= 2,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
