"""Fig. 11 — ResNet50/VGG16 (+ the 10 assigned architectures) at 3x the
single-node client count.

Paper: distributed aggregation supports 3x the clients of a single node
for ResNet50/VGG16. Here: for every workload, the single-chip max client
count vs the 256-chip mesh capacity (memory model), and a measured fuse
of 3x-the-cap clients through the streaming engine at CPU scale."""
from __future__ import annotations

from benchmarks.common import emit, make_updates, timeit
from repro.configs import ARCHITECTURES, CNN_SUITE
from repro.core import LocalEngine, max_clients_single_node
from repro.core.fusion import FedAvg


def run():
    eng = LocalEngine(strategy="jnp")
    for name in ("Resnet50", "VGG16"):
        spec = CNN_SUITE[name]
        single = max_clients_single_node(spec.bytes_fp32)
        # measured: 3x the scaled capacity streams through the cap
        p = spec.num_params // 1000
        cap = 3 * p * 4  # cap that fits ~3 scaled clients
        capped = LocalEngine(strategy="jnp", memory_cap_bytes=cap * 3)
        u, w = make_updates(9 * 3, p)
        t = timeit(lambda: capped.fuse(FedAvg(), u, w))
        emit(f"fig11/{name}_3x_streamed", t * 1e6,
             f"single_chip_max={single};mesh256_max={single * 256}")
    for arch, cfg in ARCHITECTURES.items():
        single = max_clients_single_node(cfg.update_bytes())
        emit(
            f"fig11/{arch}", 0.0,
            f"w_s_GiB={cfg.update_bytes() / 2**30:.2f};"
            f"single_chip_max={single};mesh256_max={single * 256}",
        )
