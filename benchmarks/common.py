"""Shared benchmark machinery.

CPU-scale note: the paper's experiments span GB-sized updates and 10^5
clients on a 4-node cluster; this container is one CPU core. Every figure
keeps the paper's comparative STRUCTURE (same axes, same contenders) at
MB scale, and derives cluster-scale numbers from the calibrated models
(store bandwidth, memory caps) — the same methodology the paper itself
uses for its write-latency accounting.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (seconds) with jax sync."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if r is not None else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        if r is not None:
            jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def make_updates(n: int, p: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(n, p)).astype(np.float32)
    w = rng.uniform(1, 100, size=(n,)).astype(np.float32)
    return u, w


# Scaled-down stand-ins for the paper's Table-I workloads (1/1000 of the
# parameter count -> same comparative trends at CPU-tractable sizes).
SCALED_SUITE = {
    "CNN4.6": 4_600_000 // 4 // 1000,
    "CNN73": 73_000_000 // 4 // 1000,
    "CNN179": 179_000_000 // 4 // 1000,
    "CNN478": 478_000_000 // 4 // 1000,
    "CNN956": 956_000_000 // 4 // 1000,
    "Resnet50": 91_000_000 // 4 // 1000,
    "VGG16": 528_000_000 // 4 // 1000,
}
