"""Monitor-overlapped async rounds vs the serialized PR-1 pipeline.

One aggregator round where client arrivals are SPREAD over a straggler
window (a ``repro.workload`` trace of ``UniformArrivals``, replayed by
a writer thread), measured two ways:

  serialized — ``Monitor.wait()`` idles for the whole window, THEN the
               streamed pipeline ingests and fuses (the PR-1 round loop):
               wall ≈ spread + fuse.
  overlapped — ``aggregate(async_round=True)``: partial sums fold off the
               arrival stream while stragglers are still writing; the
               threshold/timeout gate closes the stream:
               wall ≈ max(spread, fuse) + drain.

Both paths see identical updates (same seed), and the benchmark asserts
the fused vectors are allclose — the §IV-C invariant — before reporting
wall clocks. Rounds are measured WARM (one throwaway round per path
compiles the step executables) so the numbers isolate the overlap, not
compile time.

Emits BENCH_async.json. Acceptance: overlapped end-to-end round
wall-clock (monitor wait + fuse) beats serialized when arrivals are
spread over the wait window.

Usage:
  python benchmarks/async_rounds.py --quick     # CI smoke (~15 s)
  python benchmarks/async_rounds.py             # full   (~1 min)
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import AggregationService, UpdateStore
from repro.workload import (
    FixedSize,
    RegimeSchedule,
    UniformArrivals,
    WorkloadSpec,
    start_writer,
    trace_payload,
)


def make_round(n: int, p: int, spread: float, seed: int = 0):
    """One traced tenant-round: client i arrives at ~i/n of the
    straggler window (paper Fig. 12's staggered client arrivals)."""
    spec = WorkloadSpec(
        tenants=("default",), n_clients=n, rounds=1,
        regimes=RegimeSchedule.single(UniformArrivals(spread=spread)),
        sizes=FixedSize(p),
    )
    return spec.build(seed).rounds[0].tenant("default")


def dense_ref(tenant_round, seed):
    """The trace's deterministic payloads as the dense FedAvg formula
    reference."""
    u = np.stack([
        trace_payload(seed, tenant_round.tenant, ev.client_id,
                      tenant_round.dim)
        for ev in tenant_round.events
    ])
    w = np.asarray([ev.weight for ev in tenant_round.events], np.float32)
    return np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)


def run_round(svc: AggregationService, store, tenant_round, seed,
              async_round):
    writer = start_writer(store, tenant_round, seed)
    t0 = time.perf_counter()
    fused, rep = svc.aggregate(
        from_store=True, expected_clients=tenant_round.expected,
        async_round=async_round,
    )
    wall = time.perf_counter() - t0
    writer.join()
    if not async_round:
        store.clear()   # async rounds consume; serialized rounds must too
    return np.asarray(fused), rep, wall


def bench(n, p, spread, rounds, timeout, seed):
    spread_round = make_round(n, p, spread, seed)
    warm_round = make_round(n, p, 0.0, seed)   # all arrivals at once
    ref = dense_ref(spread_round, seed)
    results = {}
    for mode, async_round in (("serialized", False), ("overlapped", True)):
        store = UpdateStore()
        svc = AggregationService(
            fusion="fedavg", local_strategy="jnp", store=store,
            threshold_frac=1.0, monitor_timeout=timeout,
            stream_chunk_bytes=max(p * 4 * max(n // 8, 1), 1 << 20),
        )
        # warm round: compile the step executable outside the measurement
        run_round(svc, store, warm_round, seed, async_round=async_round)
        walls, overlaps = [], []
        for _ in range(rounds):
            fused, rep, wall = run_round(
                svc, store, spread_round, seed, async_round
            )
            np.testing.assert_allclose(fused, ref, rtol=1e-4, atol=1e-4)
            assert rep.monitor is not None and rep.monitor.ready, (
                "round timed out before the full client set arrived — "
                "raise --timeout"
            )
            walls.append(wall)
            overlaps.append(rep.overlap_seconds)
        results[mode] = {
            "wall_seconds": walls,
            "mean_wall_seconds": float(np.mean(walls)),
            "mean_overlap_seconds": float(np.mean(overlaps)),
            "fuse_seconds": rep.fuse_seconds,
            "phase_seconds": rep.phase_seconds,
        }
        print(f"{mode:>10}: mean wall {np.mean(walls):.3f}s "
              f"(overlap {np.mean(overlaps):.3f}s)")
    speedup = (results["serialized"]["mean_wall_seconds"]
               / results["overlapped"]["mean_wall_seconds"])
    print(f"overlap speedup: {speedup:.2f}x "
          f"(arrivals spread over {spread:.1f}s)")
    return results, speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--p", type=int, default=200_000)
    ap.add_argument("--spread", type=float, default=1.2)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--timeout", type=float, default=30.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed (arrival offsets, weights, payloads)")
    ap.add_argument("--out", default="BENCH_async.json")
    args = ap.parse_args()
    if args.quick:
        args.n, args.p = 24, 20_000
        args.spread, args.rounds = 0.6, 2
    results, speedup = bench(
        args.n, args.p, args.spread, args.rounds, args.timeout, args.seed
    )
    payload = {
        "benchmark": "async_rounds",
        "config": {
            "n_clients": args.n, "p": args.p, "spread_seconds": args.spread,
            "rounds": args.rounds, "seed": args.seed, "quick": args.quick,
        },
        "results": results,
        "speedup": speedup,
        "equivalent": True,   # asserted allclose against the dense formula
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
