"""Multi-tenant rounds: K tenants on ONE shared store vs K isolated
stores.

The tenant-partitioned UpdateStore's claim: K applications can
interleave open rounds on one shared store — every round gates on,
folds, and consumes only its own tenant's partition — and lose NOTHING
against the static per-app deployment (one store + one service per
tenant), while gaining what the static deployment cannot have: every
tenant after the first folds through the SAME engine's warm compile
cache instead of paying its own cold trace+compile.

Per round-cycle, every tenant's writer thread spreads its arrivals over
the straggler window CONCURRENTLY — tenant k's updates land while
tenant j's round is open, which is exactly the interleaving a shared
spool must survive. Rounds are async (monitor-overlapped) with a full
inclusion threshold, so any cross-tenant steal would surface as a wrong
fused vector or missing inclusion.

Reported per mode:
  * mean_inclusion      — clients folded / clients expected (must match
                          the isolated deployment),
  * total_compile_seconds / cold_compiles — the cross-tenant warm-cache
                          win (shared pays ~1 cold compile, isolated
                          pays ~K),
  * equivalent          — every tenant's fused vector matches the dense
                          FedAvg formula on that tenant's updates alone.

Acceptance (ISSUE 4): shared-store inclusion >= isolated-store
inclusion AND shared cold compiles < isolated cold compiles AND both
modes equivalent to the formula.

Emits BENCH_multitenant.json.

Usage:
  python benchmarks/multitenant_rounds.py --quick     # CI smoke (~30 s)
  python benchmarks/multitenant_rounds.py             # full  (~2 min)
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core import AggregationService, UpdateStore


def make_tenant_clients(k: int, n: int, p: int, seed: int = 1):
    """Per-tenant client updates/weights (distinct per tenant, so a
    cross-tenant steal cannot cancel out numerically)."""
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(k, n, p)).astype(np.float32)
    w = rng.uniform(1, 7, size=(k, n)).astype(np.float32)
    return u, w


def fedavg_formula(u, w):
    return np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)


def spread_writer(store, tenant, u, w, spread):
    """Write tenant's n clients spread evenly over ``spread`` seconds,
    tagged with the tenant (one thread per tenant; all tenants' writers
    run concurrently)."""
    n = u.shape[0]

    def run():
        t0 = time.perf_counter()
        for i in range(n):
            lag = (i + 1) * spread / n - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            store.write(f"c{i:04d}", u[i], weight=float(w[i]),
                        tenant=tenant)

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return t


def _mk_service(store, n, p, timeout):
    return AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=timeout,
        stream_chunk_bytes=max(p * 4 * max(n // 4, 1), 1 << 20),
    )


def run_mode(shared: bool, tenants, u, w, p, spread, timeout, rounds):
    """One deployment mode: ``shared`` = one store + one service for all
    tenants; else one isolated store + service per tenant."""
    n = u.shape[1]
    if shared:
        store = UpdateStore()
        svc = _mk_service(store, n, p, timeout)
        stores = {t: store for t in tenants}
        services = {t: svc for t in tenants}
    else:
        stores = {t: UpdateStore() for t in tenants}
        services = {
            t: _mk_service(stores[t], n, p, timeout) for t in tenants
        }
    inclusions, compiles, walls = [], [], []
    cold = 0
    equivalent = True
    for _ in range(rounds):
        writers = [
            spread_writer(stores[t], t, u[k], w[k], spread)
            for k, t in enumerate(tenants)
        ]
        for k, t in enumerate(tenants):
            t0 = time.perf_counter()
            fused, rep = services[t].aggregate(
                from_store=True, expected_clients=n, async_round=True,
                tenant=t,
            )
            walls.append(time.perf_counter() - t0)
            inclusions.append(rep.n_clients / n)
            compile_s = rep.phase_seconds.get("compile", 0.0)
            compiles.append(compile_s)
            cold += compile_s > 0.0
            if rep.n_clients > n or (rep.n_clients == n and not
                np.allclose(
                    np.asarray(fused), fedavg_formula(u[k], w[k]),
                    rtol=1e-4, atol=1e-5,
                )
            ):
                equivalent = False   # a steal or a lost update
        for wt in writers:
            wt.join()
        for t in tenants:   # drop close-race stragglers between cycles
            stores[t].clear(tenant=t)
    return {
        "mean_inclusion": float(np.mean(inclusions)),
        "inclusions": inclusions,
        "mean_wall_seconds": float(np.mean(walls)),
        "total_compile_seconds": float(np.sum(compiles)),
        "cold_compiles": int(cold),
        "equivalent": bool(equivalent),
    }


def bench(k, n, p, spread, timeout, rounds, seed):
    tenants = [f"app{i}" for i in range(k)]
    u, w = make_tenant_clients(k, n, p, seed)
    results = {}
    for mode, shared in (("isolated", False), ("shared", True)):
        results[mode] = run_mode(
            shared, tenants, u, w, p, spread, timeout, rounds
        )
        r = results[mode]
        print(f"{mode:>9}: inclusion {r['mean_inclusion']:.3f} "
              f"wall {r['mean_wall_seconds']:.3f}s "
              f"compile {r['total_compile_seconds']:.3f}s "
              f"({r['cold_compiles']} cold) "
              f"equivalent={r['equivalent']}")
    sh, iso = results["shared"], results["isolated"]
    acceptance = (
        sh["mean_inclusion"] >= iso["mean_inclusion"] - 1.0 / n - 1e-9
        and sh["cold_compiles"] < iso["cold_compiles"]
        and sh["equivalent"] and iso["equivalent"]
    )
    compile_saved = iso["total_compile_seconds"] - sh["total_compile_seconds"]
    print(f"shared store saves {compile_saved:.3f}s of compile over "
          f"{k} tenants ({iso['cold_compiles']} -> {sh['cold_compiles']} "
          f"cold compiles); acceptance={acceptance}")
    return results, acceptance, compile_saved


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--p", type=int, default=100_000)
    ap.add_argument("--spread", type=float, default=1.0)
    ap.add_argument("--timeout", type=float, default=8.0)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default="BENCH_multitenant.json")
    args = ap.parse_args()
    if args.quick:
        args.tenants, args.n, args.p = 3, 16, 20_000
        args.spread, args.timeout = 0.4, 4.0
        args.rounds = 2
    results, acceptance, compile_saved = bench(
        args.tenants, args.n, args.p, args.spread, args.timeout,
        args.rounds, args.seed,
    )
    payload = {
        "benchmark": "multitenant_rounds",
        "config": {
            "tenants": args.tenants, "n_clients_per_tenant": args.n,
            "p": args.p, "spread_seconds": args.spread,
            "timeout_seconds": args.timeout, "rounds": args.rounds,
            "quick": args.quick,
        },
        "results": results,
        "compile_seconds_saved": compile_saved,
        "acceptance": bool(acceptance),
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
