"""Figs. 12/13 — end-to-end distributed aggregation with simulated
clients: store ingest (modeled write latency), monitor wait, partition,
and fuse, per workload size.

Paper: 6..1272 simulated parties write to HDFS over 1 GbE; avg write time
+ read/partition + reduce per model size. The UpdateStore reproduces the
bandwidth model (replication x bytes / aggregate datanode bw); fuse times
are measured."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import AggregationService, UpdateStore


# (paper model, parties) pairs from Fig. 12, params scaled 1/1000
CASES = [
    ("CNN956", 956_000 // 4, 6),
    ("CNN478", 478_000 // 4, 12),
    ("Resnet50", 91_000 // 4, 60),
    ("CNN73", 73_000 // 4, 84),
    ("CNN4.6", 4_600 // 4, 256),   # scaled warm-up point
    ("CNN4.6", 4_600 // 4, 1272),  # the paper's Fig. 13 party count
]


def run():
    rng = np.random.default_rng(0)
    for name, p, parties in CASES:
        store = UpdateStore(n_datanodes=3, replication=2)
        svc = AggregationService(
            fusion="fedavg", store=store, local_strategy="jnp",
            threshold_frac=0.8, monitor_timeout=5.0,
        )
        for i in range(parties):
            u = rng.normal(size=(p,)).astype(np.float32)
            store.write(f"c{i:05d}", u, weight=float(rng.integers(1, 50)))
        avg_write = store.stats.sim_write_seconds / store.stats.writes
        fused, rep = svc.aggregate(from_store=True,
                                   expected_clients=parties)
        emit(
            f"fig12/{name}_n{parties}", rep.fuse_seconds * 1e6,
            f"avg_write_ms={avg_write * 1e3:.2f};"
            f"monitor_wait={rep.monitor.waited * 1e3:.1f}ms;"
            f"engine={rep.plan.engine}",
        )
