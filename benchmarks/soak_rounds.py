"""Long-horizon soak: hundreds of multi-tenant rounds on ONE service.

The trace-driven counterpart of the single-scenario benches: a
``repro.workload`` spec compiles (seeded, hash-stable) to a full
horizon of per-tenant arrival schedules with REGIME SHIFTS mid-run
(uniform -> bursty-dropout -> heavy-tail) and a cold-start tenant
joining mid-soak, then the SAME trace is replayed through both gates
on one ``RoundScheduler`` service:

  static   — threshold_frac=1.0 / timeout every round, the whole run.
  adaptive — the learned controller; mid-soak the service is KILLED
             (scheduler shutdown, service dropped) and a fresh one
             resumes from ``save_controller``/``load_controller`` —
             post-resume rounds must close on the learned gate, not
             re-warm from static.

Measured over the whole horizon, per round and per regime segment:
wall-clock (the cost trajectory), inclusion, gate source, drift /
rewarm behavior at the regime boundaries, and the cold-start tenant's
first gate (cross-tenant prior borrowing). Acceptance: post-resume
continuity (source != static/cold), the churn tenant's first gate is
the prior, and the adaptive gate's cumulative cost beats static at
equal-or-better inclusion under the shifted schedule.

Emits BENCH_soak.json (+ the replayable trace via --trace-out).

Usage:
  python benchmarks/soak_rounds.py --quick     # CI smoke (~30 s)
  python benchmarks/soak_rounds.py             # full, 200 rounds (~4 min)
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import AggregationService, RoundScheduler, UpdateStore
from repro.workload import (
    BurstyArrivals,
    FixedSize,
    LognormalArrivals,
    Regime,
    RegimeSchedule,
    TenantChurn,
    UniformArrivals,
    WorkloadSpec,
    start_writer,
)


def build_spec(args) -> WorkloadSpec:
    """The soak's regime-shifted, churning workload. Boundaries at
    1/3 and 2/3 of the horizon; the cold-start tenant joins between
    the first shift and the restart."""
    third = args.rounds // 3
    return WorkloadSpec(
        tenants=tuple(f"app{i}" for i in range(args.tenants)),
        n_clients=args.n,
        rounds=args.rounds,
        regimes=RegimeSchedule([
            Regime("uniform",
                   UniformArrivals(spread=args.spread), 0),
            Regime("bursty_dropout",
                   BurstyArrivals(spread=args.spread, arrive_frac=0.75,
                                  window=(0.05, 0.3)), third),
            Regime("heavy_tail",
                   LognormalArrivals(spread=2 * args.spread, sigma=0.6,
                                     median_frac=0.2, drop_clients=2),
                   2 * third),
        ]),
        sizes=FixedSize(args.p),
        churn=TenantChurn(scheduled_joins=((args.churn_round, None),)),
    )


def _mk_service(store, args, adaptive):
    return AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=args.timeout,
        adaptive=adaptive, cost_bias=args.cost_bias,
        stream_chunk_bytes=max(args.p * 4 * max(args.n // 4, 1), 1 << 20),
    )


def run_soak(trace, args, adaptive: bool, ckpt_path: str):
    """Replay the whole trace through one gate. Returns per-round
    trajectory rows plus the restart-continuity record."""
    store = UpdateStore()
    svc = _mk_service(store, args, adaptive)
    sched = RoundScheduler(svc)
    rows = []
    restart = {"round": args.restart_round, "post_resume_sources": {}}
    seed = trace.seed
    t_start = time.perf_counter()
    try:
        for rt in trace.rounds:
            if rt.index == args.restart_round:
                # the mid-soak kill: drop the scheduler AND the
                # service; a fresh service resumes the learned gates
                # from the controller checkpoint (static mode restarts
                # too, so the two cost trajectories stay comparable)
                sched.shutdown()
                if adaptive:
                    svc.save_controller(ckpt_path)
                svc = _mk_service(store, args, adaptive)
                if adaptive:
                    svc.load_controller(ckpt_path)
                sched = RoundScheduler(svc)
            active = [tr.tenant for tr in rt.tenants]
            writers = [start_writer(store, tr, seed) for tr in rt.tenants]
            t0 = time.perf_counter()
            results = sched.run_round(
                active, from_store=True, expected_clients=args.n,
                async_round=True,
            )
            wall = time.perf_counter() - t0
            for w in writers:
                w.join()
            for tr in rt.tenants:
                fused, rep = results[tr.tenant]
                pol = rep.close_policy
                source = pol.source if pol else "static"
                snap = (svc.controller.snapshot(tr.tenant)
                        if svc.controller is not None else {})
                rows.append({
                    "round": rt.index,
                    "tenant": tr.tenant,
                    "regime": tr.regime,
                    "wall_seconds": wall,
                    "inclusion": rep.n_clients / tr.expected,
                    "source": source,
                    "drift": snap.get("drift"),
                    "rewarmed": source == "rewarm",
                })
                if rt.index == args.restart_round and adaptive:
                    restart["post_resume_sources"][tr.tenant] = source
                # stragglers that raced past the close age out here so
                # every round's inclusion is measured against ITS trace
                store.clear(tenant=tr.tenant)
    finally:
        sched.shutdown()
    restart["continuity"] = bool(
        restart["post_resume_sources"]
        and all(s not in ("static", "cold")
                for s in restart["post_resume_sources"].values())
    ) if adaptive else None
    return {
        "rows": rows,
        "restart": restart,
        "total_wall_seconds": time.perf_counter() - t_start,
    }


def summarize(run, trace, args):
    """Cost/inclusion trajectory -> per-regime and whole-horizon
    aggregates. Round walls count ONCE per round (K tenants run
    concurrently; the wall is the round's, not the tenant's)."""
    rows = run["rows"]
    round_walls = {}
    for row in rows:
        round_walls[row["round"]] = row["wall_seconds"]
    segments = {}
    for row in rows:
        seg = segments.setdefault(row["regime"], {
            "inclusions": [], "rounds": set(), "rewarm_rounds": 0,
        })
        seg["inclusions"].append(row["inclusion"])
        seg["rounds"].add(row["round"])
        seg["rewarm_rounds"] += int(row["rewarmed"])
    out = {}
    for name, seg in segments.items():
        out[name] = {
            "rounds": len(seg["rounds"]),
            "cum_wall_seconds": float(sum(
                round_walls[r] for r in seg["rounds"])),
            "mean_inclusion": float(np.mean(seg["inclusions"])),
            "rewarm_rounds": seg["rewarm_rounds"],
        }
    return {
        "cum_wall_seconds": float(sum(round_walls.values())),
        "mean_inclusion": float(np.mean(
            [row["inclusion"] for row in rows])),
        "rewarm_rounds": int(sum(row["rewarmed"] for row in rows)),
        "segments": out,
    }


def prior_borrowing(run, args):
    """The cold-start tenant's FIRST gate: with other tenants' curves
    pooled, it should borrow the cross-tenant prior, not re-pay the
    static warmup."""
    first = next((row for row in run["rows"]
                  if row["tenant"].startswith("churn")), None)
    if first is None:
        return {"borrowed": False, "reason": "no churn tenant in trace"}
    return {
        "tenant": first["tenant"],
        "join_round": first["round"],
        "first_source": first["source"],
        "borrowed": first["source"] == "prior",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--p", type=int, default=20_000)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--spread", type=float, default=0.15)
    ap.add_argument("--timeout", type=float, default=0.8)
    ap.add_argument("--cost-bias", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--restart-round", type=int, default=None,
                    help="kill/resume the service before this round "
                         "(default: mid-horizon)")
    ap.add_argument("--churn-round", type=int, default=None,
                    help="cold-start tenant join round (default: "
                         "~40%% of the horizon)")
    ap.add_argument("--trace-out", default=None,
                    help="also write the replayable trace JSON here")
    ap.add_argument("--out", default="BENCH_soak.json")
    args = ap.parse_args()
    if args.quick:
        args.tenants, args.n, args.p = 2, 6, 4_000
        args.rounds, args.spread, args.timeout = 24, 0.12, 0.6
    if args.restart_round is None:
        args.restart_round = args.rounds // 2
    if args.churn_round is None:
        args.churn_round = max(int(args.rounds * 0.4), 1)

    spec = build_spec(args)
    trace = spec.build(args.seed)
    print(f"[soak] trace: {trace.n_rounds} rounds x "
          f"{args.tenants}(+churn) tenants, n={args.n} p={args.p} "
          f"hash={trace.trace_hash()[:16]}")
    if args.trace_out:
        trace.to_json(args.trace_out)
        print(f"[soak] wrote trace {args.trace_out}")

    with tempfile.TemporaryDirectory() as td:
        ckpt = os.path.join(td, "soak_ckpt")
        runs, summaries = {}, {}
        for mode, adaptive in (("static", False), ("adaptive", True)):
            run = run_soak(trace, args, adaptive, ckpt)
            runs[mode] = run
            summaries[mode] = summarize(run, trace, args)
            s = summaries[mode]
            print(f"[soak] {mode:>8}: cum wall {s['cum_wall_seconds']:.2f}s "
                  f"mean inclusion {s['mean_inclusion']:.3f} "
                  f"rewarm rounds {s['rewarm_rounds']}")
            for name, seg in s["segments"].items():
                print(f"[soak]   {name:>15}: {seg['rounds']} rounds, "
                      f"wall {seg['cum_wall_seconds']:.2f}s, inclusion "
                      f"{seg['mean_inclusion']:.3f}, rewarms "
                      f"{seg['rewarm_rounds']}")

    restart = runs["adaptive"]["restart"]
    borrow = prior_borrowing(runs["adaptive"], args)
    adaptive_wins = (
        summaries["adaptive"]["cum_wall_seconds"]
        < summaries["static"]["cum_wall_seconds"]
        and summaries["adaptive"]["mean_inclusion"]
        >= summaries["static"]["mean_inclusion"] - 1.0 / args.n - 1e-9
    )
    acceptance = bool(
        restart["continuity"] and borrow.get("borrowed") and adaptive_wins
    )
    print(f"[soak] restart@{restart['round']}: post-resume sources "
          f"{restart['post_resume_sources']} "
          f"continuity={restart['continuity']}")
    print(f"[soak] prior borrowing: {borrow}")
    print(f"[soak] adaptive beats static at equal-or-better inclusion: "
          f"{adaptive_wins}; acceptance={acceptance}")

    payload = {
        "benchmark": "soak_rounds",
        "config": {
            "tenants": args.tenants, "n_clients": args.n, "p": args.p,
            "rounds": args.rounds, "spread_seconds": args.spread,
            "timeout_seconds": args.timeout, "cost_bias": args.cost_bias,
            "seed": args.seed, "restart_round": args.restart_round,
            "churn_round": args.churn_round, "quick": args.quick,
        },
        "trace_hash": trace.trace_hash(),
        "summaries": summaries,
        "restart": restart,
        "prior_borrowing": borrow,
        "adaptive_beats_static": bool(adaptive_wins),
        "acceptance": acceptance,
        "trajectory": {
            mode: runs[mode]["rows"] for mode in runs
        },
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
