"""Streamed robust aggregation: the top-k carve vs the dense sort.

Before PR 7 an order-statistic fusion (TrimmedMean / CoordMedian)
forced every store round to materialize the dense (n, P) fp32 matrix
on the host and sort it — at n=48 clients x P=100k params that is a
~19 MB resident set per round, and it grows linearly with n. The
streaming reducer protocol folds (chunk, P) blocks into an O(K*P)
carry (running sum + per-coordinate top-k/bottom-k buffers), so host
ingest is bounded by chunk*P + K*P regardless of n.

Two identical TrimmedMean deployments over the same updates:

  * dense    — the pre-PR path (forced via a 1-byte robust_state_budget:
               the round falls back to read_stacked + full sort).
  * streamed — the carve fold over (chunk, P) blocks.

Reported per mode: warm-round rows/s, RoundReport.bytes_ingested, and
PEAK HOST MEMORY during ``aggregate`` (tracemalloc — numpy staging
allocations, exactly the ingest the carve is meant to bound). The two
fused vectors must agree to fp32 tolerance; otherwise the comparison
is meaningless.

Acceptance: streamed peak host memory <= 0.6x dense at the main
(n=48, P=100k) point AND max |streamed - dense| <= 1e-4. A second
(n=256, P=20k) point shows the bound holding as n grows (the dense
resident set scales with n; the carve carry does not).

Emits BENCH_robust.json.

Usage:
  python benchmarks/robust_rounds.py --quick   # CI smoke (~20 s)
  python benchmarks/robust_rounds.py           # full   (~1-2 min)
"""
from __future__ import annotations

import argparse
import json
import time
import tracemalloc

import numpy as np

from repro.core import AggregationService, UpdateStore
from repro.core.fusion.robust import TrimmedMean


def make_updates(n, p, seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, p)).astype(np.float32)


def run_mode(streamed, u, rounds, chunk_bytes, beta):
    """``rounds`` identical TrimmedMean store rounds on one service;
    round 0 pays the compile, the rest time the warm hot path. The
    dense mode forces the fallback with a 1-byte state budget."""
    n, p = u.shape
    store = UpdateStore()
    svc = AggregationService(
        fusion=TrimmedMean(beta=beta), local_strategy="jnp", store=store,
        stream_chunk_bytes=chunk_bytes,
        robust_state_budget=(64 << 20) if streamed else 1,
    )
    fuse_s, peaks, ingest_bytes, fused_rounds = [], [], [], []
    for _ in range(rounds):
        for i in range(n):
            store.write(f"c{i:04d}", u[i])
        tracemalloc.start()
        fused, rep = svc.aggregate(from_store=True, expected_clients=n)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert rep.streamed == streamed, rep.notes
        fuse_s.append(rep.fuse_seconds)
        peaks.append(peak)
        ingest_bytes.append(rep.bytes_ingested)
        fused_rounds.append(np.asarray(fused))
        store.clear()
    warm = fuse_s[1:] or fuse_s
    fusion = svc.fusion
    return {
        "rows_per_s": n / float(np.median(warm)),
        "warm_fuse_seconds": float(np.median(warm)),
        "peak_host_bytes": int(np.median(peaks)),
        "bytes_per_round": int(ingest_bytes[-1]),
        "state_bytes_model": (
            int(fusion.state_nbytes(p, n)) if streamed else 0
        ),
        "_fused_rounds": fused_rounds,
    }


def bench_point(n, p, rounds, seed, chunk_bytes, beta):
    u = make_updates(n, p, seed)
    dense = run_mode(False, u, rounds, chunk_bytes, beta)
    stream = run_mode(True, u, rounds, chunk_bytes, beta)
    errs = [
        float(np.max(np.abs(sf - df)))
        for sf, df in zip(stream["_fused_rounds"], dense["_fused_rounds"])
    ]
    for mode in (dense, stream):
        del mode["_fused_rounds"]
    mem_ratio = stream["peak_host_bytes"] / max(dense["peak_host_bytes"], 1)
    speed_ratio = stream["rows_per_s"] / max(dense["rows_per_s"], 1e-9)
    point = {
        "n": n, "p": p, "rounds": rounds, "beta": beta,
        "dense_matrix_bytes": int(n * p * 4),
        "dense": dense, "streamed": stream,
        "peak_memory_ratio": mem_ratio,
        "rows_per_s_ratio": speed_ratio,
        "max_fused_error": max(errs),
        "matched": bool(max(errs) <= 1e-4),
    }
    print(f"n={n} P={p}: dense {dense['rows_per_s']:.0f} rows/s "
          f"peak {dense['peak_host_bytes'] / 1e6:.1f} MB | streamed "
          f"{stream['rows_per_s']:.0f} rows/s peak "
          f"{stream['peak_host_bytes'] / 1e6:.1f} MB "
          f"(carry model {stream['state_bytes_model'] / 1e6:.1f} MB) | "
          f"mem {mem_ratio:.2f}x rows/s {speed_ratio:.2f}x "
          f"err {max(errs):.2e} matched={point['matched']}")
    return point


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--p", type=int, default=100_000)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--beta", type=float, default=0.1)
    # 4 MiB blocks: ~10 fp32 rows at P=100k, so the streamed resident
    # set (chunk*P + K*P) sits well under the 19 MB dense matrix
    ap.add_argument("--chunk-bytes", type=int, default=4 << 20)
    ap.add_argument("--out", default="BENCH_robust.json")
    args = ap.parse_args()
    t0 = time.time()
    if args.quick:
        args.n, args.p, args.rounds = 16, 20_000, 3
        args.chunk_bytes = 4 * args.p * 4  # 4-row blocks
    points = [bench_point(args.n, args.p, args.rounds, args.seed,
                          args.chunk_bytes, args.beta)]
    if not args.quick:
        # scaling with client count: the dense resident set grows with
        # n, the carve carry does not
        points.append(bench_point(256, 20_000, args.rounds, args.seed,
                                  4 * 20_000 * 16, args.beta))
    main_pt = points[0]
    acceptance = (
        main_pt["peak_memory_ratio"] <= 0.6
        and all(pt["matched"] for pt in points)
    )
    print(f"acceptance={acceptance} "
          f"(peak mem {main_pt['peak_memory_ratio']:.2f}x <= 0.6, "
          f"matched to fp32 tolerance all points) "
          f"wall {time.time()-t0:.1f}s")
    payload = {
        "benchmark": "robust_rounds",
        "config": {
            "n": args.n, "p": args.p, "rounds": args.rounds,
            "beta": args.beta, "chunk_bytes": args.chunk_bytes,
            "quick": args.quick,
        },
        "points": points,
        "peak_memory_ratio": main_pt["peak_memory_ratio"],
        "rows_per_s_ratio": main_pt["rows_per_s_ratio"],
        "acceptance": bool(acceptance),
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
