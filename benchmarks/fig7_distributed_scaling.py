"""Figs. 7–10 — distributed aggregation scalability + step breakdown.

Paper: PySpark/HDFS supports 100k clients at 4.6 MB (429% over the single
node), 3x clients at every model size, with read/partition/sum/reduce
step timings. Here: the shard_map map-reduce engine over 1..8 forced host
devices (subprocess per mesh size so the benchmark process itself keeps
one device), with the map/reduce time split, plus the analytic max-client
scaling at mesh scale."""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

from benchmarks.common import emit
from repro.core import max_clients_single_node

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.core import DistributedEngine
    from repro.core.fusion import FedAvg, IterAvg
    d = int(sys.argv[1]); n = int(sys.argv[2]); p = int(sys.argv[3])
    from repro.utils.compat import make_mesh
    mesh = make_mesh((d, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    u = rng.normal(size=(n, p)).astype(np.float32)
    w = rng.uniform(1, 100, size=(n,)).astype(np.float32)
    eng = DistributedEngine(mesh=mesh)
    out = {}
    for f in (FedAvg(), IterAvg()):
        r = eng.fuse(f, u, w); jax.block_until_ready(r)  # compile
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            r = eng.fuse(f, u, w); jax.block_until_ready(r)
            ts.append(time.perf_counter() - t0)
        out[f.name] = float(np.median(ts))
    print("RESULT::" + json.dumps(out))
""")


def _child(devices: int, n: int, p: int):
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(devices), str(n), str(p)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
    )
    for line in r.stdout.splitlines():
        if line.startswith("RESULT::"):
            return json.loads(line[len("RESULT::"):])
    raise RuntimeError(r.stderr[-1500:])


def run():
    n, p = 512, 4_600  # 512 scaled-4.6MB clients
    for d in (1, 2, 4, 8):
        res = _child(d, n, p)
        for name, t in res.items():
            emit(f"fig7/{name}_n{n}_mesh{d}", t * 1e6, f"devices={d}")
    # paper's scalability claim at production-mesh scale (memory model):
    single = max_clients_single_node(int(4.6e6))
    mesh_256 = single * 256  # client shards across the data|model mesh
    emit("fig7/max_clients_4.6MB", 0.0,
         f"single_chip={single};mesh256={mesh_256};"
         f"scalability={mesh_256 / single:.0f}x;paper_target=100000")
