"""Fig. 2 — single-node aggregation vs model size at fixed memory.

Paper: at 170 GB, supportable clients collapse from tens of thousands
(4.6 MB) to <150 (956 MB); time grows with model size. Same sweep over
the scaled Table-I suite + analytic max-client curve at 16 GB HBM."""
from __future__ import annotations

from benchmarks.common import SCALED_SUITE, emit, make_updates, timeit
from repro.core import LocalEngine, max_clients_single_node
from repro.core.fusion import FedAvg, IterAvg


def run():
    eng = LocalEngine(strategy="jnp")
    n = 32
    for name, p in SCALED_SUITE.items():
        u, w = make_updates(n, p)
        for fusion in (FedAvg(), IterAvg()):
            t = timeit(lambda: eng.fuse(fusion, u, w))
            emit(f"fig2/{fusion.name}_{name}", t * 1e6, f"n={n};params={p}")
    for name, p in SCALED_SUITE.items():
        full_bytes = p * 1000 * 4  # un-scale to the paper's true size
        emit(
            f"fig2/max_clients_{name}", 0.0,
            f"tpu16GB_max_clients={max_clients_single_node(full_bytes)}",
        )
