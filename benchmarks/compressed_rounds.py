"""Quantized transport: int8 spool + in-kernel dequant fold vs fp32.

The streamed hot path (PR 2/5) still moved every update as fp32: at
n=48 clients x P=100k params a round ingests ~19 MB. This PR's
transport quantizes each client update to int8 codes + fp32 per-block
scales on the WRITE side (``AggregationService(compress=True)`` /
``svc.compress_update``, with per-client error feedback), spools the
codes, and folds the dequantization scales into the streamed
weighted-sum step — the fp32 (n, P) matrix never exists on the host
OR on the device.

Two identical streamed FedAvg deployments over the same updates:

  * dense      — clients write fp32; rounds stream (chunk, P) fp32
                 blocks (the pre-PR hot path).
  * compressed — clients write int8 codes + scales; rounds stream
                 CompressedBlocks through the dequant-folding step.

Reported per mode: warm-round rows/s (median over rounds after the
compile round), bytes/round actually ingested (RoundReport.
bytes_ingested), and the fused vector. MATCHED ERROR: each compressed
round's fused vector must match the dense round's within one
quantization step (atol = max|u| / 127 — the per-block scale bound;
rtol 0), else the speed comparison is meaningless.

Acceptance (ISSUE 6): compressed ingests <= 1/3 the bytes of dense
(int8 codes + fp32 scales model to ~0.251x at P=100k) AND sustains
>= 1.2x dense rows/s at the main (n=48, P=100k) point, with every
round matched-error. A second (n=512, P=20k) point reports scaling
with client count.

Emits BENCH_compressed.json.

Usage:
  python benchmarks/compressed_rounds.py --quick   # CI smoke (~30 s)
  python benchmarks/compressed_rounds.py           # full   (~2 min)
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import AggregationService, UpdateStore


def make_updates(n, p, seed):
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(n, p)).astype(np.float32)
    w = rng.uniform(1, 7, size=(n,)).astype(np.float32)
    return u, w


def run_mode(compress, u, w, rounds, chunk_bytes):
    """``rounds`` identical streamed FedAvg rounds over one service;
    round 0 pays the compile, the rest time the warm hot path."""
    n, p = u.shape
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        compress=compress, stream_chunk_bytes=chunk_bytes,
    )
    fuse_s, ingest_bytes, fused_rounds = [], [], []
    for _ in range(rounds):
        for i in range(n):
            ui = (svc.compress_update(f"c{i:04d}", u[i])
                  if compress else u[i])
            store.write(f"c{i:04d}", ui, weight=float(w[i]))
        fused, rep = svc.aggregate(from_store=True, expected_clients=n)
        assert rep.streamed, "benchmark needs the streamed path"
        fuse_s.append(rep.fuse_seconds)
        ingest_bytes.append(rep.bytes_ingested)
        fused_rounds.append(np.asarray(fused))
        store.clear()
    warm = fuse_s[1:] or fuse_s
    return {
        "rows_per_s": n / float(np.median(warm)),
        "warm_fuse_seconds": float(np.median(warm)),
        "bytes_per_round": int(ingest_bytes[-1]),
        "_fused_rounds": fused_rounds,
    }


def bench_point(n, p, rounds, seed, chunk_bytes):
    u, w = make_updates(n, p, seed)
    dense = run_mode(False, u, w, rounds, chunk_bytes)
    comp = run_mode(True, u, w, rounds, chunk_bytes)
    # matched error: every compressed round within one quantization
    # step of the dense fused vector (EF keeps later rounds there too)
    tol = float(np.abs(u).max()) / 127.0
    errs = [
        float(np.max(np.abs(cf - df)))
        for cf, df in zip(comp["_fused_rounds"], dense["_fused_rounds"])
    ]
    matched = all(e <= tol for e in errs)
    for mode in (dense, comp):
        del mode["_fused_rounds"]
    bytes_ratio = dense["bytes_per_round"] / max(comp["bytes_per_round"], 1)
    speedup = comp["rows_per_s"] / max(dense["rows_per_s"], 1e-9)
    point = {
        "n": n, "p": p, "rounds": rounds,
        "dense": dense, "compressed": comp,
        "bytes_reduction": bytes_ratio,
        "rows_per_s_speedup": speedup,
        "max_fused_error": max(errs),
        "error_tolerance": tol,
        "matched_error": bool(matched),
    }
    print(f"n={n} P={p}: dense {dense['rows_per_s']:.0f} rows/s "
          f"{dense['bytes_per_round']} B/round | compressed "
          f"{comp['rows_per_s']:.0f} rows/s {comp['bytes_per_round']} "
          f"B/round | bytes {bytes_ratio:.2f}x rows/s {speedup:.2f}x "
          f"err {max(errs):.2e} (tol {tol:.2e}) matched={matched}")
    return point


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--p", type=int, default=100_000)
    ap.add_argument("--rounds", type=int, default=7)
    ap.add_argument("--seed", type=int, default=1)
    # 16 MiB: a realistic edge-host staging budget — dense fp32 blocks
    # at this size are memory-bandwidth-bound while the 4x-smaller int8
    # blocks stay cache-resident, which is where quantized transport's
    # compute win comes from (shrink it and both paths converge)
    ap.add_argument("--chunk-bytes", type=int, default=16 << 20)
    ap.add_argument("--out", default="BENCH_compressed.json")
    args = ap.parse_args()
    t0 = time.time()
    if args.quick:
        args.n, args.p, args.rounds = 12, 20_000, 3
    points = [bench_point(args.n, args.p, args.rounds, args.seed,
                          args.chunk_bytes)]
    if not args.quick:
        # scaling with client count: many small clients, same transport
        points.append(bench_point(512, 20_000, args.rounds, args.seed,
                                  args.chunk_bytes))
    main_pt = points[0]
    acceptance = (
        main_pt["bytes_reduction"] >= 3.0
        and main_pt["rows_per_s_speedup"] >= 1.2
        and all(pt["matched_error"] for pt in points)
    )
    print(f"acceptance={acceptance} "
          f"(bytes {main_pt['bytes_reduction']:.2f}x >= 3.0, "
          f"rows/s {main_pt['rows_per_s_speedup']:.2f}x >= 1.2, "
          f"matched error all points) wall {time.time()-t0:.1f}s")
    payload = {
        "benchmark": "compressed_rounds",
        "config": {
            "n": args.n, "p": args.p, "rounds": args.rounds,
            "chunk_bytes": args.chunk_bytes, "quick": args.quick,
        },
        "points": points,
        "bytes_reduction": main_pt["bytes_reduction"],
        "rows_per_s_speedup": main_pt["rows_per_s_speedup"],
        "acceptance": bool(acceptance),
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
