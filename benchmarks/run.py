"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig7 roofline
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (
    fig1_memory_wall,
    fig2_model_size_wall,
    fig3_core_scaling,
    fig5_parallel_vs_baseline,
    fig7_distributed_scaling,
    fig11_model_zoo,
    fig12_end_to_end,
    fig14_engine_comparison,
    roofline,
)

SUITES = {
    "fig1": fig1_memory_wall.run,
    "fig2": fig2_model_size_wall.run,
    "fig3": fig3_core_scaling.run,
    "fig5": fig5_parallel_vs_baseline.run,
    "fig7": fig7_distributed_scaling.run,
    "fig11": fig11_model_zoo.run,
    "fig12": fig12_end_to_end.run,
    "fig14": fig14_engine_comparison.run,
    "roofline": roofline.run,
}


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    failures = []
    for name in wanted:
        try:
            SUITES[name]()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
