"""Figs. 5/6 — parallel single-node fusion vs the NumPy baseline.

Paper: Numba cuts FedAvg time by ~36% (4.6 MB) and ~39.6% (ResNet50, 900
parties); gains grow with party count. TPU adaptation: the Pallas
streaming kernel is the Numba analogue. On CPU the kernel runs in
interpret mode (a correctness harness, not a speed one), so the HONEST
wall-clock comparison here is numpy-loop vs XLA-fused; the kernel's
performance claim is structural (single HBM pass, MXU-shaped) and is
carried by the roofline, not this wall clock. Both are reported."""
from __future__ import annotations

from benchmarks.common import emit, make_updates, timeit
from repro.core import LocalEngine
from repro.core.fusion import FedAvg, IterAvg
from benchmarks.fig3_core_scaling import _ibmfl_style_numpy


def run():
    for fusion in (FedAvg(), IterAvg()):
        for n in (64, 256, 900):
            p = 23_000  # scaled ResNet50 (91 MB / 4 / 1000)
            u, w = make_updates(n, p)
            t_base = timeit(lambda: _ibmfl_style_numpy(u, w))
            t_fused = timeit(
                lambda: LocalEngine(strategy="jnp").fuse(fusion, u, w)
            )
            emit(
                f"fig5/{fusion.name}_resnet50s_n{n}_baseline",
                t_base * 1e6, "",
            )
            emit(
                f"fig5/{fusion.name}_resnet50s_n{n}_fused",
                t_fused * 1e6,
                f"reduction={100 * (1 - t_fused / t_base):.1f}%",
            )
    # pallas interpret-mode correctness wall time (NOT a TPU speed claim)
    u, w = make_updates(64, 23_000)
    t_pl = timeit(
        lambda: LocalEngine(strategy="pallas").fuse(FedAvg(), u, w),
        iters=1,
    )
    emit("fig5/pallas_interpret_n64", t_pl * 1e6, "interpret_mode=True")
