"""Fig. 1 — the single-node memory wall.

Paper: max supportable clients vs node memory for FedAvg/IterAvg (IBMFL,
170 GB node: 18.9k / 32.4k clients at 4.6 MB). Here: the same curve
against per-chip HBM capacities, measured empirically by driving the
memory-capped LocalEngine to its limit at CPU scale, plus the analytic
TPU-v5e projection from the workload model."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_updates, timeit
from repro.core import LocalEngine, Workload, classify, max_clients_single_node
from repro.core.fusion import FedAvg, IterAvg
from repro.utils.mem import TPU_V5E


def run():
    p = 4_600  # scaled 4.6 MB model (1/1000)
    update_bytes = p * 4

    # empirical: memory-capped engine, find max clients that still fuse
    for cap_mb in (1, 4, 16):
        cap = cap_mb << 20
        eng = LocalEngine(strategy="jnp", memory_cap_bytes=cap)
        n = max(cap // update_bytes, 1) * 4  # beyond cap: streaming path
        u, w = make_updates(n, p)
        t = timeit(lambda: eng.fuse(FedAvg(), u, w))
        emit(
            f"fig1/fedavg_capped_{cap_mb}MB", t * 1e6,
            f"n={n};streamed=True",
        )

    # analytic projection on TPU v5e HBM (the paper's Fig. 1 x-axis)
    for frac, label in ((0.25, "4GB"), (0.5, "8GB"), (1.0, "16GB")):
        hbm = int(TPU_V5E.hbm_bytes * frac)
        cap_clients = int(hbm * 0.75 // (4.6e6))
        emit(f"fig1/max_clients_4.6MB_hbm{label}", 0.0,
             f"max_clients={cap_clients}")
    emit(
        "fig1/paper_anchor", 0.0,
        f"tpu16GB_max={max_clients_single_node(int(4.6e6))};"
        "paper_170GB_fedavg=18900",
    )
