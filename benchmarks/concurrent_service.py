"""Concurrent round execution: K tenants' rounds at once on ONE service.

PR 4 made interleaved open rounds safe on one shared store, but one
``AggregationService`` still executed one round at a time — concurrent
tenants needed one service per tenant. The RoundScheduler closes that
gap: per-tenant round workers run every tenant's round NOW, overlapping
their monitor waits and host staging while a bounded device-execution
semaphore (default 1) serializes only what the hardware requires, and
the engines' single-flight compile cache lets K racing tenants pay ONE
cold compile.

Three deployments over identical per-tenant workloads — ONE
``repro.workload`` trace (``UniformArrivals`` over the straggler
window, distinct deterministic payloads per tenant) replayed by every
mode; rounds are async with a full-inclusion threshold:

  * serialized  — ONE service, rounds one at a time (the pre-scheduler
                  behavior): each tenant's round runs after the
                  previous tenant's closed, so K straggler windows are
                  paid end to end.
  * concurrent  — ONE service + RoundScheduler: all K rounds at once;
                  the K straggler windows overlap into ~one.
  * separate    — K services (one per tenant, the PR-4 workaround),
                  rounds in K threads: walls overlap too, but every
                  service pays its own cold compile and its own engine
                  state.

Reported per mode: total round wall-clock, per-round inclusion, cold
compiles, peak host memory (tracemalloc) — and EQUIVALENCE: every
tenant's fused vector must match the dense FedAvg formula on that
tenant's updates alone, and the shared-service (concurrent) vectors
must match the isolated-service (separate) ones.

Acceptance (ISSUE 5): concurrent total wall < serialized total wall,
inclusion 1.0 everywhere, all modes formula-equivalent, concurrent
cold compiles <= the number of DISTINCT shape buckets (not <= K x
buckets).

Emits BENCH_concurrent.json.

Usage:
  python benchmarks/concurrent_service.py --quick   # CI smoke (~30 s)
  python benchmarks/concurrent_service.py           # full   (~2 min)
"""
from __future__ import annotations

import argparse
import json
import threading
import time
import tracemalloc

import numpy as np

from repro.core import AggregationService, RoundScheduler, UpdateStore
from repro.workload import (
    FixedSize,
    RegimeSchedule,
    UniformArrivals,
    WorkloadSpec,
    start_writer,
    trace_payload,
)


def make_trace(tenants, n, p, spread, seed):
    """ONE shared trace: per-tenant rounds with distinct deterministic
    payload streams (``trace_payload`` keys on the tenant), so a
    cross-tenant steal or a crossed accumulator cannot cancel out
    numerically — and every mode replays the identical schedule."""
    spec = WorkloadSpec(
        tenants=tuple(tenants), n_clients=n, rounds=1,
        regimes=RegimeSchedule.single(UniformArrivals(spread=spread)),
        sizes=FixedSize(p),
    )
    return spec.build(seed).rounds[0]


def dense_tenant(tenant_round, seed):
    """The traced tenant-round as a dense (u, w) pair — the formula
    reference every fused vector is checked against."""
    u = np.stack([
        trace_payload(seed, tenant_round.tenant, ev.client_id,
                      tenant_round.dim)
        for ev in tenant_round.events
    ])
    w = np.asarray([ev.weight for ev in tenant_round.events], np.float32)
    return u, w


def fedavg_formula(u, w):
    return np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)


def _mk_service(store, n, p, timeout):
    return AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=timeout,
        stream_chunk_bytes=max(p * 4 * max(n // 4, 1), 1 << 20),
    )


def _check_round(rep, fused, u_k, w_k, n, state):
    state["inclusions"].append(rep.n_clients / n)
    if rep.n_clients > n or (rep.n_clients == n and not np.allclose(
        np.asarray(fused), fedavg_formula(u_k, w_k),
        rtol=1e-4, atol=1e-5,
    )):
        state["equivalent"] = False   # a steal or a lost update


def run_serialized(tenants, trace_round, refs, seed, p, timeout, rounds):
    """ONE service, one round at a time — each tenant's writer starts
    with its OWN round, so the K straggler windows are paid end to end
    (the pre-scheduler deployment's cost)."""
    n = trace_round.tenants[0].expected
    store = UpdateStore()
    svc = _mk_service(store, n, p, timeout)
    state = {"inclusions": [], "equivalent": True, "fused": {}}
    t0 = time.perf_counter()
    for _ in range(rounds):
        for t in tenants:
            wt = start_writer(store, trace_round.tenant(t), seed)
            fused, rep = svc.aggregate(
                from_store=True, expected_clients=n, async_round=True,
                tenant=t,
            )
            wt.join()
            _check_round(rep, fused, *refs[t], n, state)
            state["fused"][t] = np.asarray(fused)
            store.clear(tenant=t)
    state["wall_seconds"] = time.perf_counter() - t0
    state["cold_compiles"] = svc.local.cache.misses
    return state


def run_concurrent(tenants, trace_round, refs, seed, p, timeout, rounds):
    """ONE service + RoundScheduler: every tenant's round executes NOW;
    straggler windows overlap, device folds share the semaphore, and
    racing tenants share one single-flight compile."""
    n = trace_round.tenants[0].expected
    store = UpdateStore()
    svc = _mk_service(store, n, p, timeout)
    state = {"inclusions": [], "equivalent": True, "fused": {}}
    t0 = time.perf_counter()
    with RoundScheduler(svc) as sched:
        for _ in range(rounds):
            writers = [
                start_writer(store, trace_round.tenant(t), seed)
                for t in tenants
            ]
            results = sched.run_round(
                tenants, from_store=True, expected_clients=n,
                async_round=True,
            )
            for wt in writers:
                wt.join()
            for t in tenants:
                fused, rep = results[t]
                _check_round(rep, fused, *refs[t], n, state)
                state["fused"][t] = np.asarray(fused)
                store.clear(tenant=t)
    state["wall_seconds"] = time.perf_counter() - t0
    state["cold_compiles"] = svc.local.cache.misses
    return state


def run_separate(tenants, trace_round, refs, seed, p, timeout, rounds):
    """K isolated services (one per tenant — the PR-4 workaround for
    concurrent execution), rounds in K threads."""
    n = trace_round.tenants[0].expected
    stores = {t: UpdateStore() for t in tenants}
    services = {t: _mk_service(stores[t], n, p, timeout) for t in tenants}
    state = {"inclusions": [], "equivalent": True, "fused": {}}
    lock = threading.Lock()

    def one_tenant(t):
        for _ in range(rounds):
            wt = start_writer(stores[t], trace_round.tenant(t), seed)
            fused, rep = services[t].aggregate(
                from_store=True, expected_clients=n, async_round=True,
                tenant=t,
            )
            wt.join()
            with lock:
                _check_round(rep, fused, *refs[t], n, state)
                state["fused"][t] = np.asarray(fused)
            stores[t].clear(tenant=t)

    t0 = time.perf_counter()
    threads = [
        threading.Thread(target=one_tenant, args=(t,), daemon=True)
        for t in tenants
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    state["wall_seconds"] = time.perf_counter() - t0
    state["cold_compiles"] = sum(
        services[t].local.cache.misses for t in tenants
    )
    return state


def bench(k, n, p, spread, timeout, rounds, seed):
    tenants = [f"app{i}" for i in range(k)]
    trace_round = make_trace(tenants, n, p, spread, seed)
    refs = {t: dense_tenant(trace_round.tenant(t), seed) for t in tenants}
    # one shape bucket per distinct (n, p) pair — here all tenants share
    # one, which is exactly what the <= buckets acceptance pins down
    buckets = len({(n, p)})
    runners = {
        "serialized": run_serialized,
        "concurrent": run_concurrent,
        "separate": run_separate,
    }
    results = {}
    tracemalloc.start()
    for mode, fn in runners.items():
        tracemalloc.reset_peak()
        st = fn(tenants, trace_round, refs, seed, p, timeout, rounds)
        _, peak = tracemalloc.get_traced_memory()
        results[mode] = {
            "total_wall_seconds": st["wall_seconds"],
            "mean_inclusion": float(np.mean(st["inclusions"])),
            "cold_compiles": int(st["cold_compiles"]),
            "equivalent": bool(st["equivalent"]),
            "peak_host_bytes": int(peak),
        }
        results[mode]["_fused"] = st["fused"]
        r = results[mode]
        print(f"{mode:>10}: wall {r['total_wall_seconds']:.3f}s "
              f"inclusion {r['mean_inclusion']:.3f} "
              f"cold_compiles {r['cold_compiles']} "
              f"peak_mem {r['peak_host_bytes'] / 1e6:.1f}MB "
              f"equivalent={r['equivalent']}")
    tracemalloc.stop()
    # shared-vs-isolated: the concurrent (shared service) vectors must
    # match the separate-services (isolated) ones tenant by tenant
    shared_vs_isolated = all(
        np.allclose(results["concurrent"]["_fused"][t],
                    results["separate"]["_fused"][t],
                    rtol=1e-4, atol=1e-5)
        for t in tenants
    )
    for mode in results:
        del results[mode]["_fused"]
    con, ser = results["concurrent"], results["serialized"]
    speedup = ser["total_wall_seconds"] / max(
        con["total_wall_seconds"], 1e-9
    )
    acceptance = (
        con["total_wall_seconds"] < ser["total_wall_seconds"]
        and all(results[m]["mean_inclusion"] >= 1.0 - 1e-9
                for m in results)
        and all(results[m]["equivalent"] for m in results)
        and shared_vs_isolated
        and con["cold_compiles"] <= buckets
    )
    print(f"concurrent beats serialized {speedup:.2f}x on one service "
          f"({con['cold_compiles']} cold compiles for {k} tenants, "
          f"{buckets} shape bucket(s)); shared==isolated: "
          f"{shared_vs_isolated}; acceptance={acceptance}")
    return results, {
        "speedup_vs_serialized": speedup,
        "shape_buckets": buckets,
        "shared_vs_isolated_equivalent": bool(shared_vs_isolated),
        "acceptance": bool(acceptance),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--p", type=int, default=100_000)
    ap.add_argument("--spread", type=float, default=1.0)
    ap.add_argument("--timeout", type=float, default=10.0)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default="BENCH_concurrent.json")
    args = ap.parse_args()
    if args.quick:
        args.n, args.p = 12, 20_000
        args.spread, args.timeout = 0.5, 6.0
        args.rounds = 1
    results, summary = bench(
        args.tenants, args.n, args.p, args.spread, args.timeout,
        args.rounds, args.seed,
    )
    payload = {
        "benchmark": "concurrent_service",
        "config": {
            "tenants": args.tenants, "n_clients_per_tenant": args.n,
            "p": args.p, "spread_seconds": args.spread,
            "timeout_seconds": args.timeout, "rounds": args.rounds,
            "quick": args.quick,
        },
        "results": results,
        **summary,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
