"""Model-stack tests: per-arch smoke (deliverable f), attention math vs
naive reference (values + grads), chunked-scan vs recurrent equivalence
(SSD / mLSTM / ring caches), and prefill==decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.models import build_model
from repro.models.layers.attention import blockwise_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.utils import tree_num_params

RNG = np.random.default_rng(3)
ARCH_IDS = list(ARCHITECTURES)


def _batch(cfg, B=2, T=32):
    b = {
        "tokens": jnp.asarray(
            RNG.integers(0, cfg.vocab, size=(B, T)), jnp.int32
        ),
    }
    b["labels"] = b["tokens"]
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_patch_tokens, cfg.d_model)) * 0.02,
            cfg.param_dtype,
        )
    if cfg.family == "audio":
        b["audio_frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_audio_frames, cfg.d_model)) * 0.02,
            cfg.param_dtype,
        )
    return b


# -- per-arch smoke tests (REDUCED configs, one fwd + one train step) ---------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert tree_num_params(params) == cfg.num_params()

    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True)
    )(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    for g in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad"

    logits = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    cache = model.init_cache(B, S)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    tok = jnp.ones((B, 1), jnp.int32)
    cache, logits = step(params, cache, tok, jnp.int32(0))
    cache, logits = step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


# -- attention math -----------------------------------------------------------


def test_blockwise_attention_values_and_grads():
    B, T, nq, nkv, hd = 2, 128, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, T, nq, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    for win in (0, 48):
        out = blockwise_attention(q, k, v, causal=True, window=win,
                                  q_chunk=32, kv_chunk=32)
        ref = attention_ref(q, k, v, causal=True, window=win)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)
        f1 = lambda *a: jnp.sum(jnp.sin(blockwise_attention(
            *a, causal=True, window=win, q_chunk=32, kv_chunk=32)))
        f2 = lambda *a: jnp.sum(jnp.sin(attention_ref(
            *a, causal=True, window=win)))
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_blockwise_attention_dynamic_window():
    """Traced window (gemma3 5:1 pattern under scan) == static window."""
    B, T, nq, nkv, hd = 1, 64, 2, 1, 16
    q = jnp.asarray(RNG.normal(size=(B, T, nq, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    stat = blockwise_attention(q, k, v, window=16, q_chunk=16, kv_chunk=16)
    dyn = jax.jit(
        lambda w: blockwise_attention(q, k, v, window=w, q_chunk=16,
                                      kv_chunk=16)
    )(jnp.int32(16))
    np.testing.assert_allclose(stat, dyn, rtol=1e-6)


# -- chunked-parallel vs recurrent equivalence --------------------------------


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m", "qwen2-0.5b",
                                  "gemma3-1b"])
def test_prefill_matches_stepwise_decode(arch):
    """Teacher-forced decode step-by-step must reproduce prefill's
    last-position logits: validates SSD chunking, mLSTM chunking, RoPE'd
    ring caches, and windowed attention in one shot."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, T = 2, 24
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, size=(B, T)), jnp.int32)
    batch = {"tokens": toks}
    ref_logits = jax.jit(model.prefill)(params, batch)

    cache = model.init_cache(B, 64)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    logits = None
    for t in range(T):
        cache, logits = step(params, cache, toks[:, t: t + 1], jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits), rtol=2e-3, atol=2e-3
    )


def test_ring_cache_windowed_equals_full_for_short_seq():
    """A windowed ring cache must agree with a full cache while the
    context is shorter than the window."""
    cfg = get_config("gemma3-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, T = 1, 10  # < window (16 in reduced)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, size=(B, T)), jnp.int32)
    c_full = model.init_cache(B, 64)          # windowed layers get ring 16
    c_big = model.init_cache(B, 64, force_local=False)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    la = lb = None
    for t in range(T):
        c_full, la = step(params, c_full, toks[:, t: t + 1], jnp.int32(t))
        c_big, lb = step(params, c_big, toks[:, t: t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5,
                               atol=1e-5)


def test_long_context_archs_have_o1_or_windowed_state():
    """long_500k-capable archs must not allocate O(seq) full caches."""
    for arch in ("xlstm-350m", "zamba2-1.2b", "gemma3-1b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        cache = model.init_cache(1, 524_288, spec_only=True,
                                 force_local=True)
        from repro.utils.pytree import tree_size_bytes
        assert tree_size_bytes(cache) < 2 * 2**30, arch
