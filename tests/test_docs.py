"""Docs smoke checks: the README / ARCHITECTURE / benchmarks docs stay
truthful as the code moves.

  * every intra-repo markdown link resolves to a real file;
  * every ``python <path>`` / ``python -m <module>`` command in a doc
    code block references a file / importable module that exists;
  * every ``--flag`` a doc passes to ``repro.launch.aggregate`` (or a
    benchmark script) is actually defined by that script's parser.

Runtime execution of the documented commands lives in the verify
recipe (the ``--quick`` benchmark paths), not here — this suite must
stay fast enough for tier-1.
"""
import importlib.util
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOCS = [
    "README.md",
    "docs/ARCHITECTURE.md",
    "docs/MULTITENANCY.md",
    "docs/TUNING.md",
    "docs/SERVING.md",
    "docs/ANALYSIS.md",
    "benchmarks/README.md",
]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```(?:bash|sh|console)?\n(.*?)```", re.DOTALL)
CMD_RE = re.compile(
    r"python\s+(?:-m\s+(?P<module>[\w.]+)|(?P<path>[\w/.-]+\.py))"
    r"(?P<rest>[^\n\\]*(?:\\\n[^\n\\]*)*)"
)
FLAG_RE = re.compile(r"(--[\w-]+)")


def _read(rel):
    with open(os.path.join(REPO, rel)) as f:
        return f.read()


@pytest.mark.parametrize("doc", DOCS)
def test_docs_exist(doc):
    assert os.path.exists(os.path.join(REPO, doc)), f"{doc} missing"


@pytest.mark.parametrize("doc", DOCS)
def test_intra_repo_links_resolve(doc):
    text = _read(doc)
    base = os.path.dirname(os.path.join(REPO, doc))
    broken = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        path = os.path.normpath(os.path.join(base, target.split("#")[0]))
        if not os.path.exists(path):
            broken.append(target)
    assert not broken, f"{doc}: broken intra-repo links: {broken}"


def _commands(doc):
    text = _read(doc)
    for block in FENCE_RE.findall(text):
        for m in CMD_RE.finditer(block):
            yield m


@pytest.mark.parametrize("doc", DOCS)
def test_doc_commands_reference_real_entry_points(doc):
    missing = []
    for m in _commands(doc):
        if m.group("module"):
            if importlib.util.find_spec(m.group("module")) is None:
                missing.append(m.group("module"))
        else:
            if not os.path.exists(os.path.join(REPO, m.group("path"))):
                missing.append(m.group("path"))
    assert not missing, f"{doc}: commands reference missing entry " \
                        f"points: {missing}"


@pytest.mark.parametrize("doc", DOCS)
def test_doc_flags_exist_in_target_scripts(doc):
    """A doc showing ``python x.py --flag`` must only use flags the
    script's argparse actually defines."""
    unknown = []
    for m in _commands(doc):
        if m.group("module"):
            spec = importlib.util.find_spec(m.group("module"))
            if spec is None or not spec.origin:
                continue
            src_path = spec.origin
        else:
            src_path = os.path.join(REPO, m.group("path"))
            if not os.path.exists(src_path):
                continue
        with open(src_path) as f:
            src = f.read()
        for flag in FLAG_RE.findall(m.group("rest") or ""):
            if f'"{flag}"' not in src and f"'{flag}'" not in src:
                unknown.append((os.path.basename(src_path), flag))
    assert not unknown, f"{doc}: flags not defined by their script: " \
                        f"{unknown}"


def test_operator_docs_cover_their_subjects():
    """The operator docs must keep documenting the surfaces they exist
    for — a rename in the code without a doc update fails here."""
    multitenancy = _read("docs/MULTITENANCY.md")
    for term in ("tenant=", "SpoolTailer", ".tenant", "ingest_external",
                 "save_controller", "--concurrent-tenants",
                 "BENCH_multitenant.json", "RoundScheduler",
                 "set_quota", "QuotaExceededError", "stats_for",
                 "device_concurrency", "BENCH_concurrent.json"):
        assert term in multitenancy, f"MULTITENANCY.md lost {term!r}"
    tuning = _read("docs/TUNING.md")
    for term in ("cost_bias", "staleness_discount", 'async_round="auto"',
                 "threshold_frac", "monitor_timeout", "phase_seconds",
                 "RoundReport", "drift", "device_concurrency",
                 "set_quota", "rewarm", "store_stats", "RoundScheduler",
                 "compress=True", "--compress", "compress_update",
                 "bytes_ingested", "stream_chunk_bytes",
                 "Reading soak trajectories", "BENCH_soak.json",
                 "save_controller", "rewarm_patience", "drift_gain"):
        assert term in tuning, f"TUNING.md lost {term!r}"
    bench_readme = _read("benchmarks/README.md")
    for term in ("BENCH_soak.json", "soak_rounds.py", "trace_hash",
                 "repro.workload", "post_resume_sources",
                 "prior_borrowing", "--trace-out", "--seed",
                 "BENCH_ingest.json", "ingest_service.py",
                 "disconnects_injected", "p99_latency_s",
                 "sustained_uploads_per_s"):
        assert term in bench_readme, f"benchmarks/README.md lost {term!r}"
    serving = _read("docs/SERVING.md")
    for term in ("FLU1", "IngestServer", "IngestQueue", "write_batch",
                 "HttpStoreClient", "FairRoundScheduler",
                 "EdgeAggregatorServer", "Retry-After", "TokenBucket",
                 "read_timeout", "max_body_bytes", "WireError",
                 "encode_update", "/v1/upload", "/v1/healthz",
                 "Bearer", "BENCH_ingest.json", "--quick",
                 "capacity_bytes", "max_running"):
        assert term in serving, f"SERVING.md lost {term!r}"
    arch = _read("docs/ARCHITECTURE.md")
    for term in ("compress_update", "weighted_sum_dequant_pallas",
                 "CompressedBlock", "error feedback", ".scale",
                 "bytes_ingested", "BENCH_compressed.json",
                 "repro/analysis/", "ANALYSIS.md"):
        assert term in arch, f"ARCHITECTURE.md lost {term!r}"
    analysis = _read("docs/ANALYSIS.md")
    for term in ("guarded-by", "lint: disable=", "-- <reason>",
                 "guarded-access", "blocking-under-lock", "trace-hazard",
                 "sync-under-sem", "thread-join", "bare-acquire",
                 "unused-import", "suppression-format",
                 "repro.analysis.lint", "--format=json", "--baseline",
                 "--write-baseline", "--show-suppressed", "--list-rules",
                 "LockOrderWitness", "instrument_service",
                 "lock_witness", "state lock", "holds=_lock",
                 "Caller holds"):
        assert term in analysis, f"ANALYSIS.md lost {term!r}"


def test_readme_documents_tier1_and_bench_artifacts():
    """The README must keep the tier-1 command and a row per BENCH
    artifact actually present in the repo root."""
    text = _read("README.md")
    assert "python -m pytest -x -q" in text
    for artifact in sorted(
        f for f in os.listdir(REPO)
        if f.startswith("BENCH_") and f.endswith(".json")
    ):
        assert artifact in text, f"README missing a row for {artifact}"
