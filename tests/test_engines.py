"""Engine equivalence (paper §IV-C): every engine computes the same fusion
formula. Single-device in-process; 8-device via subprocess (the dry-run
alone may force host device counts, never the test process)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DistributedEngine, LocalEngine
from repro.core.fusion import (
    ClippedAvg,
    CoordMedian,
    FedAvg,
    GeometricMedian,
    IterAvg,
    Krum,
    TrimmedMean,
    Zeno,
)

ALL_FUSIONS = [
    FedAvg(), IterAvg(), ClippedAvg(clip_norm=3.0), CoordMedian(),
    TrimmedMean(beta=0.2), Krum(n_byzantine=2), Zeno(n_suspect=2),
    GeometricMedian(),
]


@pytest.fixture(scope="module")
def data(rng=np.random.default_rng(1)):
    u = rng.normal(size=(13, 257)).astype(np.float32)
    w = rng.uniform(1, 5, size=(13,)).astype(np.float32)
    return u, w


@pytest.mark.parametrize("fusion", ALL_FUSIONS, ids=lambda f: f.name)
def test_local_pallas_matches_jnp(fusion, data):
    u, w = data
    a = np.asarray(LocalEngine(strategy="jnp").fuse(fusion, u, w))
    b = np.asarray(LocalEngine(strategy="pallas").fuse(fusion, u, w))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("fusion", ALL_FUSIONS, ids=lambda f: f.name)
def test_distributed_1dev_matches_local(fusion, data):
    u, w = data
    from repro.utils.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    a = np.asarray(LocalEngine(strategy="jnp").fuse(fusion, u, w))
    b = np.asarray(DistributedEngine(mesh=mesh).fuse(fusion, u, w))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_streamed_memory_cap_matches_full(data):
    u, w = data
    full = np.asarray(LocalEngine(strategy="jnp").fuse(FedAvg(), u, w))
    row_bytes = u.shape[1] * 4
    capped = LocalEngine(strategy="jnp", memory_cap_bytes=row_bytes * 3)
    out = np.asarray(capped.fuse(FedAvg(), u, w))
    np.testing.assert_allclose(out, full, rtol=1e-5, atol=1e-6)


def test_memory_cap_rejects_nonstreamable(data):
    u, w = data
    capped = LocalEngine(strategy="jnp", memory_cap_bytes=u.shape[1] * 4 * 2)
    with pytest.raises(MemoryError):
        capped.fuse(Krum(), u, w)


def test_memory_cap_streams_order_statistics(data):
    """CoordMedian under a memory cap streams through the carve fold
    (PR 7) instead of raising MemoryError."""
    u, w = data
    capped = LocalEngine(strategy="jnp", memory_cap_bytes=u.shape[1] * 4 * 2)
    out = np.asarray(capped.fuse(CoordMedian(), u, w))
    np.testing.assert_allclose(out, np.median(u, axis=0),
                               rtol=1e-5, atol=1e-5)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core import DistributedEngine, LocalEngine
    from repro.core.fusion import (FedAvg, IterAvg, ClippedAvg, CoordMedian,
                                   TrimmedMean, Krum, Zeno, GeometricMedian)
    from repro.utils.compat import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(1)
    u = rng.normal(size=(13, 257)).astype(np.float32)
    w = rng.uniform(1, 5, size=(13,)).astype(np.float32)
    le = LocalEngine(strategy="jnp")
    for hier in (False, True):
        de = DistributedEngine(mesh=mesh, hierarchical=hier)
        for f in (FedAvg(), IterAvg(), ClippedAvg(clip_norm=3.0),
                  CoordMedian(), TrimmedMean(beta=0.2), Krum(n_byzantine=2),
                  Zeno(n_suspect=2), GeometricMedian()):
            if hier and not f.reducible:
                continue
            a = np.asarray(le.fuse(f, u, w))
            b = np.asarray(de.fuse(f, u, w))
            assert np.allclose(a, b, rtol=1e-4, atol=1e-5), (f.name, hier)
    print("MULTI_DEVICE_OK")
""")


def test_multi_device_equivalence_subprocess():
    """2x2x2 pod mesh on 8 forced host devices, all fusions + hierarchical."""
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert "MULTI_DEVICE_OK" in r.stdout, r.stderr[-3000:]
