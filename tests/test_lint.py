"""repro.analysis — static lint + runtime lock-order witness (ISSUE 10):

  * repo-must-be-clean gate — `python -m repro.analysis.lint src/repro`
    has zero findings on the committed tree, and every suppression
    carries a rule name AND a reason;
  * fixture corpus — each rule class detects its deliberately seeded
    violations (true positives) and stays quiet on the disciplined
    variants (true negatives), and suppression comments parse;
  * CLI — text/JSON reporters, exit codes, --baseline (fail only on
    NEW findings) and --write-baseline;
  * witness — cycle + declared-partial-order detection on artificial
    locks, and a clean bill for a real concurrent multi-tenant run on
    one instrumented AggregationService (the witness also rides along
    on the concurrency suites via the ``lock_witness`` fixture);
  * shutdown hygiene — SpoolTailer.stop() / IngestQueue.close() /
    FairRoundScheduler.shutdown() leave zero live worker threads;
  * regression tests for the true positives the pass surfaced in
    store.py (ingest_external's unlocked grace-map touches) and
    service.py (unlocked carry/stale-age maps).
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.analysis.core import Finding, default_rules, lint_file, lint_paths
from repro.analysis.lint import main as lint_main
from repro.analysis.witness import (
    LockOrderViolation,
    LockOrderWitness,
    instrument_service,
)
from repro.core import AggregationService, RoundScheduler, UpdateStore

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src", "repro")
FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "lint_fixtures")
RNG = np.random.default_rng(17)


def fixture_findings(name):
    res = lint_paths([os.path.join(FIXTURES, name)])
    return res


# -- the repo-must-be-clean gate ---------------------------------------------


def test_repo_tree_is_lint_clean():
    """The committed tree has ZERO findings — new violations of any
    rule fail tier-1, not just full lint runs."""
    res = lint_paths([SRC])
    assert res.files > 80, "lint walked suspiciously few files"
    assert res.findings == [], "\n".join(str(f) for f in res.findings)


def test_every_suppression_has_rule_and_reason():
    """The suppression register is the repo's enumerable debt: each
    entry names a shipped rule and explains itself."""
    res = lint_paths([SRC])
    known = {r.name for r in default_rules()}
    assert res.suppressed, "expected documented known-limitation sites"
    for finding, sup in res.suppressed:
        assert finding.rule in known
        assert sup.reason and len(sup.reason) > 10, (
            f"{sup.path}:{sup.line} suppression lacks a real reason"
        )


def test_known_limitation_sites_are_recorded():
    """The documented deliberate sites stay visible as suppressions:
    the engines' sync-inside-device_sem and the store's one-lock-per-
    batch quota probes."""
    res = lint_paths([SRC])
    rules = {f.rule for f, _ in res.suppressed}
    files = {os.path.basename(f.path) for f, _ in res.suppressed}
    assert "sync-under-sem" in rules
    assert "guarded-access" in rules
    assert {"local.py", "distributed.py", "service.py", "store.py"} <= files


# -- fixture corpus: every rule's true positives and negatives ---------------


def test_guarded_access_positives_and_negatives():
    bad = fixture_findings("guarded_bad.py")
    got = [(f.rule, f.line) for f in bad.findings]
    assert got == [("guarded-access", 13), ("guarded-access", 18),
                   ("guarded-access", 23)]
    ok = fixture_findings("guarded_ok.py")
    assert ok.findings == []


def test_blocking_under_lock_positives_and_negatives():
    bad = fixture_findings("blocking_bad.py")
    assert [f.rule for f in bad.findings] == ["blocking-under-lock"] * 4
    assert [f.line for f in bad.findings] == [15, 19, 23, 27]
    ok = fixture_findings("blocking_ok.py")
    assert ok.findings == []


def test_trace_hazard_positives_and_negatives():
    bad = fixture_findings("trace_bad.py")
    assert [f.rule for f in bad.findings] == ["trace-hazard"] * 5
    msgs = " ".join(f.message for f in bad.findings)
    assert "compile-cache key" in msgs
    assert "traced function" in msgs
    assert "unhashable" in msgs
    ok = fixture_findings("trace_ok.py")
    assert ok.findings == []


def test_sync_under_sem_positive_and_negative():
    bad = fixture_findings("sem_bad.py")
    assert [(f.rule, f.line) for f in bad.findings] == [
        ("sync-under-sem", 14), ("sync-under-sem", 19)]


def test_thread_hygiene_positives_and_negatives():
    bad = fixture_findings("threads_bad.py")
    assert [(f.rule, f.line) for f in bad.findings] == [
        ("thread-join", 10), ("thread-join", 15), ("bare-acquire", 19)]
    ok = fixture_findings("threads_ok.py")
    assert ok.findings == []


def test_unused_import_positives_and_negatives():
    bad = fixture_findings("unused_bad.py")
    names = sorted(f.message.split("'")[1] for f in bad.findings)
    assert names == ["Optional", "json"]  # __future__, __all__, Dict exempt


def test_suppressions_silence_and_register():
    ok = fixture_findings("suppress_ok.py")
    assert ok.findings == []
    assert len(ok.suppressed) == 3  # probe + two sleeps (function-level)
    reasons = {s.reason for _, s in ok.suppressed}
    assert all(r for r in reasons)


def test_malformed_suppressions_are_findings():
    bad = fixture_findings("suppress_bad.py")
    assert [f.rule for f in bad.findings] == ["suppression-format"] * 3
    msgs = " ".join(f.message for f in bad.findings)
    assert "missing a reason" in msgs
    assert "unknown rule" in msgs
    assert "malformed" in msgs


def test_holds_docstring_convention_matches_repo_idiom():
    """The exact docstring phrasing store.py uses ('Caller holds
    ``self._lock``' / 'Callers must hold') declares the lock held."""
    snippet = (
        "import threading\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "        self._m = {}  # guarded-by: _lock\n"
        "    def _a_locked(self):\n"
        '        """Drop. Caller holds ``self._lock``."""\n'
        "        self._m.clear()\n"
        "    def _b(self):\n"
        '        """Callers must hold ``self._lock``."""\n'
        "        return len(self._m)\n"
    )
    kept, _ = lint_file("s.py", default_rules(), source=snippet)
    assert kept == []


# -- CLI reporters and baseline ----------------------------------------------


def test_cli_json_reporter_and_exit_codes(capsys):
    rc = lint_main([os.path.join(FIXTURES, "guarded_bad.py"),
                    "--format=json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["files"] == 1
    assert {f["rule"] for f in payload["findings"]} == {"guarded-access"}
    assert all(
        {"rule", "path", "line", "message"} <= set(f)
        for f in payload["findings"]
    )
    rc = lint_main([os.path.join(FIXTURES, "guarded_ok.py"),
                    "--format=json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0 and payload["findings"] == []


def test_cli_baseline_masks_old_findings_only(tmp_path, capsys):
    """--baseline: pre-existing findings don't fail the run; NEW ones
    do. This is the future-PR escape hatch for inherited debt."""
    base = tmp_path / "base.json"
    target = os.path.join(FIXTURES, "guarded_bad.py")
    rc = lint_main([target, "--write-baseline", str(base)])
    capsys.readouterr()
    assert rc == 0 and base.exists()
    # same tree, baselined -> clean
    rc = lint_main([target, "--baseline", str(base)])
    out = capsys.readouterr().out
    assert rc == 0 and "0 finding(s) (3 baselined)" in out
    # a NEW finding not in the baseline -> rc 1
    rc = lint_main([target, os.path.join(FIXTURES, "threads_bad.py"),
                    "--baseline", str(base)])
    capsys.readouterr()
    assert rc == 1


def test_cli_rules_subset_and_list(capsys):
    rc = lint_main(["--list-rules"])
    out = capsys.readouterr().out
    assert rc == 0
    for name in ("guarded-access", "blocking-under-lock", "trace-hazard",
                 "sync-under-sem", "thread-join", "bare-acquire",
                 "unused-import"):
        assert name in out
    # subset: thread rules only -> guarded_bad.py is clean under them
    rc = lint_main([os.path.join(FIXTURES, "guarded_bad.py"),
                    "--rules", "thread-join,bare-acquire"])
    capsys.readouterr()
    assert rc == 0


# -- the lock-order witness ---------------------------------------------------


def test_witness_detects_cross_thread_cycle():
    """Thread 1 takes a->b, thread 2 takes b->a: the union graph has a
    cycle even though neither thread deadlocked this run."""
    w = LockOrderWitness()
    a = w.wrap(threading.Lock(), "a")
    b = w.wrap(threading.Lock(), "b")

    def t1():
        with a:
            with b:
                pass

    def t2():
        with b:
            with a:
                pass

    th1 = threading.Thread(target=t1)
    th1.start(); th1.join()
    th2 = threading.Thread(target=t2)
    th2.start(); th2.join()
    with pytest.raises(LockOrderViolation, match="cycle"):
        w.check()


def test_witness_detects_rank_violation():
    """Acquiring the store lock while holding the state lock breaks
    the declared inner-first order (state ≺ store ≺ round)."""
    w = LockOrderWitness()
    state = w.wrap(threading.Lock(), "state", "state")
    store = w.wrap(threading.Lock(), "store", "store")
    with state:
        with store:
            pass
    with pytest.raises(LockOrderViolation, match="declared order"):
        w.check()


def test_witness_accepts_declared_nesting_and_equal_rank_rejected():
    w = LockOrderWitness()
    rnd = w.wrap(threading.Lock(), "round:a", "round")
    store = w.wrap(threading.Lock(), "store", "store")
    state = w.wrap(threading.Lock(), "state", "state")
    with rnd:           # outermost
        with store:
            pass
        with state:
            pass
    w.check()  # declared nesting is clean
    rnd2 = w.wrap(threading.Lock(), "round:b", "round")
    with rnd:
        with rnd2:      # two round locks nest: forbidden
            pass
    with pytest.raises(LockOrderViolation, match="rank"):
        w.check()


def test_witness_condition_wait_releases_and_reacquires():
    """threading.Condition built over a witnessed lock keeps the held
    stack honest across wait()'s release/reacquire."""
    w = LockOrderWitness()
    lk = w.wrap(threading.Lock(), "store", "store")
    cv = threading.Condition(lk)
    seen = []

    def waiter():
        with cv:
            cv.wait(timeout=5.0)
            seen.append(len(w._held.stack))  # reacquired -> held again

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.1)
    with cv:
        cv.notify_all()
    t.join(timeout=5.0)
    assert not t.is_alive() and seen == [1]
    w.check()


def test_witness_gate_concurrent_service_clean():
    """The witness gate over a real concurrent multi-tenant run: 3
    tenants' async rounds race on ONE instrumented service; the
    recorded acquisition graph must honor the declared order and be
    acyclic — and it must actually have OBSERVED the cross-layer
    nesting (round -> store, round -> state), or the gate is vacuous."""
    w = LockOrderWitness()
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=10.0,
    )
    instrument_service(svc, w)
    k, n, p, rounds = 3, 6, 128, 3
    tenants = [f"app{i}" for i in range(k)]
    u = RNG.normal(size=(k, rounds, n, p)).astype(np.float32)
    with RoundScheduler(svc) as sched:
        for r in range(rounds):
            def writes(kk, tenant, r=r):
                for i in range(n):
                    store.write(f"c{i}", u[kk, r, i], tenant=tenant)
            wt = [threading.Thread(target=writes, args=(kk, t), daemon=True)
                  for kk, t in enumerate(tenants)]
            for t_ in wt:
                t_.start()
            futs = {t: sched.submit(t, from_store=True, async_round=True,
                                    expected_clients=n)
                    for t in tenants}
            for t_ in wt:
                t_.join()
            for tenant, fut in futs.items():
                fused, rep = fut.result(timeout=60)
                assert rep.n_clients == n
    w.check()
    edges = set(w.edges)
    assert any(a.startswith("round:") and b == "store" for a, b in edges), \
        "witness never saw a store acquisition inside a round lock"
    assert any(a.startswith("round:") and b == "state" for a, b in edges), \
        "witness never saw a state acquisition inside a round lock"


def test_instrument_service_is_idempotent_per_store():
    """Two services sharing one store: the store layer wraps once (a
    double wrap would record store->store self-edges = false cycles)."""
    w = LockOrderWitness()
    store = UpdateStore()
    s1 = AggregationService(fusion="fedavg", store=store)
    s2 = AggregationService(fusion="fedavg", store=store)
    instrument_service(s1, w)
    lock_after_first = store._lock
    instrument_service(s2, w)
    assert store._lock is lock_after_first


# -- shutdown hygiene (satellite: SpoolTailer / IngestQueue) ------------------


def _live_workers(before):
    return [t for t in threading.enumerate()
            if t not in before and t is not threading.current_thread()]


def test_spool_tailer_stop_leaves_no_threads(tmp_path):
    from repro.core.store import SpoolTailer

    before = set(threading.enumerate())
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    tailer = SpoolTailer(store, poll_interval=0.05)
    tailer.start()
    np.save(tmp_path / "ext1.npy", RNG.normal(size=8).astype(np.float32))
    (tmp_path / "ext1.npy.w").write_text("2.0")
    deadline = time.time() + 5
    while "ext1" not in store.client_ids() and time.time() < deadline:
        time.sleep(0.02)
    assert "ext1" in store.client_ids()
    tailer.stop()
    leftover = [t for t in _live_workers(before) if t.is_alive()]
    assert leftover == [], f"threads outlived stop(): {leftover}"
    assert tailer._thread is None  # stop() joined and cleared the worker


def test_ingest_queue_close_leaves_no_threads():
    from repro.serving.ingest import IngestQueue

    before = set(threading.enumerate())
    store = UpdateStore()
    q = IngestQueue(store, maxsize=16)
    futs = [
        q.submit(f"c{i}", RNG.normal(size=16).astype(np.float32),
                 1.0, tenant="app")
        for i in range(8)
    ]
    q.close()
    for f in futs:
        f.result(timeout=5)
    assert q.stats()["committed"] == 8
    leftover = [t for t in _live_workers(before) if t.is_alive()]
    assert leftover == [], f"threads outlived close(): {leftover}"


def test_fair_scheduler_shutdown_joins_round_workers():
    """The fix the thread-join rule forced: shutdown() now joins the
    per-round worker threads, not just the admission loop."""
    from repro.core.service import FairRoundScheduler

    before = set(threading.enumerate())
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=5.0,
    )
    n, p = 4, 64
    sched = FairRoundScheduler(svc, max_running=2)
    futs = []
    for tenant in ("a", "b", "c"):
        for i in range(n):
            store.write(f"c{i}", RNG.normal(size=p).astype(np.float32),
                        tenant=tenant)
        futs.append(sched.submit(tenant, from_store=True,
                                 expected_clients=n))
    for f in futs:
        f.result(timeout=60)
    sched.shutdown()
    leftover = [t for t in _live_workers(before) if t.is_alive()]
    assert leftover == [], f"threads outlived shutdown(): {leftover}"


# -- regression tests for the true positives the lint surfaced ----------------


def test_ext_seen_grace_tracking_is_lock_consistent(tmp_path):
    """ingest_external's sidecar-grace map (_ext_seen) is now touched
    under the store lock: concurrent passes racing a writer must agree
    on ONE first-seen time (dedup) and still register exactly once
    after the grace window."""
    clock = {"t": 0.0}
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path),
                        sidecar_grace_seconds=10.0,
                        wall_clock=lambda: clock["t"])
    np.save(tmp_path / "extc.npy", RNG.normal(size=8).astype(np.float32))
    # no .w sidecar: every pass defers within the grace window
    errs = []

    def pass_once():
        try:
            store.ingest_external()
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=pass_once) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errs == []
    assert store.client_ids() == []           # still in grace
    with store._lock:
        assert list(store._ext_seen) == [("default", "extc")]
    clock["t"] = 11.0                          # grace expired
    assert store.ingest_external() == ["extc"]
    assert store.client_ids() == ["extc"]
    with store._lock:
        assert store._ext_seen == {}           # popped under the lock


def test_service_carry_and_ages_consistent_under_concurrent_tenants():
    """_carry/_stale_ages are now written under _state_lock: two
    tenants' discounted async rounds racing on one service must yield
    exactly what each tenant gets running ALONE (a cross-tenant
    lost-update on the shared maps would corrupt the γ-carry)."""
    k, n, p, rounds = 2, 4, 64, 3
    tenants = ["ta", "tb"]
    u = RNG.normal(size=(k, rounds, n, p)).astype(np.float32)

    def make_service(store):
        return AggregationService(
            fusion="fedavg", local_strategy="jnp", store=store,
            threshold_frac=1.0, monitor_timeout=10.0,
            staleness_discount=0.5,
        )

    store = UpdateStore()
    svc = make_service(store)
    got = {t: [] for t in tenants}
    with RoundScheduler(svc) as sched:
        for r in range(rounds):
            for kk, tenant in enumerate(tenants):
                for i in range(n):
                    store.write(f"c{i}", u[kk, r, i], tenant=tenant)
            futs = {t: sched.submit(t, from_store=True, async_round=True,
                                    expected_clients=n)
                    for t in tenants}
            for tenant, fut in futs.items():
                fused, rep = fut.result(timeout=60)
                assert rep.n_clients == n
                got[tenant].append(np.asarray(fused))
    with svc._state_lock:
        assert set(svc._carry) == set(tenants)
        assert set(svc._stale_ages) == set(tenants)
    # reference: each tenant alone on a private service, sequentially
    for kk, tenant in enumerate(tenants):
        ref_store = UpdateStore()
        ref_svc = make_service(ref_store)
        for r in range(rounds):
            for i in range(n):
                ref_store.write(f"c{i}", u[kk, r, i], tenant=tenant)
            fused, _ = ref_svc.aggregate(
                tenant=tenant, from_store=True, async_round=True,
                expected_clients=n,
            )
            np.testing.assert_allclose(
                got[tenant][r], np.asarray(fused), rtol=1e-5, atol=1e-6,
                err_msg=f"{tenant} round {r} diverged from solo run",
            )


def test_round_report_unchanged_by_instrumentation():
    """Instrumented and raw services fuse identically (the witness is
    observe-only)."""
    u = RNG.normal(size=(5, 96)).astype(np.float32)
    outs = []
    for instrument in (False, True):
        store = UpdateStore()
        svc = AggregationService(fusion="fedavg", local_strategy="jnp",
                                 store=store, threshold_frac=1.0,
                                 monitor_timeout=5.0)
        if instrument:
            instrument_service(svc, LockOrderWitness())
        for i in range(5):
            store.write(f"c{i}", u[i])
        fused, rep = svc.aggregate(from_store=True, expected_clients=5)
        outs.append(np.asarray(fused))
    assert np.array_equal(outs[0], outs[1])
