"""Algorithm-1 semantics: classification, planning, monitor, seamless
transition, store round-trip."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregationService,
    Monitor,
    Planner,
    UpdateStore,
    Workload,
    WorkloadClass,
    classify,
    get_fusion,
    max_clients_single_node,
)
from repro.utils.mem import TPU_V5E
from repro.utils.pytree import tree_to_flat_vector

RNG = np.random.default_rng(5)


# -- workload classification ---------------------------------------------------


def test_classify_thresholds():
    assert classify(Workload(update_bytes=1 << 20, n_clients=4)) is \
        WorkloadClass.VMEM_RESIDENT
    assert classify(Workload(update_bytes=10 << 20, n_clients=100)) is \
        WorkloadClass.HBM_LOCAL
    assert classify(Workload(update_bytes=100 << 20, n_clients=1000)) is \
        WorkloadClass.DISTRIBUTED


def test_max_clients_matches_paper_shape():
    """Paper Fig. 2: supportable clients fall as model size grows."""
    sizes = [int(mb * 1e6) for mb in (4.6, 73, 179, 478, 956)]
    caps = [max_clients_single_node(s) for s in sizes]
    assert all(a > b for a, b in zip(caps, caps[1:]))


# -- planner -------------------------------------------------------------------


def test_planner_routes_small_local_large_distributed():
    p = Planner(n_devices=256)
    f = get_fusion("fedavg")
    small = p.plan(Workload(update_bytes=5 << 20, n_clients=10), f)
    assert small.engine == "local"
    huge = p.plan(Workload(update_bytes=1 << 30, n_clients=10_000), f)
    assert huge.engine == "distributed"


def test_planner_infeasible_raises():
    p = Planner(n_devices=1)
    f = get_fusion("krum")  # not streamable
    with pytest.raises(MemoryError):
        p.plan(Workload(update_bytes=1 << 30, n_clients=10_000), f)


def test_planner_hierarchical_on_pods():
    p = Planner(n_devices=512, n_pods=2)
    f = get_fusion("fedavg")
    plan = p.plan(Workload(update_bytes=1 << 30, n_clients=10_000), f)
    assert plan.engine == "hierarchical"


# -- monitor -------------------------------------------------------------------


def test_monitor_threshold_and_timeout():
    store = UpdateStore()
    clock = {"t": 0.0}
    mon = Monitor(store, threshold=3, timeout=1.0, poll_interval=0.1,
                  clock=lambda: clock["t"],
                  sleep=lambda s: clock.__setitem__("t", clock["t"] + s))
    store.write("a", np.zeros(4, np.float32))
    store.write("b", np.zeros(4, np.float32))
    res = mon.wait()  # only 2 of 3 -> timeout path
    assert not res.ready and res.count == 2 and res.waited >= 1.0

    store.write("c", np.zeros(4, np.float32))
    clock["t"] = 0.0
    res = mon.wait()
    assert res.ready and res.count == 3


# -- store ---------------------------------------------------------------------


def test_store_roundtrip_and_partition(tmp_path):
    for backend, kw in (("memory", {}),
                        ("disk", {"spool_dir": str(tmp_path)})):
        store = UpdateStore(backend=backend, **kw)
        tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                "b": np.ones(2, np.float32)}
        lat = store.write("c1", tree, weight=3.0)
        assert lat > 0
        store.write("c2", np.zeros(8, np.float32), weight=1.0)
        assert store.count() == 2
        u, w = store.read("c1")
        assert w == 3.0 and u.shape == (8,)
        parts = store.partition(2)
        assert sorted(sum(parts, [])) == ["c1", "c2"]
        stacked, ws = store.read_stacked()
        assert stacked.shape == (2, 8) and ws.tolist() == [3.0, 1.0]
        store.clear()
        assert store.count() == 0


def test_store_write_latency_model():
    """Fig. 12's average-write-time: scales with bytes, replication."""
    s1 = UpdateStore(replication=1)
    s2 = UpdateStore(replication=2)
    u = np.zeros(1_000_000, np.float32)
    assert s2.write("a", u) == pytest.approx(2 * s1.write("a", u))


# -- service (Algorithm 1 end to end) ------------------------------------------


def _mk_updates(n=6, shape=(50,)):
    tmpl = {"w": jnp.zeros(shape)}
    ups = [{"w": jnp.asarray(RNG.normal(size=shape), jnp.float32)}
           for _ in range(n)]
    ws = list(RNG.uniform(1, 5, n))
    return tmpl, ups, ws


def test_service_small_path_exact():
    tmpl, ups, ws = _mk_updates()
    svc = AggregationService(fusion="fedavg", local_strategy="jnp")
    fused, rep = svc.aggregate(updates=ups, weights=ws, template=tmpl)
    manual = sum(
        w * tree_to_flat_vector(u) for u, w in zip(ups, ws)
    ) / (sum(ws) + 1e-6)
    np.testing.assert_allclose(
        tree_to_flat_vector(fused), manual, rtol=1e-5, atol=1e-6
    )
    assert rep.plan.engine == "local"
    assert not rep.route_next_to_store


def test_service_store_path_with_monitor():
    tmpl, ups, ws = _mk_updates()
    store = UpdateStore()
    svc = AggregationService(fusion="iteravg", store=store,
                             monitor_timeout=0.5, local_strategy="jnp")
    for i, u in enumerate(ups):
        store.write(f"c{i}", u)
    fused, rep = svc.aggregate(from_store=True, template=tmpl,
                               expected_clients=len(ups))
    assert rep.monitor is not None and rep.monitor.ready
    manual = sum(tree_to_flat_vector(u) for u in ups) / len(ups)
    np.testing.assert_allclose(
        tree_to_flat_vector(fused), manual, rtol=1e-4, atol=1e-5
    )


def test_service_seamless_transition_flag():
    """When the projected next-round load exceeds one chip, the service
    tells clients to route updates to the store (paper §III-D3)."""
    tmpl, ups, ws = _mk_updates(n=2, shape=(1 << 20,))  # 4 MiB updates
    svc = AggregationService(fusion="fedavg", local_strategy="jnp")
    _, rep = svc.aggregate(
        updates=ups, weights=ws, template=tmpl,
        expected_clients=100_000,  # next round: 100k clients x 4 MiB
    )
    assert rep.route_next_to_store


def test_service_memory_capped_still_correct():
    tmpl, ups, ws = _mk_updates(n=10, shape=(1000,))
    svc = AggregationService(fusion="fedavg", local_strategy="jnp",
                             memory_cap_bytes=3 * 4000)
    fused, rep = svc.aggregate(updates=ups, weights=ws, template=tmpl)
    manual = sum(
        w * tree_to_flat_vector(u) for u, w in zip(ups, ws)
    ) / (sum(ws) + 1e-6)
    np.testing.assert_allclose(
        tree_to_flat_vector(fused), manual, rtol=1e-5, atol=1e-6
    )


# -- planner property tests ----------------------------------------------------

from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(wbytes=st.integers(1 << 10, 1 << 30), n=st.integers(1, 10_000))
def test_planner_always_has_a_reducible_plan(wbytes, n):
    p = Planner(n_devices=256)
    plan = p.plan(Workload(update_bytes=wbytes, n_clients=n),
                  get_fusion("fedavg"))
    assert plan.feasible and plan.est_seconds > 0


@settings(max_examples=30, deadline=None)
@given(wbytes=st.integers(1 << 16, 1 << 26), n1=st.integers(1, 5_000),
       n2=st.integers(1, 5_000))
def test_planner_cost_monotone_in_clients(wbytes, n1, n2):
    """More clients never get cheaper for the same engine."""
    if n1 > n2:
        n1, n2 = n2, n1
    p = Planner(n_devices=256)
    f = get_fusion("fedavg")
    for engine in ("local", "distributed"):
        c1 = [x for x in p.candidate_plans(
            Workload(update_bytes=wbytes, n_clients=n1), f)
            if x.engine == engine]
        c2 = [x for x in p.candidate_plans(
            Workload(update_bytes=wbytes, n_clients=n2), f)
            if x.engine == engine]
        if c1 and c2:
            assert c2[0].est_seconds >= c1[0].est_seconds - 1e-12


@settings(max_examples=30, deadline=None)
@given(wbytes=st.integers(1 << 10, 1 << 28), n=st.integers(1, 100_000))
def test_classification_monotone(wbytes, n):
    """Doubling the load never moves the class toward 'smaller'."""
    order = [WorkloadClass.VMEM_RESIDENT, WorkloadClass.HBM_LOCAL,
             WorkloadClass.DISTRIBUTED]
    a = classify(Workload(update_bytes=wbytes, n_clients=n))
    b = classify(Workload(update_bytes=wbytes, n_clients=2 * n))
    assert order.index(b) >= order.index(a)
