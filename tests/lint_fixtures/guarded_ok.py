# Lint fixture: guarded-access true negatives. Never imported.
import threading


class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._index = {}  # guarded-by: _lock
        self._index["seed"] = 1              # ok: __init__ is unshared

    def read(self, key):
        with self._lock:
            return self._index.get(key)      # ok: lock held

    def read_via_condition(self, key):
        with self._cv:
            return self._index.get(key)      # ok: the condition IS the lock

    def _drop_locked(self, key):
        """Drop one entry. Caller holds ``self._lock``."""
        self._index.pop(key, None)           # ok: declared caller-held

    def _scan(self):  # lint: holds=_lock
        return list(self._index)             # ok: def-line holds comment

    def unguarded_attr(self):
        return id(self)                      # ok: not a guarded attribute
