# Lint fixture: trace-hazard true positives. Never imported.
import random
import time

import jax


def keyed_on_time(cache, builder):
    return cache.get(("step", time.time()), builder)     # BAD: cold every call


def keyed_on_random(cache, builder):
    return cache.get_jitted(("r", random.random()), builder)   # BAD


def unhashable_key(cache, builder, shapes):
    return cache.get(("step", [s for s in shapes]), builder)   # BAD: list key


@jax.jit
def traced_with_clock(x):
    return x * time.time()                               # BAD: baked constant


def kernel_with_random(x_ref, o_ref):
    o_ref[...] = x_ref[...] * random.random()            # BAD once traced


def build(x):
    import jax.experimental.pallas as pl
    return pl.pallas_call(kernel_with_random,
                          out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))
