# Lint fixture: trace-hazard true negatives. Never imported.
import time

import jax
import jax.numpy as jnp


def keyed_on_shape(cache, builder, n, p):
    return cache.get(("step", int(n), int(p)), builder)  # ok: static key


def tuple_key(cache, builder, shapes):
    return cache.get(("step", tuple(shapes)), builder)   # ok: hashable


@jax.jit
def pure_step(x, w):
    return jnp.einsum("np,n->p", x, w)                   # ok: pure

def timed_host_side(x):
    t0 = time.perf_counter()                             # ok: not traced
    y = pure_step(x, x[:, 0])
    return y, time.perf_counter() - t0


def plain_dict_get(d, key):
    return d.get(key)                                    # ok: not a cache call
