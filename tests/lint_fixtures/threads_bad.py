# Lint fixture: thread-hygiene true positives. Never imported.
import threading


class Runner:
    def __init__(self):
        self._lock = threading.Lock()

    def spawn_and_forget(self):
        t = threading.Thread(target=print)   # BAD: never joined
        t.start()
        return None

    def forget_nondaemon(self):
        self._t = threading.Thread(target=print, daemon=False)  # BAD
        self._t.start()

    def manual_acquire(self):
        self._lock.acquire()                 # BAD: bare acquire
        try:
            return 1
        finally:
            self._lock.release()
