# Lint fixture: blocking-under-lock true negatives. Never imported.
import threading

import numpy as np


class Spool:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)

    def load_outside_lock(self, path):
        data = np.load(path)                 # ok: no lock held
        with self._lock:
            return data.sum()

    def wait_on_condition(self):
        with self._cv:
            self._cv.wait(timeout=0.5)       # ok: wait RELEASES the lock

    def io_in_deferred_worker(self, path):
        with self._lock:
            def worker():
                return np.load(path)         # ok: runs after release
            return worker
