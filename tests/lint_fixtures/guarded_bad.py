# Lint fixture: guarded-access true positives. Never imported.
import threading


class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._index = {}  # guarded-by: _lock
        self._bytes = 0  # guarded-by: _lock

    def read_unlocked(self, key):
        return self._index.get(key)          # BAD: no lock held

    def write_after_release(self, key, val):
        with self._lock:
            self._index[key] = val           # ok
        self._bytes += 1                     # BAD: lock already released

    def nested_worker(self):
        with self._lock:
            def worker():
                return dict(self._index)     # BAD: runs on another thread
            return worker
