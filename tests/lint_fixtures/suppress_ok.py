# Lint fixture: well-formed suppressions silence findings. Never imported.
import threading
import time


class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._index = {}  # guarded-by: _lock

    def fast_probe(self):
        return bool(self._index)  # lint: disable=guarded-access -- emptiness probe; worst case one stale batch

    def timed_hold(self):  # lint: disable=blocking-under-lock -- test fixture exercising function-level suppression
        with self._lock:
            time.sleep(0.001)
            time.sleep(0.001)
