# Lint fixture: malformed suppressions are themselves findings.
import threading


class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._index = {}  # guarded-by: _lock

    def missing_reason(self):
        return bool(self._index)  # lint: disable=guarded-access

    def unknown_rule(self):
        with self._lock:
            return len(self._index)  # lint: disable=no-such-rule -- reason present but rule unknown

    def not_parseable(self):
        return 0  # lint: disable=
