# Lint fixture: unused-import positives + negatives. Never imported.
from __future__ import annotations          # ok: __future__ exempt

import json                                  # BAD: never referenced
import os
from typing import Dict, Optional            # Optional BAD, Dict ok

__all__ = ["exported"]

exported = os.getcwd()


def typed(d: Dict[str, int]) -> int:         # Dict used in annotation
    return len(d)
