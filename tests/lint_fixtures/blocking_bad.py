# Lint fixture: blocking-under-lock true positives. Never imported.
import os
import threading
import time

import numpy as np


class Spool:
    def __init__(self):
        self._lock = threading.Lock()

    def load_under_lock(self, path):
        with self._lock:
            return np.load(path)             # BAD: I/O while holding lock

    def sleep_under_lock(self):
        with self._lock:
            time.sleep(0.1)                  # BAD

    def replace_under_lock(self, a, b):
        with self._lock:
            os.replace(a, b)                 # BAD

    def open_under_lock(self, path):
        with self._lock:
            with open(path) as f:            # BAD
                return f.read()
