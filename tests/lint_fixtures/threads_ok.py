# Lint fixture: thread-hygiene true negatives. Never imported.
import threading


class Runner:
    def __init__(self):
        self._lock = threading.Lock()
        self._t = threading.Thread(target=print, daemon=True)

    def start(self):
        self._t.start()

    def stop(self):
        self._t.join(timeout=5.0)            # ok: joined on shutdown

    def pooled(self, n):
        pool = []
        for _ in range(n):
            pool.append(threading.Thread(target=print, daemon=True))
        for t in pool:
            t.start()
        for t in pool:
            t.join()                         # ok: pool joined
        with self._lock:                     # ok: with, not bare acquire
            return len(pool)
