# Lint fixture: sync-under-sem true positive + negative. Never imported.
import threading

import jax


class Engine:
    def __init__(self):
        self.device_sem = threading.BoundedSemaphore(1)

    def fold_sync_inside(self, step, block):
        with self.device_sem:
            out = step(block)
            jax.block_until_ready(out)       # BAD (unannotated sync)
            return out

    def scalar_inside(self, step, block):
        with self.device_sem:
            return step(block).item()        # BAD

    def fold_sync_outside(self, step, block):
        with self.device_sem:
            out = step(block)
        jax.block_until_ready(out)           # ok: permit already released
        return out
