"""Tenant-partitioned UpdateStore and the multi-tenant service path:

  * store partitioning — per-tenant count/client_ids/meta/read filters,
    the same client id under two tenants staying independent, per-tenant
    iter_chunks/iter_arrivals/read_stacked/remove/clear;
  * no-steal — interleaved open rounds on ONE store never fold another
    tenant's updates (scripted-clock exactness + genuinely concurrent
    threads), and shared-store rounds produce the same report/result as
    isolated per-tenant stores (the ISSUE-4 equivalence bar);
  * disk spool layout — default tenant at the root (restart-compatible),
    other tenants in subdirectories; restart recovery; external-blob
    tenant routing by subdirectory and by ``.tenant`` sidecar;
    SpoolTailer discovery of tenant subdirectories;
  * adaptive follow-ons — cross-tenant prior for cold-start tenants,
    drift detection widening the learned deadline, and controller
    checkpoint/restore via ``repro.checkpoint`` (a restarted service
    resumes learned, not cold).
"""
import bisect
import os
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import load_controller_state, save_controller_state
from repro.core import (
    AdaptiveController,
    AggregationService,
    ArrivalModel,
    SpoolTailer,
    UpdateStore,
)

RNG = np.random.default_rng(123)


class ScriptedClock:
    def __init__(self):
        self.t = 0.0
        self._events = []

    def at(self, t, fn):
        bisect.insort(self._events, (t, id(fn), fn))

    def clock(self):
        return self.t

    def sleep(self, seconds):
        self.t += seconds
        while self._events and self._events[0][0] <= self.t:
            _, _, fn = self._events.pop(0)
            fn()


def _mk(n, p=32):
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
    return u, w


def _fedavg(u, w):
    return np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)


def _service(store, clk=None, **kw):
    kw.setdefault("threshold_frac", 1.0)
    kw.setdefault("monitor_timeout", 30.0)
    extra = {}
    if clk is not None:
        extra = {"clock": clk.clock, "sleep": clk.sleep}
    return AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store, **extra, **kw
    )


# -- store partitioning --------------------------------------------------------


def test_store_partitions_by_tenant():
    store = UpdateStore()
    store.write("c0", np.ones(4, np.float32), weight=2.0, tenant="A")
    store.write("c1", np.full(4, 2.0, np.float32), tenant="A")
    store.write("c0", np.full(4, 7.0, np.float32), weight=3.0, tenant="B")
    store.write("u0", np.zeros(4, np.float32))   # untagged -> default
    assert store.count() == 4                    # whole-spool view
    assert store.count("A") == 2
    assert store.count("B") == 1
    assert store.count("default") == 1
    assert store.count("nope") == 0
    assert store.client_ids("A") == ["c0", "c1"]
    assert store.client_ids("B") == ["c0"]
    assert store.tenants() == ["A", "B", "default"]
    # the same client id under two tenants: independent updates
    ua, wa = store.read("c0", tenant="A")
    ub, wb = store.read("c0", tenant="B")
    assert wa == 2.0 and wb == 3.0
    np.testing.assert_array_equal(np.asarray(ua), np.ones(4, np.float32))
    np.testing.assert_array_equal(np.asarray(ub),
                                  np.full(4, 7.0, np.float32))
    n, p, _ = store.meta("A")
    assert (n, p) == (2, 4)
    with pytest.raises(LookupError):
        store.meta("nope")


def test_store_per_tenant_streams_and_consume():
    u, w = _mk(6, 8)
    store = UpdateStore()
    for i in range(3):
        store.write(f"c{i}", u[i], weight=float(w[i]), tenant="A")
    for i in range(3, 6):
        store.write(f"c{i}", u[i], weight=float(w[i]), tenant="B")
    stacked, ws = store.read_stacked(tenant="A")
    np.testing.assert_array_equal(stacked, u[:3])
    np.testing.assert_array_equal(ws, w[:3])
    blocks = list(store.iter_chunks(2, tenant="B"))
    got = np.concatenate([b for b, _ in blocks])
    np.testing.assert_array_equal(got, u[3:])
    # arrival timestamps filter too
    assert set(store.arrival_times("A")) == {"c0", "c1", "c2"}
    # consume is tenant-scoped: removing A's ids never touches B's
    store.remove(["c0", "c1", "c2"], tenant="A")
    assert store.count("A") == 0
    assert store.count("B") == 3
    store.clear(tenant="B")
    assert store.count() == 0


def test_iter_arrivals_filters_tenant():
    """An open arrival stream for tenant A never yields B's concurrent
    writes — the property that makes interleaved open rounds safe."""
    u, w = _mk(6, 8)
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    for i in range(2):
        store.write(f"a{i}", u[i], weight=float(w[i]), tenant="A")
    # B's updates land WHILE A's stream is open
    clk.at(0.1, lambda: store.write("b0", u[3], tenant="B"))
    clk.at(0.2, lambda: store.write("a2", u[2], weight=float(w[2]),
                                    tenant="A"))
    got = list(store.iter_arrivals(
        2, lambda count, waited: count >= 3 or waited > 5.0,
        clock=clk.clock, sleep=clk.sleep, tenant="A",
    ))
    ids = [cid for _, _, batch in got for cid in batch]
    assert ids == ["a0", "a1", "a2"]     # b0 never entered the stream
    assert store.count("B") == 1


# -- no-steal / shared-vs-isolated equivalence ---------------------------------


def test_interleaved_rounds_do_not_steal(tmp_path):
    """Scripted-clock exactness: A's and B's writes interleave in one
    store; A's async round folds exactly A's fleet, leaves B's
    partition intact, and B's round then folds exactly B's."""
    na, nb, p = 4, 3, 16
    ua, wa = _mk(na, p)
    ub, wb = _mk(nb, p)
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    svc = _service(store, clk)

    for i in range(na):
        clk.at(0.1 * (i + 1),
               lambda i=i: store.write(f"c{i}", ua[i],
                                       weight=float(wa[i]), tenant="A"))
    for i in range(nb):   # same client ids, interleaved timing
        clk.at(0.05 + 0.1 * (i + 1),
               lambda i=i: store.write(f"c{i}", ub[i],
                                       weight=float(wb[i]), tenant="B"))

    fused_a, rep_a = svc.aggregate(from_store=True, expected_clients=na,
                                   async_round=True, tenant="A")
    assert rep_a.n_clients == na and rep_a.tenant == "A"
    np.testing.assert_allclose(np.asarray(fused_a), _fedavg(ua, wa),
                               rtol=1e-4, atol=1e-5)
    # A's consume left B's partition whole
    assert store.count("A") == 0
    assert store.count("B") == nb
    fused_b, rep_b = svc.aggregate(from_store=True, expected_clients=nb,
                                   async_round=True, tenant="B")
    assert rep_b.n_clients == nb
    np.testing.assert_allclose(np.asarray(fused_b), _fedavg(ub, wb),
                               rtol=1e-4, atol=1e-5)


def test_shared_store_matches_isolated_stores():
    """The ISSUE-4 equivalence bar: two tenants with interleaved open
    rounds on ONE store produce the same RoundReport substance
    (included count, ready, result) as the same tenants on isolated
    stores — here with genuinely concurrent rounds (one service per
    tenant, one shared store, real threads)."""
    n, p = 6, 24
    u = {t: _mk(n, p) for t in ("A", "B")}

    def run_shared():
        store = UpdateStore()
        out = {}

        def one_round(tenant):
            svc = _service(store, poll_interval=0.005)
            for i in range(n):
                time.sleep(0.02)
                store.write(f"c{i}", u[tenant][0][i],
                            weight=float(u[tenant][1][i]), tenant=tenant)
            out[tenant] = svc.aggregate(
                from_store=True, expected_clients=n, async_round=True,
                tenant=tenant,
            )

        threads = [
            threading.Thread(target=one_round, args=(t,))
            for t in ("A", "B")
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return out

    def run_isolated():
        out = {}
        for tenant in ("A", "B"):
            store = UpdateStore()
            svc = _service(store, poll_interval=0.005)
            for i in range(n):
                store.write(f"c{i}", u[tenant][0][i],
                            weight=float(u[tenant][1][i]), tenant=tenant)
            out[tenant] = svc.aggregate(
                from_store=True, expected_clients=n, async_round=True,
                tenant=tenant,
            )
        return out

    shared, isolated = run_shared(), run_isolated()
    for tenant in ("A", "B"):
        fs, rs = shared[tenant]
        fi, ri = isolated[tenant]
        assert rs.n_clients == ri.n_clients == n
        assert rs.monitor.ready and ri.monitor.ready
        assert rs.tenant == ri.tenant == tenant
        np.testing.assert_allclose(np.asarray(fs), np.asarray(fi),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(fs), _fedavg(*u[tenant]), rtol=1e-4, atol=1e-5,
        )


# -- disk spool layout / routing -----------------------------------------------


def test_disk_spool_tenant_layout_and_recovery(tmp_path):
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.write("c0", np.ones(4, np.float32), weight=2.0)
    store.write("c0", np.full(4, 3.0, np.float32), weight=1.5,
                tenant="appX")
    # default at the root, tenant in its subdirectory
    assert os.path.exists(tmp_path / "c0.npy")
    assert os.path.exists(tmp_path / "appX" / "c0.npy")
    # a new incarnation recovers both partitions
    store2 = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    assert store2.count("default") == 1
    assert store2.count("appX") == 1
    upd, weight = store2.read("c0", tenant="appX")
    assert weight == 1.5
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.full(4, 3.0, np.float32))
    # per-tenant clear unlinks only that partition's blobs
    store2.clear(tenant="appX")
    assert not os.path.exists(tmp_path / "appX" / "c0.npy")
    assert os.path.exists(tmp_path / "c0.npy")


def test_ingest_external_tenant_subdir(tmp_path):
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path),
                        sidecar_grace_seconds=0.05)
    os.makedirs(tmp_path / "appY")
    np.save(tmp_path / "appY" / "e0.npy", np.full(8, 5.0, np.float32))
    with open(tmp_path / "appY" / "e0.npy.w", "w") as f:
        f.write("4.0")
    assert store.ingest_external() == ["e0"]
    assert store.count("appY") == 1
    upd, weight = store.read("e0", tenant="appY")
    assert weight == 4.0
    assert "e0" in store.arrival_times("appY")
    # idempotent
    assert store.ingest_external() == []


def test_ingest_external_tenant_sidecar_routes_and_moves(tmp_path):
    """A root-level blob with a ``.tenant`` sidecar registers under the
    named tenant and its files move into the tenant subdirectory."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    np.save(tmp_path / "e1.npy", np.full(4, 2.0, np.float32))
    with open(tmp_path / "e1.npy.tenant", "w") as f:
        f.write("appZ")
    with open(tmp_path / "e1.npy.w", "w") as f:
        f.write("2.5")
    assert store.ingest_external() == ["e1"]
    assert store.count("appZ") == 1
    assert store.count("default") == 0
    assert os.path.exists(tmp_path / "appZ" / "e1.npy")
    assert not os.path.exists(tmp_path / "e1.npy")
    assert not os.path.exists(tmp_path / "e1.npy.tenant")
    _, weight = store.read("e1", tenant="appZ")
    assert weight == 2.5


def test_tenant_sidecar_waits_for_weight_sidecar(tmp_path):
    """The review race: ``.tenant`` lands but ``.w`` is still in flight
    — the move/registration must defer so the weight is not frozen at
    the 1.0 default with an orphaned ``.w`` at the root."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    np.save(tmp_path / "e2.npy", np.ones(4, np.float32))
    with open(tmp_path / "e2.npy.tenant", "w") as f:
        f.write("appW")
    assert store.ingest_external() == []     # within grace: no move yet
    assert os.path.exists(tmp_path / "e2.npy")
    with open(tmp_path / "e2.npy.w", "w") as f:
        f.write("9.0")
    assert store.ingest_external() == ["e2"]
    _, weight = store.read("e2", tenant="appW")
    assert weight == 9.0
    assert not os.path.exists(tmp_path / "e2.npy.w")   # moved, not orphaned


def test_late_tenant_sidecar_cannot_steal_registered_bytes(tmp_path):
    """Once a blob registers, its bytes belong to that entry: a
    ``.tenant`` sidecar arriving late (out of the documented blob ->
    .tenant -> .w order) is removed, never honored — a stray sidecar
    alone must not move a live registration's payload cross-tenant."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path),
                        sidecar_grace_seconds=0.01)
    np.save(tmp_path / "e3.npy", np.full(4, 6.0, np.float32))
    with open(tmp_path / "e3.npy.w", "w") as f:
        f.write("2.0")
    assert store.ingest_external() == ["e3"]
    assert store.count("default") == 1
    with open(tmp_path / "e3.npy.tenant", "w") as f:   # late sidecar
        f.write("appV")
    assert store.ingest_external() == []
    assert store.count("default") == 1 and store.count("appV") == 0
    assert not os.path.exists(tmp_path / "e3.npy.tenant")  # cleaned up
    upd, weight = store.read("e3")          # still the default's, intact
    assert weight == 2.0
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.full(4, 6.0, np.float32))


def test_stray_sidecar_on_api_written_entry_is_ignored(tmp_path):
    """A stray ``.tenant`` sidecar dropped next to a ``write()``-
    registered default blob (no new blob bytes) must not reroute the
    client's live update."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.write("w7", np.full(4, 5.0, np.float32), weight=3.0)
    with open(tmp_path / "w7.npy.tenant", "w") as f:
        f.write("appR")
    assert store.ingest_external() == []
    assert store.count("default") == 1 and store.count("appR") == 0
    assert not os.path.exists(tmp_path / "w7.npy.tenant")
    upd, weight = store.read("w7")
    assert weight == 3.0
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.full(4, 5.0, np.float32))


def test_resubmission_after_restart_still_reroutes(tmp_path):
    """Root-blob ownership survives restarts: a genuine byte-replacing
    re-submission landing AFTER a new store incarnation recovered the
    entry must still evict + re-route (recovery records blob mtimes)."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.write("c8", np.ones(4, np.float32), weight=2.0)
    store2 = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    assert store2.count("default") == 1
    np.save(tmp_path / "c8.npy", np.full(4, 7.0, np.float32))  # new bytes
    with open(tmp_path / "c8.npy.tenant", "w") as f:
        f.write("appQ")
    with open(tmp_path / "c8.npy.w", "w") as f:
        f.write("5.0")
    assert store2.ingest_external() == ["c8"]
    assert store2.count("default") == 0 and store2.count("appQ") == 1
    _, weight = store2.read("c8", tenant="appQ")
    assert weight == 5.0


def test_empty_rounds_do_not_pollute_prior():
    """One dead tenant's timed-out rounds must not drag the
    cross-tenant prior's attainable fraction (and with it every
    cold-start tenant's borrowed threshold) toward zero."""
    c = AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                           timeout=30.0)
    for _ in range(3):
        c.observe_round("healthy", np.linspace(0.1, 1.0, 10), 10)
        c.observe_round("dead", [], 10)     # fleet down: empty rounds
    assert c.model("dead").attainable == pytest.approx(0.0, abs=0.2)
    assert c.prior_model().attainable == pytest.approx(1.0)
    pol = c.policy("fresh", 10)
    assert pol.source == "prior"
    assert pol.threshold == 10              # full fleet, not threshold=1


def test_recover_skips_npy_named_tenant_directories(tmp_path):
    """A tenant whose name ends in .npy creates spool_dir/<name>/ — a
    restart must not register the DIRECTORY as a phantom default blob."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.write("c0", np.ones(4, np.float32), weight=2.0, tenant="x.npy")
    store2 = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    assert store2.count("default") == 0      # no phantom 'x' blob
    assert store2.count("x.npy") == 1
    n, p, _ = store2.meta("x.npy")           # reads resolve fine
    assert (n, p) == (1, 4)


def test_resubmitted_external_blob_does_not_clobber_registration(tmp_path):
    """A root re-submission of an already-registered (tenant, cid) must
    not move/overwrite the registered blob out from under the index and
    its version guard — it waits at the root until the registered one
    is consumed."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))

    def submit(value, weight):
        np.save(tmp_path / "e4.npy", np.full(4, value, np.float32))
        with open(tmp_path / "e4.npy.tenant", "w") as f:
            f.write("appU")
        with open(tmp_path / "e4.npy.w", "w") as f:
            f.write(repr(weight))

    submit(1.0, 2.0)
    assert store.ingest_external() == ["e4"]
    submit(9.0, 5.0)                       # re-submission, still at root
    assert store.ingest_external() == []   # registered entry wins
    upd, weight = store.read("e4", tenant="appU")
    assert weight == 2.0                   # NOT clobbered by the re-submit
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.ones(4, np.float32))
    # once the registered update is consumed, the re-submission lands
    store.remove(["e4"], tenant="appU")
    assert store.ingest_external() == ["e4"]
    upd, weight = store.read("e4", tenant="appU")
    assert weight == 5.0
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.full(4, 9.0, np.float32))


def test_external_default_subdir_routes_to_root_partition(tmp_path):
    """A literal ``default/`` subdirectory registers into the root
    partition (files moved there) instead of being silently skipped."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    os.makedirs(tmp_path / "default")
    np.save(tmp_path / "default" / "d0.npy", np.full(4, 3.0, np.float32))
    with open(tmp_path / "default" / "d0.npy.w", "w") as f:
        f.write("1.5")
    assert store.ingest_external() == ["d0"]
    assert store.count("default") == 1
    assert os.path.exists(tmp_path / "d0.npy")
    assert not os.path.exists(tmp_path / "default" / "d0.npy")
    upd, weight = store.read("d0")
    assert weight == 1.5
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.full(4, 3.0, np.float32))


def test_invalid_tenant_names_rejected(tmp_path):
    """Tenant names become spool subdirectories: path separators and
    traversal are rejected at write, and a poisoned ``.tenant`` sidecar
    never routes (no files escape the spool)."""
    store = UpdateStore()
    for bad in ("", "a/b", "..", ".", "a\\b", "../../tmp/evil"):
        with pytest.raises(ValueError):
            store.write("c0", np.ones(4, np.float32), tenant=bad)
    disk = UpdateStore(backend="disk", spool_dir=str(tmp_path / "spool"),
                       sidecar_grace_seconds=0.0)
    np.save(tmp_path / "spool" / "x.npy", np.ones(4, np.float32))
    with open(tmp_path / "spool" / "x.npy.tenant", "w") as f:
        f.write("../../escape")
    with open(tmp_path / "spool" / "x.npy.w", "w") as f:
        f.write("1.0")
    assert disk.ingest_external() == []          # quarantined, not routed
    assert disk.count() == 0
    assert os.path.exists(tmp_path / "spool" / "x.npy")  # never moved
    assert not os.path.exists(tmp_path / "escape")


def test_sidecar_route_colliding_with_default_cid_evicts_stale_entry(
    tmp_path,
):
    """The root staging namespace is shared: a sidecar-routed
    submission reusing a live default-tenant cid has already
    overwritten its blob bytes — the store must evict the stale default
    entry instead of folding another tenant's payload into the default
    round."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.write("c9", np.ones(4, np.float32), weight=2.0)   # default
    assert store.count("default") == 1
    # external writer reuses the cid via the root+sidecar route
    np.save(tmp_path / "c9.npy", np.full(4, 8.0, np.float32))
    with open(tmp_path / "c9.npy.tenant", "w") as f:
        f.write("appS")
    with open(tmp_path / "c9.npy.w", "w") as f:
        f.write("4.0")
    assert store.ingest_external() == ["c9"]
    assert store.count("default") == 0     # stale entry evicted
    assert store.count("appS") == 1
    upd, weight = store.read("c9", tenant="appS")
    assert weight == 4.0
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.full(4, 8.0, np.float32))


def test_recover_leaves_pending_sidecar_routing_to_ingest(tmp_path):
    """Restart with a root blob whose ``.tenant`` sidecar names another
    tenant: _recover must NOT register it under default (cross-tenant
    steal) — it stays unregistered until ingest_external routes it."""
    np.save(tmp_path / "r0.npy", np.full(4, 2.0, np.float32))
    with open(tmp_path / "r0.npy.tenant", "w") as f:
        f.write("appT")
    with open(tmp_path / "r0.npy.w", "w") as f:
        f.write("3.0")
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    assert store.count("default") == 0      # not stolen by recovery
    assert store.count("appT") == 0
    assert store.ingest_external() == ["r0"]
    assert store.count("appT") == 1
    _, weight = store.read("r0", tenant="appT")
    assert weight == 3.0


def test_recover_leaves_default_subdir_to_ingest(tmp_path):
    """Restart with a literal ``default/`` subdirectory: _recover must
    not register it in place (its read paths resolve to the root) —
    ingest_external moves and registers it."""
    os.makedirs(tmp_path / "default")
    np.save(tmp_path / "default" / "d1.npy", np.full(4, 4.0, np.float32))
    with open(tmp_path / "default" / "d1.npy.w", "w") as f:
        f.write("2.0")
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    assert store.count() == 0
    assert store.ingest_external() == ["d1"]
    upd, weight = store.read("d1")          # readable at the ROOT path
    assert weight == 2.0
    np.testing.assert_array_equal(np.asarray(upd),
                                  np.full(4, 4.0, np.float32))


def test_spool_tailer_discovers_tenant_subdirs(tmp_path):
    """External writes into a tenant subdirectory created AFTER the
    tailer started are still discovered and routed."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    with SpoolTailer(store, poll_interval=0.05):
        def foreign_writer():
            time.sleep(0.1)
            os.makedirs(tmp_path / "late-tenant")
            np.save(tmp_path / "late-tenant" / "x.npy",
                    np.ones(4, np.float32))
            with open(tmp_path / "late-tenant" / "x.npy.w", "w") as f:
                f.write("1.5")
        th = threading.Thread(target=foreign_writer)
        th.start()
        deadline = time.time() + 5.0
        while store.count("late-tenant") < 1 and time.time() < deadline:
            time.sleep(0.02)
        th.join()
        assert store.count("late-tenant") == 1
        _, weight = store.read("x", tenant="late-tenant")
        assert weight == 1.5


# -- cross-tenant prior (cold-start transfer) ----------------------------------


def test_cold_start_tenant_borrows_prior():
    """A tenant with no history gets a policy derived from the pooled
    cross-tenant curve instead of the static timeout."""
    c = AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                           timeout=30.0)
    # tenant A: 8 of 10 arrive within 1 s, 2 drop
    for _ in range(3):
        c.observe_round("A", np.linspace(0.1, 1.0, 8), 10)
    pol = c.policy("fresh-tenant", 10)
    assert pol.source == "prior"
    assert pol.threshold == 8          # the prior's attainable fleet
    assert pol.deadline < 5.0          # ~A's tail, not the 30 s timeout
    # once the tenant has its own mass, its own curve takes over
    c.observe_round("fresh-tenant", np.linspace(0.05, 0.2, 10), 10)
    own = c.policy("fresh-tenant", 10)
    assert own.source == "learned"
    assert own.deadline < pol.deadline  # its fleet is faster than A's


def test_prior_survives_state_dict_roundtrip():
    c = AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                           timeout=30.0)
    for _ in range(2):
        c.observe_round("A", np.linspace(0.1, 0.6, 10), 10)
    c2 = AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                            timeout=30.0)
    c2.load_state_dict(c.state_dict())
    assert c2.prior_model().rounds == c.prior_model().rounds
    assert c2.policy("unseen", 10) == c.policy("unseen", 10)
    assert c2.policy("unseen", 10).source == "prior"


def test_service_cold_tenant_closes_on_prior():
    """End to end: tenant A trains the prior; tenant B's FIRST round
    already closes early instead of burning the static timeout."""
    n, p = 8, 24
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    svc = _service(store, clk, adaptive=True)

    def schedule(tenant, base):
        for i in range(n):
            clk.at(base + 0.1 * (i + 1),
                   lambda i=i: store.write(f"c{i}", u[i],
                                           weight=float(w[i]),
                                           tenant=tenant))

    schedule("A", 0.0)
    _, rep1 = svc.aggregate(from_store=True, expected_clients=10,
                            async_round=True, tenant="A")
    assert rep1.close_policy.source == "static"
    assert rep1.monitor.waited >= 30.0      # static gate burns the timeout

    schedule("B", clk.t)
    _, rep2 = svc.aggregate(from_store=True, expected_clients=10,
                            async_round=True, tenant="B")
    assert rep2.close_policy.source == "prior"
    assert rep2.n_clients == n              # same inclusion as A achieved
    assert rep2.monitor.waited < 3.0        # closed on the borrowed curve


# -- drift detection -----------------------------------------------------------


def test_drift_tracks_regime_change_and_decays():
    m = ArrivalModel(n_quantiles=10, ema=0.5)
    for _ in range(3):
        m.observe(np.linspace(0.1, 1.0, 10), expected=10)
    assert m.drift == pytest.approx(0.0, abs=1e-9)   # steady state
    m.observe(np.linspace(0.4, 4.0, 10), expected=10)  # 4x slowdown
    assert m.drift is not None and m.drift > 0.3
    for _ in range(6):   # new regime becomes the steady state again
        m.observe(np.linspace(0.4, 4.0, 10), expected=10)
    assert m.drift < 0.1


def test_drift_widens_learned_deadline_capped_at_timeout():
    mk = lambda: AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                                    timeout=30.0)
    steady, shifted = mk(), mk()
    for _ in range(3):
        steady.observe_round("m", np.linspace(0.1, 1.0, 10), 10)
        shifted.observe_round("m", np.linspace(0.1, 1.0, 10), 10)
    # the shifted fleet slows down 3x in ONE round — faster than the EW
    # window has tracked, so the deadline backstop must loosen
    shifted.observe_round("m", np.linspace(0.3, 3.0, 10), 10)
    pol_steady = steady.policy("m", 10)
    pol_shifted = shifted.policy("m", 10)
    assert shifted.model("m").drift > steady.model("m").drift
    # compare the deadline each policy grants per second of expected
    # wait — the widening factor, independent of the curve itself
    ratio_steady = pol_steady.deadline / pol_steady.expected_wait
    ratio_shifted = pol_shifted.deadline / pol_shifted.expected_wait
    assert ratio_shifted > ratio_steady * 1.2
    assert pol_shifted.deadline <= 30.0


# -- controller checkpoint / restart -------------------------------------------


def test_controller_checkpoint_roundtrip_files(tmp_path):
    c = AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                           timeout=30.0)
    for _ in range(3):
        c.observe_round("m", np.linspace(0.1, 1.0, 8), 10,
                        est_seconds=0.02)
    path = save_controller_state(str(tmp_path / "round7.npz"), c)
    assert path.endswith(".controller.json")
    c2 = AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                            timeout=30.0)
    load_controller_state(str(tmp_path / "round7.npz"), c2)
    assert c2.tenants() == ["m"]
    assert c2.policy("m", 10) == c.policy("m", 10)
    assert c2.policy("m", 10).source == "learned"


def test_restarted_service_resumes_learned(tmp_path):
    """The ISSUE-4 acceptance bar: a restarted service restores the
    controller from repro/checkpoint and its FIRST round closes on the
    learned gate — no cold-start re-learning."""
    n, p = 8, 24
    u, w = _mk(n, p)
    ckpt = str(tmp_path / "model")

    def schedule(clk, store, base):
        for i in range(n):
            clk.at(base + 0.1 * (i + 1),
                   lambda i=i: store.write(f"c{i}", u[i],
                                           weight=float(w[i])))

    clk1 = ScriptedClock()
    store1 = UpdateStore(clock=clk1.clock)
    svc1 = _service(store1, clk1, adaptive=True)
    schedule(clk1, store1, 0.0)
    _, rep1 = svc1.aggregate(from_store=True, expected_clients=10,
                             async_round=True)
    assert rep1.close_policy.source == "static"   # cold first round
    svc1.save_controller(ckpt)

    # 'restart': fresh store, fresh clock, fresh service — then restore
    clk2 = ScriptedClock()
    store2 = UpdateStore(clock=clk2.clock)
    svc2 = _service(store2, clk2, adaptive=True)
    svc2.load_controller(ckpt)
    schedule(clk2, store2, 0.0)
    _, rep2 = svc2.aggregate(from_store=True, expected_clients=10,
                             async_round=True)
    assert rep2.close_policy.source == "learned"  # resumed, not re-learned
    assert rep2.n_clients == n
    assert rep2.monitor.waited < 3.0              # closes on the curve
    # non-adaptive services refuse (no controller to persist)
    plain = _service(UpdateStore())
    with pytest.raises(ValueError):
        plain.save_controller(str(tmp_path / "x"))
    with pytest.raises(ValueError):
        plain.load_controller(ckpt)
